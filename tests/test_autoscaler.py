"""Load autoscaler: N_Can = ceil(R/Q_Tar) with hysteresis (§4)."""

import pytest

from repro.core.autoscaler import ConstantTarget, LoadAutoscaler


def test_constant():
    a = ConstantTarget(5)
    assert a.target(0.0) == 5
    a.observe(10.0, 100)
    assert a.target(10.0) == 5


def test_candidate_formula():
    a = LoadAutoscaler(2.0, window_s=60.0, min_replicas=1)
    for t in range(60):
        a.observe(float(t), 6)      # 6 req/s
    assert a.candidate(60.0) == 3   # ceil(6/2)


def test_upscale_needs_sustained_load():
    a = LoadAutoscaler(
        1.0, window_s=60.0, upscale_delay_s=120.0, initial_target=1
    )
    for t in range(0, 60):
        a.observe(float(t), 4)
    assert a.target(59.0) == 1      # diverged but not sustained yet
    for t in range(60, 200):
        a.observe(float(t), 4)
        a.target(float(t))
    assert a.target(200.0) == 4     # sustained past upscale_delay


def test_downscale_slower_than_upscale():
    a = LoadAutoscaler(
        1.0, window_s=60.0, upscale_delay_s=60.0,
        downscale_delay_s=600.0, initial_target=8, min_replicas=1,
    )
    # traffic stops
    t = 0.0
    while t < 500.0:
        a.observe(t, 0)
        assert a.target(t) == 8     # still holding
        t += 30.0
    while t < 700.0:
        a.observe(t, 0)
        a.target(t)
        t += 30.0
    assert a.target(t) == 1


def test_flapping_resets_hysteresis():
    a = LoadAutoscaler(
        1.0, window_s=30.0, upscale_delay_s=120.0, initial_target=2
    )
    # alternate load so the candidate flips direction before the delay
    # (60 s spacing > 30 s window: quiet periods actually show rate 0)
    for t in range(0, 600, 60):
        rate = 6 if (t // 60) % 2 == 0 else 0
        a.observe(float(t), rate * 30)
        a.target(float(t))
    assert a.target(600.0) == 2


def test_bounds():
    a = LoadAutoscaler(0.1, min_replicas=2, max_replicas=5, window_s=10.0)
    for t in range(10):
        a.observe(float(t), 1000)
    assert a.candidate(10.0) == 5
    a2 = LoadAutoscaler(10.0, min_replicas=2, max_replicas=5)
    assert a2.candidate(0.0) == 2


def test_invalid_qps():
    with pytest.raises(ValueError):
        LoadAutoscaler(0.0)

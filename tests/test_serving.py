"""Serving data plane: latency model, replica queueing, LB, e2e sim."""

import numpy as np
import pytest

from repro.cluster.catalog import default_catalog
from repro.cluster.instance import Instance, InstanceKind
from repro.cluster.traces import SpotTrace, synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.serving.latency import LatencyModel
from repro.serving.load_balancer import (
    LeastLoadedBalancer,
    RoundRobinBalancer,
)
from repro.serving.replica import Replica, ReplicaState
from repro.serving.sim import ServingSimulator
from repro.workloads import make_workload
from repro.workloads.arrivals import Request

CAT = default_catalog()
CFG = get_config("llama3.2-1b")


def mk_replica(zone="us-west-2a", t=0.0, ready=True, concurrency=2,
               timeout_s=0.0):
    z = CAT.zone(zone)
    inst = Instance(
        zone=zone, region=z.region, cloud=z.cloud,
        kind=InstanceKind.SPOT, itype="g5.48xlarge", hourly_price=4.9,
        launched_at=t, cold_start_s=183.0,
    )
    lm = LatencyModel.for_model(CFG, CAT.instance_type("g5.48xlarge"))
    r = Replica(inst, lm, concurrency=concurrency, timeout_s=timeout_s)
    if ready:
        inst.step_to(t + 200.0)
        r.readiness_probe(t + 200.0)
    return r


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------


def test_latency_monotone_in_tokens():
    lm = LatencyModel.for_model(CFG, CAT.instance_type("g5.48xlarge"))
    assert lm.service_s(100, 100) < lm.service_s(1000, 100)
    assert lm.service_s(100, 100) < lm.service_s(100, 1000)


def test_decode_dominates_prefill_for_short_prompts():
    """Fig. 6a structure: decoding dominates request time."""
    lm = LatencyModel.for_model(CFG, CAT.instance_type("g5.48xlarge"))
    assert 44 * lm.decode_s_per_token() > lm.prefill_s(20)


def test_processing_dominates_rtt():
    """§3.1: request processing >> inter-region network latency."""
    from repro.cluster.catalog import region_rtt_ms

    big = get_config("command-r-35b")
    lm = LatencyModel.for_model(big, CAT.instance_type("g5.48xlarge"))
    service = lm.service_s(200, 150)
    rtt = region_rtt_ms("us-west-2", "eu-central-1") / 1e3
    assert service > 10 * rtt


# ---------------------------------------------------------------------------
# Replica
# ---------------------------------------------------------------------------


def test_replica_readiness_follows_instance():
    r = mk_replica(ready=False)
    assert r.state is ReplicaState.PROVISIONING
    r.instance.step_to(200.0)
    assert r.readiness_probe(200.0)


def test_replica_completes_requests():
    r = mk_replica()
    req = Request(arrival_s=0.0, prompt_tokens=50, output_tokens=20)
    r.submit(req, 0.0)
    done, _ = r.step(0.0)
    assert done == []          # just started
    done, _ = r.step(1e6)
    assert len(done) == 1
    assert done[0][0].id == req.id


def test_replica_concurrency_queueing():
    r = mk_replica(concurrency=1)
    reqs = [Request(arrival_s=0.0, prompt_tokens=50, output_tokens=50)
            for _ in range(3)]
    for q in reqs:
        r.submit(q, 0.0)
    r.step(0.0)
    assert len(r.running) == 1 and len(r.queue) == 2


def test_replica_kill_returns_inflight():
    r = mk_replica()
    for _ in range(3):
        r.submit(Request(arrival_s=0.0, prompt_tokens=10,
                         output_tokens=10), 0.0)
    r.step(0.0)
    failed = r.kill()
    assert len(failed) == 3
    assert r.state is ReplicaState.DEAD


def test_replica_queue_expiry():
    r = mk_replica(concurrency=1, timeout_s=10.0)
    r.submit(Request(arrival_s=0.0, prompt_tokens=10, output_tokens=10),
             0.0)
    r.submit(Request(arrival_s=0.0, prompt_tokens=10, output_tokens=10),
             0.0)
    r.step(0.0)
    _, expired = r.step(50.0)
    assert len(expired) == 1


# ---------------------------------------------------------------------------
# Load balancers
# ---------------------------------------------------------------------------


def test_round_robin_cycles():
    lb = RoundRobinBalancer()
    reps = [mk_replica(z) for z in
            ("us-west-2a", "us-west-2b", "us-west-2c")]
    lb.update_ready(reps)
    req = Request(arrival_s=0.0, prompt_tokens=1, output_tokens=1)
    picks = [lb.pick(req, 0.0).zone for _ in range(6)]
    assert picks[:3] == ["us-west-2a", "us-west-2b", "us-west-2c"]


def test_least_loaded_prefers_idle():
    lb = LeastLoadedBalancer()
    busy, idle = mk_replica("us-west-2a"), mk_replica("us-west-2b")
    for _ in range(4):
        busy.submit(Request(arrival_s=0.0, prompt_tokens=9,
                            output_tokens=9), 0.0)
    lb.update_ready([busy, idle])
    pick = lb.pick(Request(arrival_s=0.0, prompt_tokens=1,
                           output_tokens=1), 0.0)
    assert pick is idle


def test_lb_returns_none_when_nothing_ready():
    lb = LeastLoadedBalancer()
    lb.update_ready([])
    assert lb.pick(Request(arrival_s=0.0, prompt_tokens=1,
                           output_tokens=1), 0.0) is None


# ---------------------------------------------------------------------------
# End-to-end serving sim
# ---------------------------------------------------------------------------


def _mini_trace(steps=240):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=21, max_capacity=4, name="mini")


def test_serving_sim_completes_requests():
    tr = _mini_trace()
    reqs = make_workload("poisson", rate_per_s=0.5, seed=1).generate(
        3 * 3600.0
    )
    sim = ServingSimulator(
        tr, make_policy("spothedge"), reqs, CFG, itype="g5.48xlarge",
        autoscaler=ConstantTarget(2), timeout_s=60.0,
        workload_name="poisson",
    )
    res = sim.run(3 * 3600.0 + 600.0)
    assert res.n_requests == len(reqs)
    assert res.n_completed > 0.9 * len(reqs)
    assert res.failure_rate < 0.1
    assert res.pct(50) < 60.0


def test_spothedge_beats_singleregion_spot_on_failures():
    tr = _mini_trace(steps=480)
    reqs = make_workload("poisson", rate_per_s=1.0, seed=2).generate(
        6 * 3600.0
    )

    def run(policy, zones=None):
        sim = ServingSimulator(
            tr, make_policy(policy), reqs, CFG, itype="g5.48xlarge",
            autoscaler=ConstantTarget(3), timeout_s=60.0, concurrency=2,
        )
        return sim.run(6 * 3600.0 + 600.0)

    hedge = run("spothedge")
    spread = run("even_spread")
    assert hedge.failure_rate <= spread.failure_rate
    assert hedge.availability > spread.availability

"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward/train step on CPU asserting output shapes + no NaNs, plus
a prefill→decode consistency check (the cache path must reproduce the
full-sequence forward exactly).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, cells_for
from repro.models import build_model, param_count

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    kt, kl, kf = jax.random.split(jax.random.PRNGKey(1), 3)
    toks = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    front = None
    if cfg.frontend:
        front = jax.random.normal(
            kf, (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16
        )
    return toks, labels, front


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads(arch):
    cfg = get_config(arch)
    n = param_count(build_model(cfg).blueprint())
    assert n > 1e8          # every assigned arch is >100M params
    assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
    assert cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    toks, labels, front = _inputs(cfg)
    if cfg.is_encdec:
        loss = model.loss(params, front, toks, labels)
    else:
        loss = model.loss(params, toks, labels, prefix_embed=front)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, remat=True)
    params = model.init(KEY)
    toks, labels, front = _inputs(cfg)

    def loss_fn(p):
        if cfg.is_encdec:
            return model.loss(p, front, toks, labels)
        return model.loss(p, toks, labels, prefix_embed=front)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert gn > 0 and jnp.isfinite(gn)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_prefill(arch):
    """Strong cache-correctness check: decode logits == full prefill."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S0, S1 = 2, 8, 3
    toks, _, front = _inputs(cfg, B=B, S=S0 + S1)
    extra = cfg.frontend_seq if (cfg.frontend and not cfg.is_encdec) else 0

    def fresh_cache():
        return model.init_cache(B, S0 + S1 + 4 + extra)

    if cfg.is_encdec:
        lg, cache = model.prefill(params, front, toks[:, :S0],
                                  fresh_cache())
    else:
        lg, cache = model.prefill(params, toks[:, :S0], fresh_cache(),
                                  prefix_embed=front)
    assert lg.shape[0] == B and bool(
        jnp.all(jnp.isfinite(lg.astype(jnp.float32)))
    )
    for t in range(S0, S0 + S1):
        if cfg.is_encdec:
            ref, _ = model.prefill(params, front, toks[:, : t + 1],
                                   fresh_cache())
        else:
            ref, _ = model.prefill(params, toks[:, : t + 1], fresh_cache(),
                                   prefix_embed=front)
        got, cache = model.decode_step(params, toks[:, t : t + 1], cache)
        ref32 = ref.astype(jnp.float32)
        err = jnp.abs(ref32 - got.astype(jnp.float32)).max()
        # bf16 resolution scales with logit magnitude; capacity-based MoE
        # routing is additionally batch-composition dependent
        scale = float(jnp.abs(ref32).max())
        tol = (0.1 if cfg.is_moe else 0.02) + 0.004 * scale
        assert float(err) <= tol, f"{arch} decode mismatch at t={t}: {err}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_context_rule(arch):
    """long_500k runs only for sub-quadratic archs (assignment rule)."""
    cfg = get_config(arch)
    cells = dict(cells_for(cfg))
    if cfg.family in ("ssm", "hybrid") or cfg.sliding_window:
        assert cells["long_500k"] == "run"
    else:
        assert cells["long_500k"].startswith("skip")


def test_sliding_window_ring_buffer():
    """SWA decode must work past the window with a ring cache."""
    cfg = get_smoke_config("h2o-danube3-4b")
    assert cfg.sliding_window is not None and cfg.sliding_window <= 64
    model = build_model(cfg)
    params = model.init(KEY)
    B = 1
    S = cfg.sliding_window + 12      # go past the window
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    cache = model.init_cache(B, S + 8)
    assert cache["kv"]["k"].shape[2] == cfg.sliding_window  # ring slots
    lg, cache = model.prefill(params, toks[:, :8], cache)
    for t in range(8, S):
        lg, cache = model.decode_step(params, toks[:, t : t + 1], cache)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))


def test_zamba2_layer_accounting():
    cfg = get_config("zamba2-7b")
    assert cfg.hybrid_blocks == 13
    assert cfg.hybrid_prelude == 3
    assert cfg.hybrid_mamba_layers == 68
    assert cfg.hybrid_mamba_layers + cfg.hybrid_blocks == cfg.num_layers


def test_paligemma_prefix_lm_attends_bidirectionally():
    """Prefix tokens must see each other (prefix-LM), unlike causal."""
    from repro.models.attention import naive_attention

    B, S, H, D = 1, 8, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    pos = jnp.arange(S)
    causal = naive_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    prefix = naive_attention(
        q, k, v, q_pos=pos, kv_pos=pos, causal=True, prefix_len=4
    )
    # position 0 sees positions 1-3 only under prefix-LM
    assert not jnp.allclose(causal[:, 0], prefix[:, 0])
    # last position attends everything either way
    assert jnp.allclose(causal[:, -1], prefix[:, -1], atol=1e-5)

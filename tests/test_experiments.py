"""ScenarioSuite: grid expansion, sweep loading, reports, execution."""

import json

import pytest

from repro.experiments import Scenario, ScenarioSuite
from repro.service import (
    ReplicaPolicySpec,
    SpecError,
    spec_from_dict,
)

BASE = {
    "name": "exp",
    "model": "llama3.2-1b",
    "trace": "aws-1",
    "resources": {"instance_type": "g5.48xlarge"},
    "autoscaler": {"kind": "constant", "target": 2},
    "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 3},
    "sim": {"duration_hours": 0.5, "timeout_s": 60.0,
            "concurrency": 2, "drain_s": 300.0},
}


def _spec(**over):
    d = {**BASE, **over}
    return spec_from_dict(d)


# ---------------------------------------------------------------------------
# sweep spec + loader
# ---------------------------------------------------------------------------


def test_sweep_grid_size_and_expansion():
    spec = _spec(sweep={
        "policies": ["spothedge", "even_spread"],
        "traces": ["aws-1", "gcp-1"],
        "workloads": ["poisson", "arena"],
        "seeds": [0, 1, 2],
    })
    assert spec.sweep.size == 24
    suite = ScenarioSuite.from_spec(spec)
    assert len(suite) == 24
    labels = {sc.cell_id for sc in suite.scenarios}
    assert len(labels) == 24                      # all cells distinct
    assert "spothedge/aws-1/poisson/0" in labels
    # expanded cells are single-run specs
    assert all(sc.spec.sweep is None for sc in suite.scenarios)


def test_sweep_axes_default_to_base_values():
    spec = _spec(sweep={"policies": ["spothedge", "even_spread"]})
    suite = ScenarioSuite.from_spec(spec)
    assert len(suite) == 2
    for sc in suite.scenarios:
        assert sc.spec.trace == "aws-1"
        assert sc.spec.workload.kind == "poisson"
        assert sc.spec.workload.seed == 3


def test_sweep_policy_entries_accept_mappings():
    spec = _spec(sweep={
        "policies": ["spothedge", {"name": "spothedge",
                                   "overprovision": 3}],
    })
    pols = [sc.spec.replica_policy for sc in
            ScenarioSuite.from_spec(spec).scenarios]
    assert pols[0] == ReplicaPolicySpec(name="spothedge")
    assert pols[1].overprovision == 3


def test_sweep_duplicate_policy_names_get_distinct_labels():
    spec = _spec(sweep={
        "policies": [
            {"name": "spothedge", "overprovision": 0},
            {"name": "spothedge", "overprovision": 2},
        ],
    })
    suite = ScenarioSuite.from_spec(spec)
    labels = [sc.labels["policy"] for sc in suite.scenarios]
    assert len(set(labels)) == 2
    assert all("spothedge" in lab for lab in labels)


def test_sweep_seeds_override_workload_seed():
    spec = _spec(sweep={"seeds": [7, 8]})
    seeds = [sc.spec.workload.seed for sc in
             ScenarioSuite.from_spec(spec).scenarios]
    assert seeds == [7, 8]


def test_sweep_without_seeds_axis_keeps_workload_seeds():
    spec = _spec(sweep={"workloads": [
        {"kind": "poisson", "rate_per_s": 1.0, "seed": 7},
        {"kind": "poisson", "rate_per_s": 2.0, "seed": 9},
    ]})
    cells = ScenarioSuite.from_spec(spec).scenarios
    assert [sc.spec.workload.seed for sc in cells] == [7, 9]
    assert [sc.labels["seed"] for sc in cells] == [7, 9]
    # same kind, different knobs -> labels must stay distinguishable
    labels = [sc.labels["workload"] for sc in cells]
    assert len(set(labels)) == 2


def test_scenario_rejects_metric_shadowing_labels():
    with pytest.raises(SpecError, match="collide"):
        Scenario(labels={"n_requests": "small"}, spec=_spec())


def test_sweep_rejects_unknown_policy_and_trace():
    with pytest.raises(SpecError, match="sweep policy"):
        _spec(sweep={"policies": ["not-a-policy"]})
    with pytest.raises(SpecError, match="sweep trace"):
        _spec(sweep={"traces": ["not-a-trace"]})


def test_sweep_rejects_malformed_sections():
    with pytest.raises(SpecError, match="sweep"):
        _spec(sweep={"policies": "spothedge"})       # not a list
    with pytest.raises(SpecError, match="unknown keys"):
        _spec(sweep={"polices": ["spothedge"]})      # typo'd key


def test_sweep_round_trips_through_dict():
    spec = _spec(sweep={"policies": ["spothedge"], "seeds": [1, 2]})
    assert spec_from_dict(spec.to_dict()) == spec


def test_engine_field_validated():
    with pytest.raises(SpecError, match="sim.engine"):
        _spec(sim={**BASE["sim"], "engine": "warp-drive"})


def test_scenario_rejects_unexpanded_sweep():
    spec = _spec(sweep={"seeds": [1, 2]})
    with pytest.raises(SpecError, match="expand the sweep"):
        Scenario(labels={"x": 1}, spec=spec)


# ---------------------------------------------------------------------------
# suite execution + report
# ---------------------------------------------------------------------------


def _small_suite():
    return ScenarioSuite.from_spec(_spec(sweep={
        "policies": ["spothedge", "even_spread"],
    }))


def test_suite_run_produces_cells_in_order():
    report = _small_suite().run()
    assert [c.labels["policy"] for c in report.cells] == [
        "spothedge", "even_spread"
    ]
    for c in report.cells:
        assert c.n_requests > 0
        assert c.n_completed + c.n_failed <= c.n_requests * 2
        assert 0.0 <= c.availability <= 1.0
        assert c.wall_s > 0


def test_suite_shares_request_tapes_across_cells():
    suite = _small_suite()
    keys = {sc.tape_key for sc in suite.scenarios}
    assert len(keys) == 1          # same workload -> one tape
    report = suite.run()
    assert (report.cells[0].n_requests ==
            report.cells[1].n_requests)


def test_suite_engine_override_matches_default():
    suite = _small_suite()
    vec = suite.run()
    leg = suite.run(engine="legacy")
    for a, b in zip(vec.cells, leg.cells):
        assert a.n_completed == b.n_completed
        assert a.n_failed == b.n_failed
        assert a.p50_s == pytest.approx(b.p50_s, abs=1e-9)


def test_suite_parallel_equals_serial():
    suite = _small_suite()
    serial = suite.run()
    parallel = suite.run(workers=2)
    assert parallel.workers == 2
    for a, b in zip(serial.cells, parallel.cells):
        da = {**a.to_dict(round_to=None), "wall_s": None}
        db = {**b.to_dict(round_to=None), "wall_s": None}
        assert da == db


def test_report_select_and_json_artifact(tmp_path):
    report = _small_suite().run(save_to=str(tmp_path))
    assert len(report.select(policy="spothedge")) == 1
    assert report.select(policy="nope") == []

    path = tmp_path / "scenario_exp.json"
    assert path.exists()
    doc = json.loads(path.read_text())
    assert doc["schema"] == 1
    assert doc["suite"] == "exp"
    assert doc["n_cells"] == 2
    cell = doc["cells"][0]
    for key in ("policy", "trace", "workload", "seed", "n_requests",
                "n_completed", "n_failed", "failure_rate", "p50_s",
                "p90_s", "p99_s", "total_cost", "cost_vs_ondemand",
                "availability", "n_preemptions", "wall_s"):
        assert key in cell, f"artifact cell missing {key}"


def test_suite_requires_scenarios():
    with pytest.raises(SpecError, match="at least one"):
        ScenarioSuite([])


def test_suite_rejects_bad_worker_counts():
    suite = _small_suite()
    with pytest.raises(SpecError, match="workers"):
        suite.run(workers="two")
    with pytest.raises(SpecError, match="workers"):
        suite.run(workers=0)


def test_worker_tape_cache_keyed_by_workload():
    """Reusing a tape_key with a different workload must not replay the
    first workload's arrivals (the worker cache outlives one run)."""
    spec_a = _spec()
    spec_b = _spec(workload={"kind": "poisson", "rate_per_s": 2.0,
                             "seed": 9})
    suite_a = ScenarioSuite(
        [Scenario(labels={"case": "a"}, spec=spec_a, tape_key="shared")],
        name="tapes-a",
    )
    suite_b = ScenarioSuite(
        [Scenario(labels={"case": "b"}, spec=spec_b, tape_key="shared")],
        name="tapes-b",
    )
    ra = suite_a.run(workers=2)
    rb = suite_b.run(workers=2)
    # 4x the rate -> far more requests; a stale shared tape would make
    # the two runs identical
    assert rb.cells[0].n_requests > 2 * ra.cells[0].n_requests


def test_suite_custom_scenarios_with_trace_override():
    from repro.cluster.traces import TraceLibrary

    tr = TraceLibrary().get("aws-1")
    base = _spec()
    sliced = tr.slice_zones(list(tr.zones[:2]))
    suite = ScenarioSuite(
        [Scenario(labels={"case": "sliced"}, spec=base, trace=sliced)],
        name="custom",
    )
    report = suite.run()
    assert len(report.cells) == 1
    assert report.cells[0].labels == {"case": "sliced"}

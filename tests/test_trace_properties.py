"""Property tests for the synthetic spot-trace generator.

The generator must reproduce the paper's documented trace structure:

* Fig. 3: preemptions are correlated *within* a region (sibling zones,
  Pearson r >= 0.3) and nearly independent *across* regions;
* Fig. 4: spot GPU availability is volatile (16.7-90.4 %), spot CPU
  availability is high (95.6-99.9 %);
* capacities are integers in [0, max_capacity] for every seed.

The deterministic tests check the shipped datasets; the hypothesis tests
explore the generator across seeds (bounds chosen so every seed in the
strategy range satisfies the property with margin — verified exhaustively
before pinning).
"""

import numpy as np
import pytest

from repro.cluster.traces import TraceLibrary, synth_correlated_trace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

LIB = TraceLibrary()
GPU_DATASETS = ("aws-1", "aws-2", "aws-3", "gcp-1")

# Fig. 4a/4b availability bands
GPU_BAND = (0.167, 0.904)
CPU_MIN = 0.95


def _region_of(zone: str) -> str:
    # "us-west-2a" -> "us-west-2"; "us-central1-a" -> "us-central1"
    return zone.rsplit("-", 1)[0] if "-" in zone[-2:] else zone[:-1]


def _corr_split(trace):
    """(mean sibling-zone r, mean cross-region r) of preemption events."""
    m = trace.zone_correlation(bin_steps=5)
    sib, cross = [], []
    for i in range(len(trace.zones)):
        for j in range(i + 1, len(trace.zones)):
            same = (
                _region_of(trace.zones[i]) == _region_of(trace.zones[j])
            )
            (sib if same else cross).append(m[i, j])
    return (
        float(np.mean(sib)) if sib else float("nan"),
        float(np.mean(cross)) if cross else float("nan"),
    )


# ---------------------------------------------------------------------------
# deterministic dataset checks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", GPU_DATASETS)
def test_gpu_dataset_availability_in_documented_band(name):
    tr = LIB.get(name)
    mean_avail = float(
        np.mean([tr.availability(z) for z in tr.zones])
    )
    assert GPU_BAND[0] <= mean_avail <= GPU_BAND[1], (
        f"{name}: mean availability {mean_avail:.3f} outside the Fig. 4 "
        f"GPU band {GPU_BAND}"
    )


def test_cpu_dataset_availability_high():
    tr = LIB.get("cpu-ref")
    for z in tr.zones:
        assert tr.availability(z) >= CPU_MIN


@pytest.mark.parametrize("name", ("aws-1", "aws-2"))
def test_single_region_datasets_sibling_correlation(name):
    """Fig. 3: sibling zones of one region correlate with r >= 0.3."""
    sib, _ = _corr_split(LIB.get(name))
    assert sib >= 0.3, f"{name}: sibling-zone r {sib:.3f} < 0.3"


@pytest.mark.parametrize("name", ("aws-3", "gcp-1"))
def test_multi_region_datasets_correlation_structure(name):
    """Fig. 3: intra-region correlation dominates cross-region."""
    sib, cross = _corr_split(LIB.get(name))
    assert sib >= 0.15
    assert cross <= 0.15
    assert sib > cross


@pytest.mark.parametrize("name", GPU_DATASETS + ("cpu-ref",))
def test_dataset_capacity_bounds(name):
    tr = LIB.get(name)
    assert tr.cap.min() >= 0
    assert np.issubdtype(tr.cap.dtype, np.integer)


# ---------------------------------------------------------------------------
# hypothesis: generator properties across seeds
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _ZONES = ["us-west-2a", "us-west-2b", "us-west-2c"]
    _ZMAP = {z: z[:-1] for z in _ZONES}

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 50))
    def test_sibling_correlation_property(seed):
        """Crunch-dominated regime: sibling r >= 0.3 for every seed.

        (Seeds 0..50 verified exhaustively; min observed r = 0.61.)
        """
        tr = synth_correlated_trace(
            _ZONES, _ZMAP, steps=15000, dt=60.0, seed=seed,
            max_capacity=4,
            region_mean_up_steps=300.0, region_mean_down_steps=60.0,
            zone_mean_up_steps=4000.0, zone_mean_down_steps=30.0,
            crunch_participation=0.97, crunch_max_lag_steps=1,
        )
        sib, _ = _corr_split(tr)
        assert sib >= 0.3
        assert tr.cap.min() >= 0

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        steps=st.integers(50, 600),
        max_capacity=st.integers(1, 8),
    )
    def test_capacity_nonnegative_and_bounded(seed, steps, max_capacity):
        """cap in [0, max_capacity] for arbitrary seeds and shapes."""
        tr = synth_correlated_trace(
            _ZONES, _ZMAP, steps=steps, dt=60.0, seed=seed,
            max_capacity=max_capacity,
        )
        assert tr.cap.shape == (steps, len(_ZONES))
        assert tr.cap.min() >= 0
        assert tr.cap.max() <= max_capacity

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_availability_consistent_with_capacity(seed):
        """availability() is exactly the fraction of cap>0 steps."""
        tr = synth_correlated_trace(
            _ZONES, _ZMAP, steps=400, dt=60.0, seed=seed, max_capacity=4,
        )
        for j, z in enumerate(tr.zones):
            assert tr.availability(z) == pytest.approx(
                float((tr.cap[:, j] > 0).mean())
            )

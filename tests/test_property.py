"""Hypothesis property tests over system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.cluster.catalog import default_catalog
from repro.cluster.instance import Instance, InstanceKind
from repro.core.policy import (
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Terminate,
)
from repro.core.spothedge import SpotHedgePolicy
from repro.distributed.compression import ef_quantize, quantize_int8
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import blockwise_attention, naive_attention

import jax
import jax.numpy as jnp

CAT = default_catalog()
ZONES = CAT.zones_in_region("us-west-2") + CAT.zones_in_region("us-east-1")


def _ready(zone, n, t=0.0):
    out = []
    for _ in range(n):
        z = CAT.zone(zone)
        i = Instance(zone=zone, region=z.region, cloud=z.cloud,
                     kind=InstanceKind.SPOT, itype="p3.2xlarge",
                     hourly_price=1.0, launched_at=t, cold_start_s=60.0)
        i.step_to(t + 100.0)
        out.append(i)
    return out


# ---------------------------------------------------------------------------
# SpotHedge invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n_target=st.integers(0, 12),
    n_extra=st.integers(0, 4),
    s_ready=st.integers(0, 16),
    o_ready=st.integers(0, 12),
)
def test_fallback_bound_invariant(n_target, n_extra, s_ready, o_ready):
    """After one decide(), launched OD never exceeds N_Tar and launched
    spot never exceeds N_Tar + N_Extra (Eq. in §3.2: O(t) <= N_Tar)."""
    p = SpotHedgePolicy(num_overprovision=n_extra)
    p.reset(ZONES, CAT, "p3.2xlarge")
    spot = _ready("us-west-2a", min(s_ready, 8)) + _ready(
        "us-east-1a", max(0, s_ready - 8)
    )
    od = [
        Instance(zone="us-west-2a", region="us-west-2", cloud="aws",
                 kind=InstanceKind.ON_DEMAND, itype="p3.2xlarge",
                 hourly_price=3.0, launched_at=0.0, cold_start_s=60.0)
        for _ in range(o_ready)
    ]
    for i in od:
        i.step_to(100.0)
    obs = Observation(now=200.0, n_target=n_target, spot_ready=spot,
                      spot_provisioning=[], od_ready=od,
                      od_provisioning=[])
    acts = p.decide(obs)
    launched_spot = sum(isinstance(a, LaunchSpot) for a in acts)
    launched_od = sum(isinstance(a, LaunchOnDemand) for a in acts)
    terminated = sum(isinstance(a, Terminate) for a in acts)
    assert len(spot) + launched_spot <= max(n_target + n_extra, len(spot))
    assert launched_od + len(od) - terminated <= max(n_target, len(od))
    # zone sanity: every launch goes to an enabled zone
    names = {z.name for z in ZONES}
    for a in acts:
        if isinstance(a, (LaunchSpot, LaunchOnDemand)):
            assert a.zone in names


@settings(max_examples=30, deadline=None)
@given(events=st.lists(
    st.tuples(st.sampled_from(["preempt", "fail", "ready"]),
              st.integers(0, 9)),
    max_size=60,
))
def test_zone_lists_partition_invariant(events):
    """Z_A and Z_P always partition the enabled zones; |Z_A| >= 2 or all."""
    p = SpotHedgePolicy()
    p.reset(ZONES, CAT, "p3.2xlarge")
    names = [z.name for z in ZONES]
    for kind, zi in events:
        z = names[zi % len(names)]
        if kind == "preempt":
            p.on_preemption(z, 1.0)
        elif kind == "fail":
            p.on_launch_failure(z, 1.0)
        else:
            p.on_ready(z, 1.0)
        za, zp = set(p.available_zones), set(p.preempting_zones)
        assert za | zp == set(names)
        assert not (za & zp)
        assert len(za) >= min(2, len(names))


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    scale=st.floats(1e-6, 1e3),
    n=st.integers(1, 500),
    seed=st.integers(0, 1000),
)
def test_quantize_error_bound(scale, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(s) * 0.5 + 1e-12


@settings(max_examples=20, deadline=None)
def _ef_helper():
    pass


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), steps=st.integers(1, 12))
def test_error_feedback_accumulates_to_truth(seed, steps):
    """sum of transmitted g_hat + final error == sum of true gradients."""
    rng = np.random.default_rng(seed)
    gs = [jnp.asarray(rng.standard_normal(32), jnp.float32)
          for _ in range(steps)]
    err = None
    sent = jnp.zeros(32)
    for g in gs:
        g_hat, err = ef_quantize(g, err)
        sent = sent + g_hat
    total_true = sum(np.asarray(g) for g in gs)
    np.testing.assert_allclose(
        np.asarray(sent) + np.asarray(err), total_true, atol=1e-4
    )


# ---------------------------------------------------------------------------
# attention equivalence (the memory-efficient path is exact)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 50),
    sq=st.integers(4, 48),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    causal=st.booleans(),
)
def test_blockwise_equals_naive(seed, sq, heads, causal):
    H, Kv = heads
    D = 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (1, sq, H, D), jnp.float32)
    k = jax.random.normal(kk, (1, sq, Kv, D), jnp.float32)
    v = jax.random.normal(kv, (1, sq, Kv, D), jnp.float32)
    pos = jnp.arange(sq)
    got = blockwise_attention(q, k, v, q_pos=pos, kv_pos=pos,
                              causal=causal, q_block=8, kv_block=8)
    want = naive_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=causal)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# autoscaler invariant
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    rates=st.lists(st.integers(0, 50), min_size=5, max_size=40),
    q=st.floats(0.5, 5.0),
)
def test_autoscaler_within_bounds(rates, q):
    from repro.core.autoscaler import LoadAutoscaler

    a = LoadAutoscaler(q, min_replicas=1, max_replicas=10, window_s=30.0,
                       upscale_delay_s=30.0, downscale_delay_s=60.0)
    t = 0.0
    for r in rates:
        a.observe(t, r)
        n = a.target(t)
        assert 1 <= n <= 10
        t += 15.0

"""Token-level continuous-batching engine (repro.serving.token).

Covers the ISSUE-5 acceptance surface: the batch-1 reduction to the
request-level latency model, KV-budget admission invariants (hypothesis),
preemption-loses-KV re-prefill accounting, TTFT/TPOT/goodput metric
units, engine integration (legacy == vector in token mode), and the
spec/suite plumbing (serving: section, sweep.replica_models axis,
concurrency_cap satellite).
"""

import dataclasses

import numpy as np
import pytest

from repro.cluster.catalog import default_catalog
from repro.cluster.traces import synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.serving.engine import VectorizedServingEngine
from repro.serving.latency import LatencyModel
from repro.serving.replica import Replica
from repro.serving.sim import ServingSimulator
from repro.serving.token import (
    ContinuousBatch,
    TokenEngineConfig,
    TokenSchedulerConfig,
    TokenStats,
    UNBOUNDED_KV_TOKENS,
)
from repro.service import Service, spec_from_dict
from repro.workloads import make_workload
from repro.workloads.arrivals import Request

CAT = default_catalog()
CFG = get_config("llama3.2-1b")
ITYPE = CAT.instance_type("g5.48xlarge")
LM = LatencyModel.for_model(CFG, ITYPE)
ECFG = TokenEngineConfig.from_latency(LM)


def mk_batch(**knob_overrides) -> ContinuousBatch:
    knobs = TokenSchedulerConfig(**knob_overrides)
    return ContinuousBatch(TokenEngineConfig.from_latency(LM, knobs))


def _mini_trace(steps=180, seed=3):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


# ---------------------------------------------------------------------------
# physics: config derivation
# ---------------------------------------------------------------------------


def test_engine_config_matches_latency_model():
    """Decode floor == decode_s_per_token; prefill slope == prefill_s/P;
    KV budget shares max_concurrency's HBM arithmetic."""
    assert ECFG.weight_read_s == LM.decode_s_per_token()
    assert ECFG.prefill_s_per_token * 1000 == pytest.approx(
        LM.prefill_s(1000), rel=1e-12
    )
    # budget_tokens // context slots ~ max_concurrency (same free HBM)
    slots = 4096
    assert abs(ECFG.kv_budget_tokens // slots - LM.max_concurrency()) <= 1


def test_attention_free_arch_unbounded_kv():
    mamba = get_config("falcon-mamba-7b")
    lm = LatencyModel.for_model(mamba, ITYPE)
    ec = TokenEngineConfig.from_latency(lm)
    assert ec.kv_budget_tokens == UNBOUNDED_KV_TOKENS
    assert ec.kv_read_s_per_token == 0.0


# ---------------------------------------------------------------------------
# reduction property: batch 1 + unbounded KV == request-level service_s
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,o", [(1, 1), (200, 150), (2048, 512), (7, 900)])
def test_batch1_reduces_to_request_level_service_time(p, o):
    b = mk_batch()
    assert b.enqueue(0, p, o, arrival_s=0.0, enqueued_s=0.0)
    done = b.advance(1e9)
    assert len(done) == 1
    e2e = done[0].finish_s - done[0].arrival_s
    svc = LM.service_s(p, o)
    # the only extra over service_s is the (physically real) per-token KV
    # re-read; bound it by o iterations reading at most (p+o) tokens each
    kv_extra = o * ECFG.kv_read_s_per_token * (p + o)
    assert svc - 1e-9 <= e2e <= svc + kv_extra + 1e-9
    assert e2e == pytest.approx(svc, rel=0.05)


def test_batch1_component_equality():
    """Prefill and weight-read components match the roofline exactly."""
    p, o = 300, 100
    b = mk_batch()
    b.enqueue(0, p, o, 0.0, 0.0)
    c = b.advance(1e9)[0]
    ttft = c.first_token_s - c.arrival_s
    # TTFT = overhead + full prefill + one decode iteration (+ first KV read)
    expect = LM.overhead_s + LM.prefill_s(p) + LM.decode_s_per_token()
    assert ttft == pytest.approx(
        expect + ECFG.kv_read_s_per_token * p, abs=1e-12
    )
    # decode phase = (o-1) iterations at weight_read + growing KV reads
    decode = c.finish_s - c.first_token_s
    kv_sum = ECFG.kv_read_s_per_token * sum(p + i for i in range(1, o))
    assert decode == pytest.approx(
        (o - 1) * LM.decode_s_per_token() + kv_sum, rel=1e-9
    )


# ---------------------------------------------------------------------------
# batching physics
# ---------------------------------------------------------------------------


def test_weight_reads_amortize_across_batch():
    """A batch of n finishes in far less than n serial service times —
    the roofline replaces the old 1+0.15·running interference factor."""
    n, p, o = 8, 200, 150
    b = mk_batch()
    for i in range(n):
        b.enqueue(i, p, o, 0.0, 0.0)
    done = b.advance(1e9)
    assert len(done) == n
    makespan = max(c.finish_s for c in done)
    serial = n * LM.service_s(p, o)
    assert makespan < 0.25 * serial


def test_tpot_grows_with_resident_kv():
    """More resident KV tokens -> slower decode steps (per-seq KV reads)."""
    def tpot(n):
        b = mk_batch()
        for i in range(n):
            b.enqueue(i, 1024, 256, 0.0, 0.0)
        done = b.advance(1e9)
        return float(np.mean([
            (c.finish_s - c.first_token_s) / max(c.output_tokens - 1, 1)
            for c in done
        ]))
    assert tpot(32) > tpot(4) > tpot(1) >= ECFG.weight_read_s


def test_chunked_prefill_bounds_decode_stall():
    """A huge prompt joining mid-decode delays other sequences by at most
    ~chunk-sized prefill slices per iteration, not the whole prompt."""
    chunk = 256
    b = mk_batch(prefill_chunk_tokens=chunk)
    b.enqueue(0, 16, 400, 0.0, 0.0)
    b.advance(0.12)                      # seq 0 is decoding by now
    assert b._dec[0] > 0
    d0 = int(b._dec[0])
    t0 = b.now
    b.enqueue(1, 2048, 64, 0.1, 0.1)
    b.advance(t0 + 0.1)
    # seq 0 kept decoding while seq 1 prefilled in chunks
    gap = (b.now - t0) / max(int(b._dec[0]) - d0, 1)
    max_iter = (
        ECFG.iter_overhead_s + chunk * ECFG.prefill_s_per_token
        + ECFG.weight_read_s + ECFG.kv_read_s_per_token * 3000
    )
    assert gap <= max_iter + 1e-9
    assert int(b._dec[0]) - d0 >= 5


# ---------------------------------------------------------------------------
# KV admission invariants (seeded-random; hypothesis variants live in
# tests/test_token_property.py and run where hypothesis is installed)
# ---------------------------------------------------------------------------


def check_kv_admission_invariants(reqs, budget, max_batch):
    b = mk_batch(kv_budget_tokens=budget, max_batch=max_batch)
    t = 0.0
    n_accepted = 0
    completions = []
    for key, (p, o, gap) in enumerate(reqs):
        t += gap
        if b.enqueue(key, p, o, t, t):
            n_accepted += 1
        else:
            assert p + o > budget       # only oversize is refused
        completions += b.advance(t)
        # invariants after every scheduling step
        assert b.n_active <= max_batch
        assert b.reserved_tokens <= budget
        assert b.reserved_tokens == int(
            (b._prompt + b._out).sum()
        )
        assert b.kv_tokens <= b.reserved_tokens
    completions += b.advance(t + 1e7)
    # conservation: everything accepted either completed or is still held
    assert len(completions) + b.load == n_accepted
    assert b.load == 0                  # nothing can be stuck forever
    seen = {c.key for c in completions}
    assert len(seen) == len(completions)


@pytest.mark.parametrize("seed", range(12))
def test_kv_admission_invariants_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 30))
    reqs = [
        (int(rng.integers(1, 600)), int(rng.integers(1, 400)),
         float(rng.uniform(0.0, 50.0)))
        for _ in range(n)
    ]
    budget = int(rng.integers(800, 4000))
    max_batch = int(rng.integers(1, 7))
    check_kv_admission_invariants(reqs, budget, max_batch)


def check_clock_monotone(gaps):
    """advance(t) never runs an iteration past t, and time never reverses."""
    b = mk_batch()
    t = 0.0
    last = 0.0
    for k, gap in enumerate(gaps):
        t += gap
        b.enqueue(k, 50, 40, t, t)
        b.advance(t)
        assert b.now <= t + 1e-12
        assert b.now >= last - 1e-12
        last = b.now


@pytest.mark.parametrize("seed", range(6))
def test_clock_monotone_and_bounded_random(seed):
    rng = np.random.default_rng(100 + seed)
    check_clock_monotone(
        [float(g) for g in rng.uniform(0.0, 10.0, int(rng.integers(2, 20)))]
    )


# ---------------------------------------------------------------------------
# preemption: KV state is lost, retries re-prefill
# ---------------------------------------------------------------------------


def test_kill_reports_lost_kv_work():
    b = mk_batch()
    b.enqueue(0, 400, 300, 0.0, 0.0)
    b.enqueue(1, 100, 500, 0.0, 0.0)
    b.advance(0.1)                      # mid-decode
    assert b.n_active == 2              # nothing finished yet
    pref_done = int(b._pref.sum())
    dec_done = int(b._dec.sum())
    assert pref_done == 500 and dec_done > 0
    report = b.kill()
    assert set(report.keys) == {0, 1}
    assert report.n_batch == 2 and report.n_queued == 0
    assert report.lost_prefill_tokens == pref_done
    assert report.lost_decode_tokens == dec_done
    assert b.load == 0 and b.reserved_tokens == 0


def test_retry_pays_full_reprefill():
    """A request killed mid-decode re-prefills from token zero on the
    replica it retries on: its completion reflects both attempts."""
    p, o = 600, 2000
    b1 = mk_batch()
    b1.enqueue(0, p, o, 0.0, 0.0)
    b1.advance(0.4)
    assert int(b1._dec[0]) > 0          # decode underway, work to lose
    b1.kill()
    # retry on a fresh replica at t=0.4 (original arrival rides along)
    b2 = mk_batch()
    b2.enqueue(0, p, o, 0.0, 0.4)
    done = b2.advance(1e9)
    assert len(done) == 1
    e2e = done[0].finish_s - done[0].arrival_s
    # e2e >= wasted first attempt (0.4s) + one full service time
    assert e2e >= 0.4 + LM.service_s(p, o) - 1e-9


def test_simulator_aggregates_preemption_accounting():
    """End-to-end: preemptions on a churny trace surface as KV-loss
    counters in TokenStats, and retried requests complete."""
    tr = _mini_trace(steps=180, seed=3)
    reqs = make_workload("poisson", rate_per_s=0.8, seed=3).generate(
        2 * 3600.0
    )
    sim = ServingSimulator(
        tr, make_policy("spothedge"), reqs, CFG, itype="g5.48xlarge",
        autoscaler=ConstantTarget(3), timeout_s=60.0,
        replica_model="token",
    )
    res = sim.run(2 * 3600.0 + 600.0)
    assert res.n_preemptions > 0
    tok = res.token
    assert tok is not None
    assert tok.n_kv_preempted_seqs + tok.n_killed_queued > 0
    assert res.n_completed > 0.9 * len(reqs)


# ---------------------------------------------------------------------------
# metric units: TTFT / TPOT / goodput
# ---------------------------------------------------------------------------


def _token_run(slo_ttft=10.0, slo_tpot=0.2):
    tr = _mini_trace(steps=120, seed=21)
    reqs = make_workload("poisson", rate_per_s=0.5, seed=1).generate(3600.0)
    sim = ServingSimulator(
        tr, make_policy("spothedge"), reqs, CFG, itype="g5.48xlarge",
        autoscaler=ConstantTarget(2), timeout_s=60.0,
        replica_model="token",
        token_scheduler=TokenSchedulerConfig(
            slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot
        ),
    )
    return sim.run(3600.0 + 600.0)


def test_metric_units_and_bounds():
    res = _token_run()
    tok = res.token
    assert tok.n_recorded == res.n_completed == len(res.latencies_s)
    # TTFT: at least overhead + one decode step; at most the e2e latency
    assert float(tok.ttft_s.min()) >= LM.overhead_s + ECFG.weight_read_s
    assert (tok.ttft_s <= res.latencies_s.max() + 1e-9).all()
    # TPOT: bounded below by the amortized weight read; sane above
    assert float(tok.tpot_s.min()) >= ECFG.weight_read_s - 1e-12
    assert float(tok.tpot_s.max()) < 1.0
    # goodput accounting is internally consistent
    assert 0 <= tok.n_slo_ok <= tok.n_recorded
    assert tok.slo_attainment == pytest.approx(
        tok.n_slo_ok / tok.n_requests
    )
    assert tok.goodput_rps == pytest.approx(tok.n_slo_ok / 4200.0)
    assert sum(w["n_slo_ok"] for w in tok.windows) == tok.n_slo_ok
    assert sum(w["n_completed"] for w in tok.windows) == tok.n_recorded


def test_slo_targets_gate_goodput():
    lax = _token_run(slo_ttft=50.0, slo_tpot=1.0)
    strict = _token_run(slo_ttft=0.2, slo_tpot=0.0008)
    assert lax.token.n_slo_ok >= strict.token.n_slo_ok
    assert lax.token.slo_attainment > 0.9
    assert strict.token.slo_attainment < lax.token.slo_attainment


def test_stats_to_dict_parses():
    tok = _token_run().token
    d = tok.to_dict()
    assert d["n_recorded"] == tok.n_recorded
    assert d["ttft_p50_s"] is not None
    assert isinstance(d["windows"], list) and d["windows"]
    import json
    json.loads(json.dumps(d))           # JSON-safe


def test_empty_run_stats():
    stats = TokenStats.from_records(
        [], slo_ttft_s=1.0, slo_tpot_s=0.1, horizon_s=10.0,
        window_s=60.0, n_requests=0,
    )
    assert stats.n_recorded == 0 and stats.goodput_rps == 0.0
    assert np.isnan(stats.ttft_pct(50))


# ---------------------------------------------------------------------------
# engine integration: legacy == vector in token mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy,lb", [
    ("spothedge", None),
    ("even_spread", "rr"),
])
def test_token_mode_differential(policy, lb):
    from repro.serving.load_balancer import RoundRobinBalancer

    tr = _mini_trace(steps=150, seed=7)
    reqs = make_workload("poisson", rate_per_s=0.8, seed=7).generate(
        2 * 3600.0
    )
    results = []
    for cls in (ServingSimulator, VectorizedServingEngine):
        kwargs = dict(
            itype="g5.48xlarge", autoscaler=ConstantTarget(3),
            timeout_s=60.0, replica_model="token",
        )
        if lb == "rr":
            kwargs["lb"] = RoundRobinBalancer()
        sim = cls(tr, make_policy(policy), reqs, CFG, **kwargs)
        results.append(sim.run(2 * 3600.0 + 600.0))
    legacy, vector = results
    assert vector.n_requests == legacy.n_requests
    assert vector.n_completed == legacy.n_completed
    assert vector.n_failed == legacy.n_failed
    np.testing.assert_allclose(
        np.sort(vector.latencies_s), np.sort(legacy.latencies_s),
        atol=1e-9, rtol=0,
    )
    np.testing.assert_allclose(
        np.sort(vector.token.ttft_s), np.sort(legacy.token.ttft_s),
        atol=1e-9, rtol=0,
    )
    assert vector.token.n_slo_ok == legacy.token.n_slo_ok
    assert (vector.token.n_kv_preempted_seqs
            == legacy.token.n_kv_preempted_seqs)
    assert (vector.token.lost_prefill_tokens
            == legacy.token.lost_prefill_tokens)


# ---------------------------------------------------------------------------
# spec / suite plumbing
# ---------------------------------------------------------------------------


def _spec_dict(**over):
    d = {
        "name": "tok", "model": "llama3.2-1b", "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "replica_policy": {"name": "spothedge"},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 17},
        "sim": {"duration_hours": 1.0, "timeout_s": 60.0,
                "drain_s": 300.0},
    }
    d.update(over)
    return d


def test_serving_section_round_trip():
    d = _spec_dict(serving={
        "replica_model": "token",
        "slo": {"ttft_s": 2.5, "tpot_s": 0.01},
        "max_batch": 12, "prefill_chunk_tokens": 128,
    })
    spec = spec_from_dict(d)
    assert spec.sim.replica_model == "token"
    assert spec.serving.slo.ttft_s == 2.5
    assert spec.serving.max_batch == 12
    assert spec_from_dict(spec.to_dict()) == spec


def test_serving_replica_model_conflict_rejected():
    from repro.service import SpecError

    d = _spec_dict(serving={"replica_model": "token"})
    d["sim"]["replica_model"] = "request"
    with pytest.raises(SpecError, match="conflicts"):
        spec_from_dict(d)


def test_invalid_replica_model_rejected():
    from repro.service import SpecError

    d = _spec_dict()
    d["sim"]["replica_model"] = "per-token"
    with pytest.raises(SpecError, match="replica_model"):
        spec_from_dict(d)


def test_token_spec_attaches_stats_and_report_fields():
    from repro.experiments.report import CellResult

    d = _spec_dict(serving={"replica_model": "token"})
    res = Service(spec_from_dict(d)).run()
    assert res.token is not None and res.token.n_recorded > 0
    cell = CellResult.from_result({"policy": "spothedge"}, res, 0.1)
    out = cell.to_dict()
    assert out["goodput_rps"] is not None
    assert out["ttft_p50_s"] > 0
    # request-level cells keep the historical artifact shape
    res_req = Service(spec_from_dict(_spec_dict())).run()
    out_req = CellResult.from_result({"policy": "x"}, res_req, 0.1).to_dict()
    assert "goodput_rps" not in out_req and "ttft_p50_s" not in out_req


def test_sweep_replica_models_axis():
    d = _spec_dict(sweep={
        "policies": ["spothedge", "ondemand_only"],
        "replica_models": ["request", "token"],
    })
    from repro.experiments import ScenarioSuite

    suite = ScenarioSuite.from_spec(d)
    assert len(suite) == 4
    models = sorted(
        sc.labels["replica_model"] for sc in suite.scenarios
    )
    assert models == ["request", "request", "token", "token"]
    # same tape across the axis (fair comparison)
    keys = {sc.tape_key for sc in suite.scenarios}
    assert len(keys) == 1


def test_sweep_rejects_unknown_replica_model():
    from repro.service import SpecError

    d = _spec_dict(sweep={"replica_models": ["tokenz"]})
    with pytest.raises(SpecError, match="replica_models"):
        spec_from_dict(d)


# ---------------------------------------------------------------------------
# satellites: concurrency cap + eta residual
# ---------------------------------------------------------------------------


def test_concurrency_cap_lifted_to_spec():
    d = _spec_dict(serving={"concurrency_cap": 3})
    d["sim"]["concurrency"] = None
    from repro.service.builder import build_service

    sim = build_service(spec_from_dict(d)).simulator
    assert sim.concurrency == min(LM.max_concurrency(), 3) == 3
    # default preserves the historical min(max_concurrency, 16)
    d2 = _spec_dict()
    d2["sim"]["concurrency"] = None
    sim2 = build_service(spec_from_dict(d2)).simulator
    assert sim2.concurrency == min(LM.max_concurrency(), 16)


def test_eta_includes_residual_running_time():
    from repro.cluster.instance import Instance, InstanceKind

    z = CAT.zone("us-west-2a")
    inst = Instance(
        zone="us-west-2a", region=z.region, cloud=z.cloud,
        kind=InstanceKind.SPOT, itype="g5.48xlarge", hourly_price=4.9,
        launched_at=0.0, cold_start_s=183.0,
    )
    inst.step_to(200.0)
    rep = Replica(inst, LM, concurrency=1)
    rep.readiness_probe(200.0)
    probe = Request(arrival_s=200.0, prompt_tokens=50, output_tokens=50)
    idle_eta = rep.eta_if_submitted(probe, 200.0)
    # fill the only slot with a long request: ETA must now include its
    # residual service time even though the queue is empty
    rep.submit(Request(arrival_s=200.0, prompt_tokens=1000,
                       output_tokens=1000), 200.0)
    rep.step(200.0)
    assert len(rep.running) == 1 and not rep.queue
    busy_eta = rep.eta_if_submitted(probe, 200.0)
    residual = rep.running[0].finish_s - 200.0
    assert busy_eta == pytest.approx(idle_eta + residual, rel=1e-9)

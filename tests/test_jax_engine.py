"""Differential lockdown for the jit/vmap JAX scenario engine.

Every scenario runs the same trace / policy / tape / autoscaler / LB
through the NumPy oracle (``VectorizedServingEngine``) and the two-phase
JAX engine (``JaxServingEngine``) and asserts identical decisions:
request / completion / failure / retry counts, cost, and latency arrays
equal to 1e-6.  Scenarios cross the behavioral regimes — spot churn
with retries, round-robin vs least-loaded, autoscaler terminations,
saturation with queue expiry, cross-region RTT timeout boundaries,
token-model delegation, and the batched suite path.

Also pins the ``_workload_tape_key`` canonicalizer: stable across
process boundaries (the bug: ``json.dumps(default=repr)`` embedded
memory addresses), order-insensitive, type-strict.
"""

import dataclasses
import subprocess
import sys

import numpy as np
import pytest

from repro.cluster.traces import synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget, LoadAutoscaler
from repro.core.policy import make_policy
from repro.experiments.suite import (
    ScenarioSuite,
    _canonical_args,
    _workload_tape_key,
)
from repro.serving.engine import VectorizedServingEngine
from repro.serving.jaxengine import JaxServingEngine
from repro.serving.load_balancer import RoundRobinBalancer
from repro.service import Service, SpecError, spec_from_dict
from repro.workloads import make_workload

CFG = get_config("llama3.2-1b")


def _mini_trace(steps, seed):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


def _run_both(policy, workload, *, hours=1.0, seed=3, rate=0.8,
              autoscaler=None, lb_cls=None, timeout_s=60.0,
              concurrency=2, client_regions=None, replica_model="request"):
    """Run (vector, jax) on one scenario; identical inputs for both."""
    trace = _mini_trace(steps=int(hours * 60) + 60, seed=seed)
    rate_key = "rate_per_s" if workload == "poisson" else "base_rate_per_s"
    wargs = {rate_key: rate, "seed": seed}
    if client_regions is not None:
        wargs["client_regions"] = client_regions
    reqs = make_workload(workload, **wargs).generate(hours * 3600.0)
    out = []
    for cls in (VectorizedServingEngine, JaxServingEngine):
        kwargs = dict(
            itype="g5.48xlarge",
            autoscaler=autoscaler() if autoscaler else ConstantTarget(3),
            timeout_s=timeout_s,
            concurrency=concurrency,
            workload_name=workload,
            replica_model=replica_model,
        )
        if lb_cls is not None:
            kwargs["lb"] = lb_cls()
        sim = cls(trace, make_policy(policy), reqs, CFG, **kwargs)
        out.append(sim.run(hours * 3600.0 + 600.0))
    return out


def _assert_equivalent(vector, jx):
    assert jx.n_requests == vector.n_requests
    assert jx.n_completed == vector.n_completed
    assert jx.n_failed == vector.n_failed
    assert jx.n_preemptions == vector.n_preemptions
    assert jx.n_launch_failures == vector.n_launch_failures
    assert jx.n_retried_requests == vector.n_retried_requests
    assert jx.total_cost == pytest.approx(vector.total_cost, abs=1e-9)
    assert jx.availability == pytest.approx(vector.availability, abs=1e-12)
    lat_v = np.sort(vector.latencies_s)
    lat_j = np.sort(jx.latencies_s)
    assert len(lat_v) == len(lat_j)
    if len(lat_v):
        np.testing.assert_allclose(lat_j, lat_v, atol=1e-6, rtol=0)


# ---------------------------------------------------------------------------
# engine-level differentials
# ---------------------------------------------------------------------------


def test_spothedge_poisson_least_loaded():
    """Spot churn + preemption re-pends through the LL balancer."""
    vector, jx = _run_both("spothedge", "poisson", hours=2.0)
    assert vector.n_completed > 0
    _assert_equivalent(vector, jx)


def test_even_spread_arena_round_robin():
    """Bursty arrivals through the round-robin cursor."""
    vector, jx = _run_both(
        "even_spread", "arena", hours=2.0, lb_cls=RoundRobinBalancer
    )
    assert vector.n_completed > 0
    _assert_equivalent(vector, jx)


def test_aws_spot_maf_load_autoscaler():
    """Diurnal load: autoscaler-driven launches AND terminations (kill
    events on both the preempt and the policy-terminate window edge)."""
    vector, jx = _run_both(
        "aws_spot", "maf", hours=2.0,
        autoscaler=lambda: LoadAutoscaler(
            0.8, min_replicas=1, max_replicas=6, initial_target=2,
            upscale_delay_s=60.0, downscale_delay_s=300.0,
        ),
    )
    assert vector.n_completed > 0
    _assert_equivalent(vector, jx)


def test_saturated_queues_and_expiry():
    """Overload: deep queues, RTT-inclusive expiry, mid-queue stragglers
    from re-pended requests with original arrival times."""
    vector, jx = _run_both(
        "spothedge", "poisson", rate=6.0, concurrency=1,
        timeout_s=30.0, hours=1.0,
    )
    assert vector.n_failed > 0
    _assert_equivalent(vector, jx)


def test_cross_region_rtt_timeout_boundary():
    """Clients split across regions: the RTT term in the unified timeout
    (queue expiry AND completion deadline) must move the same requests
    across the boundary in both engines.  Sub-second timeout with ~70 ms
    cross-country RTTs makes the boundary load-bearing."""
    vector, jx = _run_both(
        "spothedge", "poisson", rate=2.0, hours=1.0, timeout_s=2.5,
        client_regions={"us-west-2": 0.5, "us-east-2": 0.3,
                        "eu-west-1": 0.2},
    )
    assert vector.n_failed > 0      # the boundary must actually bite
    _assert_equivalent(vector, jx)


def test_token_model_delegates_to_oracle():
    """``replica_model: token`` on the jax engine runs the oracle's
    continuous-batching data plane — exact equality, token stats intact."""
    vector, jx = _run_both(
        "spothedge", "poisson", hours=1.0, replica_model="token"
    )
    assert vector.n_completed > 0
    _assert_equivalent(vector, jx)
    assert jx.token is not None
    assert jx.token.n_recorded == vector.token.n_recorded
    assert jx.token.goodput_rps == pytest.approx(
        vector.token.goodput_rps, abs=1e-9
    )


def test_queue_overflow_falls_back_to_oracle():
    """A cell whose queue pool is too small must rerun on the oracle
    (exactness over speed), never drop work."""
    trace = _mini_trace(steps=120, seed=3)
    reqs = make_workload("poisson", rate_per_s=6.0, seed=3).generate(3600.0)
    vec = VectorizedServingEngine(
        trace, make_policy("spothedge"), reqs, CFG,
        itype="g5.48xlarge", autoscaler=ConstantTarget(3),
        timeout_s=30.0, concurrency=1,
    )
    jx = JaxServingEngine(
        trace, make_policy("spothedge"), reqs, CFG,
        itype="g5.48xlarge", autoscaler=ConstantTarget(3),
        timeout_s=30.0, concurrency=1,
    )
    jx.queue_capacity = 2           # force overflow under saturation
    _assert_equivalent(vec.run(4200.0), jx.run(4200.0))


# ---------------------------------------------------------------------------
# spec / suite plumbing
# ---------------------------------------------------------------------------


def _spec_dict(policy="spothedge", seed=0, engine="vector"):
    return {
        "name": f"jaxdiff-{policy}-{seed}",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "replica_policy": {"name": policy},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 17},
        "sim": {"duration_hours": 1.0, "timeout_s": 60.0,
                "concurrency": 2, "drain_s": 300.0, "seed": seed,
                "engine": engine},
    }


def test_engine_jax_via_service_spec():
    """``sim.engine: "jax"`` end to end through Service.run()."""
    res_v = Service(spec_from_dict(_spec_dict(engine="vector"))).run()
    res_j = Service(spec_from_dict(_spec_dict(engine="jax"))).run()
    _assert_equivalent(res_v, res_j)


def test_suite_matrix_batched_path_matches_vector():
    """``ScenarioSuite.run(engine="jax")`` batches the whole matrix into
    vmapped programs; every cell metric must match the vector path."""
    spec = spec_from_dict({
        **_spec_dict(),
        "sweep": {"policies": ["spothedge", "even_spread"],
                  "seeds": [0, 1]},
    })
    suite = ScenarioSuite.from_spec(spec)
    rep_v = suite.run(engine="vector")
    rep_j = suite.run(engine="jax")
    assert rep_j.engine == "jax"
    assert len(rep_j.cells) == len(rep_v.cells) == 4
    for cv, cj in zip(rep_v.cells, rep_j.cells):
        assert cj.labels == cv.labels
        assert cj.n_requests == cv.n_requests
        assert cj.n_completed == cv.n_completed
        assert cj.n_failed == cv.n_failed
        assert cj.n_preemptions == cv.n_preemptions
        assert cj.total_cost == pytest.approx(cv.total_cost, abs=1e-9)
        assert cj.p50_s == pytest.approx(cv.p50_s, abs=1e-6)
        assert cj.p99_s == pytest.approx(cv.p99_s, abs=1e-6)


# ---------------------------------------------------------------------------
# tape-key canonicalizer regressions
# ---------------------------------------------------------------------------


def test_tape_key_order_insensitive_and_type_strict():
    a = _canonical_args({"regions": {"us-west-2": 0.5, "us-east-2": 0.5},
                         "burst": [1, 2, 3]})
    b = _canonical_args({"burst": [1, 2, 3],
                         "regions": {"us-east-2": 0.5, "us-west-2": 0.5}})
    assert a == b and hash(a) == hash(b)
    # True == 1 under tuple equality; tape keys must distinguish them
    assert _canonical_args({"flag": True}) != _canonical_args({"flag": 1})


def test_tape_key_rejects_unstable_values():
    class Opaque:
        pass

    with pytest.raises(SpecError, match="cannot canonicalize"):
        _canonical_args({"x": Opaque()})
    with pytest.raises(SpecError, match="not a string"):
        _canonical_args({1: "a"})
    # the old default=repr fallback would have happily embedded the
    # object's memory address here — different key every process


_KEY_SCRIPT = """
import sys
from repro.experiments.suite import _workload_tape_key
from repro.service import spec_from_dict

spec = spec_from_dict({
    "name": "stab", "model": "llama3.2-1b", "trace": "aws-1",
    "resources": {"instance_type": "g5.48xlarge"},
    "autoscaler": {"kind": "constant", "target": 2},
    "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 7,
                 "args": {"client_regions": {"us-west-2": 0.6,
                                             "us-east-2": 0.4}}},
    "sim": {"duration_hours": 1.0, "drain_s": 300.0},
})
sys.stdout.write(repr(_workload_tape_key(spec)))
"""


def test_tape_key_stable_across_process_boundaries():
    """The regression the canonicalizer fixes: keys computed in freshly
    spawned interpreters (different hash seeds, different heap layouts)
    must be identical, or spawn-started suite workers stop sharing
    tapes.  The old repr-based key embedded ``object.__repr__`` memory
    addresses and failed exactly this check."""
    keys = set()
    for hashseed in ("0", "1", "31337"):
        proc = subprocess.run(
            [sys.executable, "-c", _KEY_SCRIPT],
            capture_output=True, text=True, timeout=120,
            env={
                "PYTHONPATH": "src",
                "PYTHONHASHSEED": hashseed,
                "PATH": "/usr/bin:/bin:/usr/local/bin",
            },
            cwd="/root/repo" if sys.path else None,
        )
        assert proc.returncode == 0, proc.stderr
        keys.add(proc.stdout)
    assert len(keys) == 1, f"tape key unstable across processes: {keys}"


def test_tape_key_matches_in_process_value():
    """Subprocess keys equal the parent's (not just each other)."""
    spec = spec_from_dict({
        "name": "stab", "model": "llama3.2-1b", "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 2},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 7,
                     "args": {"client_regions": {"us-west-2": 0.6,
                                                 "us-east-2": 0.4}}},
        "sim": {"duration_hours": 1.0, "drain_s": 300.0},
    })
    proc = subprocess.run(
        [sys.executable, "-c", _KEY_SCRIPT],
        capture_output=True, text=True, timeout=120,
        env={
            "PYTHONPATH": "src",
            "PYTHONHASHSEED": "1729",
            "PATH": "/usr/bin:/bin:/usr/local/bin",
        },
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout == repr(_workload_tape_key(spec))

"""Cluster simulator: FSM, billing, trace replay, policy comparisons."""

import numpy as np
import pytest

from repro.cluster.catalog import default_catalog
from repro.cluster.instance import Instance, InstanceKind, InstanceState
from repro.cluster.simulator import (
    ClusterSimulator,
    SimConfig,
    run_policy_on_trace,
)
from repro.cluster.traces import SpotTrace, synth_correlated_trace
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy


def mini_trace(steps=200, cap=4):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(
        zones, zmap, steps=steps, dt=60.0, max_capacity=cap, seed=11,
        name="mini",
    )


# ---------------------------------------------------------------------------
# Instance FSM
# ---------------------------------------------------------------------------


def test_instance_lifecycle_and_billing():
    inst = Instance(
        zone="us-west-2a", region="us-west-2", cloud="aws",
        kind=InstanceKind.SPOT, itype="p3.2xlarge", hourly_price=1.0,
        launched_at=0.0, cold_start_s=183.0,
    )
    assert inst.state is InstanceState.PROVISIONING
    inst.step_to(100.0)
    assert not inst.is_ready()
    inst.step_to(183.0)
    assert inst.is_ready()
    # billed from launch INCLUDING provisioning (§2.3)
    assert inst.cost(3600.0) == pytest.approx(1.0)
    inst.preempt(3600.0)
    assert inst.state is InstanceState.PREEMPTED
    assert inst.cost(7200.0) == pytest.approx(1.0)   # billing stopped


def test_ondemand_never_preempted():
    inst = Instance(
        zone="z", region="r", cloud="aws", kind=InstanceKind.ON_DEMAND,
        itype="p3.2xlarge", hourly_price=3.0, launched_at=0.0,
        cold_start_s=10.0,
    )
    with pytest.raises(ValueError):
        inst.preempt(5.0)


# ---------------------------------------------------------------------------
# Simulator mechanics
# ---------------------------------------------------------------------------


def test_spot_launch_respects_capacity():
    tr = SpotTrace(
        zones=("us-west-2a",), cap=np.array([[1]] * 10), dt=60.0,
    )
    sim = ClusterSimulator(
        tr, make_policy("even_spread"), autoscaler=ConstantTarget(3),
        config=SimConfig(control_interval_s=60.0),
    )
    res = sim.run(600.0)
    # capacity 1: only one spot can ever be active
    assert max(res.ready_spot.max(), 0) <= 1
    assert res.n_launch_failures > 0


def test_capacity_drop_preempts():
    cap = np.array([[3]] * 5 + [[0]] * 5)
    tr = SpotTrace(zones=("us-west-2a",), cap=cap, dt=60.0)
    sim = ClusterSimulator(
        tr, make_policy("even_spread"), autoscaler=ConstantTarget(3),
        config=SimConfig(control_interval_s=60.0, cold_start_s=60.0),
    )
    res = sim.run(600.0)
    assert res.n_preemptions == 3
    assert res.ready_spot[-1] == 0


def test_ondemand_only_full_availability():
    tr = mini_trace()
    res = run_policy_on_trace(
        "ondemand_only", tr, n_target=4, control_interval_s=60.0
    )
    # only the initial cold start can be unavailable
    assert res.availability > 0.97
    assert res.cost_vs_ondemand == pytest.approx(1.0, abs=0.08)
    assert res.n_preemptions == 0


def test_spothedge_beats_baselines_on_availability():
    tr = mini_trace(steps=800)
    rs = {
        name: run_policy_on_trace(
            name, tr, n_target=4, control_interval_s=30.0
        )
        for name in ("spothedge", "even_spread", "round_robin")
    }
    assert rs["spothedge"].availability > rs["round_robin"].availability
    assert rs["round_robin"].availability >= rs["even_spread"].availability
    assert rs["spothedge"].availability > 0.9


def test_spothedge_cheaper_than_ondemand():
    tr = mini_trace(steps=800)
    res = run_policy_on_trace(
        "spothedge", tr, n_target=4, control_interval_s=30.0
    )
    assert res.cost_vs_ondemand < 0.8


def test_preempt_listener_fires():
    cap = np.array([[2]] * 5 + [[0]] * 5)
    tr = SpotTrace(zones=("us-west-2a",), cap=cap, dt=60.0)
    sim = ClusterSimulator(
        tr, make_policy("even_spread"), autoscaler=ConstantTarget(2),
        config=SimConfig(control_interval_s=60.0, cold_start_s=30.0),
    )
    seen = []
    sim.add_preempt_listener(lambda inst, t: seen.append(inst.id))
    sim.run(600.0)
    assert len(seen) == 2


def test_series_recorded():
    tr = mini_trace()
    res = run_policy_on_trace("spothedge", tr, n_target=2,
                              control_interval_s=60.0)
    assert len(res.t) == len(res.ready_spot) == len(res.ready_od)
    assert len(res.t) > 0

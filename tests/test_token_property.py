"""Hypothesis property tests for the token engine's scheduling core.

The same invariant checkers as ``tests/test_token_engine.py`` (which runs
them on a fixed seeded sample everywhere), driven here by hypothesis
search where the ``property`` extra is installed (CI).
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_token_engine import (  # noqa: E402
    check_clock_monotone,
    check_kv_admission_invariants,
)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(1, 600),        # prompt
            st.integers(1, 400),        # output
            st.floats(0.0, 50.0),       # enqueue gap
        ),
        min_size=1, max_size=30,
    ),
    st.integers(800, 4000),             # kv budget
    st.integers(1, 6),                  # max batch
)
def test_kv_admission_invariants(reqs, budget, max_batch):
    check_kv_admission_invariants(reqs, budget, max_batch)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 10.0), min_size=2, max_size=20))
def test_clock_monotone_and_bounded(gaps):
    check_clock_monotone(gaps)

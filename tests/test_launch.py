"""Launch layer: step builders, input specs, HLO counting, mesh helpers."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.analysis import Roofline, model_flops_for
from repro.launch.hlo_count import analyze_hlo
from repro.launch.mesh import data_axis_size, make_host_mesh, mesh_chip_count
from repro.launch.steps import build_step, input_specs


def test_host_mesh():
    mesh = make_host_mesh()
    assert set(mesh.axis_names) == {"data", "model"}
    assert mesh_chip_count(mesh) >= 1
    assert data_axis_size(mesh) >= 1


@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_build_step_shapes(shape_name):
    """Abstract args carry the assigned shapes; shardings mirror args."""
    mesh = make_host_mesh()
    built = build_step("llama3.2-1b", shape_name, mesh)
    spec = SHAPES[shape_name]
    flat_args = jax.tree_util.tree_leaves(built.abstract_args)
    flat_shard = jax.tree_util.tree_leaves(built.in_shardings)
    assert len(flat_args) == len(flat_shard)
    if spec.kind == "train":
        params, opt, batch = built.abstract_args
        assert batch["tokens"].shape == (spec.global_batch, spec.seq_len)
    elif spec.kind == "prefill":
        tokens = built.abstract_args[1]
        assert tokens.shape == (spec.global_batch, spec.seq_len)
    else:  # decode
        tokens = built.abstract_args[1]
        assert tokens.shape == (spec.global_batch, 1)
        cache = built.abstract_args[2]
        assert cache["kv"]["k"].shape[2] == spec.seq_len  # cache slots
        assert built.donate == (2,)


def test_input_specs_no_allocation():
    """input_specs returns ShapeDtypeStructs only (no device buffers)."""
    mesh = make_host_mesh()
    args = input_specs("qwen2.5-3b", "decode_32k", mesh)
    for leaf in jax.tree_util.tree_leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_swa_cache_is_ring_sized():
    mesh = make_host_mesh()
    built = build_step("h2o-danube3-4b", "decode_32k", mesh)
    cache = built.abstract_args[2]
    cfg = get_config("h2o-danube3-4b")
    assert cache["kv"]["k"].shape[2] == cfg.sliding_window


def test_ssm_decode_has_o1_state():
    """long_500k for mamba carries O(1) state, not a 500k KV cache."""
    mesh = make_host_mesh()
    built = build_step("falcon-mamba-7b", "long_500k", mesh)
    cache = built.abstract_args[2]
    assert "kv" not in cache
    assert cache["ssm_state"]["ssm"].shape[-1] == 16   # d_state, not seq


def test_model_flops_scaling():
    cfg = get_config("llama3.2-1b")
    f_train = model_flops_for(cfg, SHAPES["train_4k"])
    f_dec = model_flops_for(cfg, SHAPES["decode_32k"])
    # 6·N·(B·S) vs 2·N·B
    ratio = f_train / f_dec
    assert ratio == pytest.approx(3 * 4096 * 256 / 128, rel=0.01)


def test_moe_active_params_flops():
    cfg = get_config("qwen3-moe-30b")
    f = model_flops_for(cfg, SHAPES["decode_32k"])
    # active ~3.3B of 30.5B total: 2 * N_active * 128
    n_active = f / (2 * 128)
    assert 2e9 < n_active < 6e9


def test_hlo_count_loop_scaling():
    def body(x, w):
        return x @ w, None

    W = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    X = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = jax.jit(
        lambda x, ws: jax.lax.scan(body, x, ws)[0]
    ).lower(X, W).compile()
    k = analyze_hlo(c.as_text())
    assert k.flops == 4 * 2 * 8 * 64 * 64     # trip count × dot flops


def test_roofline_terms():
    r = Roofline(
        arch="a", shape="s", mesh_desc="m", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9, collective_link_bytes=50e9,
        model_flops=197e12 * 256,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.step_time_s == pytest.approx(1.0)
    assert r.useful_flops_fraction == pytest.approx(1.0)
    assert r.mfu == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory", "collective")

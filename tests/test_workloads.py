"""Workload generators (Poisson / Arena / MAF)."""

import numpy as np

from repro.workloads import make_workload
from repro.workloads.arrivals import interarrival_stats


def test_poisson_rate():
    wl = make_workload("poisson", rate_per_s=0.5, seed=1)
    reqs = wl.generate(20_000.0)
    rate = len(reqs) / 20_000.0
    assert abs(rate - 0.5) < 0.05


def test_poisson_sorted_and_bounded():
    reqs = make_workload("poisson", rate_per_s=1.0, seed=2).generate(500.0)
    times = [r.arrival_s for r in reqs]
    assert times == sorted(times)
    assert all(0 <= t < 500.0 for t in times)
    assert all(r.prompt_tokens >= 1 and r.output_tokens >= 1 for r in reqs)


def test_arena_burstier_than_poisson():
    """Fig. 11: Arena interarrivals have higher CV than Poisson (CV=1)."""
    dur = 100_000.0
    arena = make_workload("arena", base_rate_per_s=0.5, seed=3).generate(dur)
    poisson = make_workload("poisson", rate_per_s=0.5, seed=3).generate(dur)
    cv_a = interarrival_stats(arena)["cv"]
    cv_p = interarrival_stats(poisson)["cv"]
    assert cv_a > cv_p
    assert cv_a > 1.1


def test_maf_diurnal():
    wl = make_workload("maf", base_rate_per_s=0.5, seed=4)
    reqs = wl.generate(86_400.0)
    times = np.array([r.arrival_s for r in reqs])
    # compare midnight-ish vs midday-ish rates
    night = ((times > 0) & (times < 3 * 3600)).sum()
    day = ((times > 11 * 3600) & (times < 14 * 3600)).sum()
    assert day > 1.5 * night


def test_determinism():
    a = make_workload("arena", seed=9).generate(5_000.0)
    b = make_workload("arena", seed=9).generate(5_000.0)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]


def test_unique_ids():
    reqs = make_workload("poisson", rate_per_s=1.0, seed=5).generate(100.0)
    ids = [r.id for r in reqs]
    assert len(set(ids)) == len(ids)

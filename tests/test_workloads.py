"""Workload generators (Poisson / Arena / MAF) + client-region mixtures."""

import numpy as np
import pytest

from repro.workloads import make_workload
from repro.workloads.arrivals import Request, interarrival_stats


def test_poisson_rate():
    wl = make_workload("poisson", rate_per_s=0.5, seed=1)
    reqs = wl.generate(20_000.0)
    rate = len(reqs) / 20_000.0
    assert abs(rate - 0.5) < 0.05


def test_poisson_sorted_and_bounded():
    reqs = make_workload("poisson", rate_per_s=1.0, seed=2).generate(500.0)
    times = [r.arrival_s for r in reqs]
    assert times == sorted(times)
    assert all(0 <= t < 500.0 for t in times)
    assert all(r.prompt_tokens >= 1 and r.output_tokens >= 1 for r in reqs)


def test_arena_burstier_than_poisson():
    """Fig. 11: Arena interarrivals have higher CV than Poisson (CV=1)."""
    dur = 100_000.0
    arena = make_workload("arena", base_rate_per_s=0.5, seed=3).generate(dur)
    poisson = make_workload("poisson", rate_per_s=0.5, seed=3).generate(dur)
    cv_a = interarrival_stats(arena)["cv"]
    cv_p = interarrival_stats(poisson)["cv"]
    assert cv_a > cv_p
    assert cv_a > 1.1


def test_maf_diurnal():
    wl = make_workload("maf", base_rate_per_s=0.5, seed=4)
    reqs = wl.generate(86_400.0)
    times = np.array([r.arrival_s for r in reqs])
    # compare midnight-ish vs midday-ish rates
    night = ((times > 0) & (times < 3 * 3600)).sum()
    day = ((times > 11 * 3600) & (times < 14 * 3600)).sum()
    assert day > 1.5 * night


def test_determinism():
    a = make_workload("arena", seed=9).generate(5_000.0)
    b = make_workload("arena", seed=9).generate(5_000.0)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]


def test_unique_ids():
    reqs = make_workload("poisson", rate_per_s=1.0, seed=5).generate(100.0)
    ids = [r.id for r in reqs]
    assert len(set(ids)) == len(ids)


# ---------------------------------------------------------------------------
# client-region mixtures
# ---------------------------------------------------------------------------


def test_default_single_region_unchanged():
    reqs = make_workload("poisson", rate_per_s=1.0, seed=5).generate(600.0)
    assert all(r.client_region == "us-west-2" for r in reqs)


@pytest.mark.parametrize("kind", ["poisson", "arena", "maf"])
def test_client_regions_mixture(kind):
    mix = {"us-west-2": 0.5, "eu-central-1": 0.3, "ap-northeast-1": 0.2}
    rate = {"poisson": {"rate_per_s": 1.0}}.get(
        kind, {"base_rate_per_s": 1.0}
    )
    reqs = make_workload(
        kind, seed=5, client_regions=mix, **rate
    ).generate(3600.0)
    seen = {r.client_region for r in reqs}
    assert seen == set(mix)
    # roughly proportional draws (binomial slack)
    frac = sum(r.client_region == "us-west-2" for r in reqs) / len(reqs)
    assert 0.4 < frac < 0.6


def test_client_regions_do_not_perturb_arrivals():
    """The mixture uses its own RNG stream: arrival times and token
    lengths are bit-identical with and without it."""
    base = make_workload("poisson", rate_per_s=1.0, seed=7).generate(3600.0)
    mix = make_workload(
        "poisson", rate_per_s=1.0, seed=7,
        client_regions=["us-west-2", "eu-central-1"],
    ).generate(3600.0)
    assert [r.arrival_s for r in base] == [r.arrival_s for r in mix]
    assert [r.prompt_tokens for r in base] == [r.prompt_tokens for r in mix]
    assert [r.output_tokens for r in base] == [r.output_tokens for r in mix]


def test_client_regions_seeded():
    kw = dict(rate_per_s=1.0, seed=11,
              client_regions={"us-west-2": 0.7, "us-east-1": 0.3})
    a = make_workload("poisson", **kw).generate(1800.0)
    b = make_workload("poisson", **kw).generate(1800.0)
    assert [r.client_region for r in a] == [r.client_region for r in b]


def test_client_regions_validation():
    with pytest.raises(ValueError):
        make_workload("poisson", client_regions={})
    with pytest.raises(ValueError):
        make_workload("poisson", client_regions={"": 1.0})
    with pytest.raises(ValueError):
        make_workload("poisson", client_regions={"us-west-2": -1.0})


def test_client_regions_exercise_rtt_in_lb():
    """Cross-region clients see the RTT term in their e2e latency."""
    from repro.cluster.catalog import default_catalog, region_rtt_ms
    from repro.cluster.instance import Instance, InstanceKind
    from repro.configs import get_config
    from repro.serving.latency import LatencyModel
    from repro.serving.load_balancer import LoadBalancer
    from repro.serving.replica import Replica

    cat = default_catalog()
    z = cat.zone("us-west-2a")
    inst = Instance(
        zone=z.name, region=z.region, cloud=z.cloud,
        kind=InstanceKind.SPOT, itype="g5.48xlarge", hourly_price=4.9,
        launched_at=0.0, cold_start_s=183.0,
    )
    lm = LatencyModel.for_model(
        get_config("llama3.2-1b"), cat.instance_type("g5.48xlarge")
    )
    rep = Replica(inst, lm, concurrency=2)
    far = Request(arrival_s=0.0, prompt_tokens=10, output_tokens=10,
                  client_region="eu-central-1")
    near = Request(arrival_s=0.0, prompt_tokens=10, output_tokens=10,
                   client_region="us-west-2")
    assert LoadBalancer.rtt_s(far, rep) == pytest.approx(
        region_rtt_ms("eu-central-1", "us-west-2") / 1e3
    )
    assert LoadBalancer.rtt_s(far, rep) > LoadBalancer.rtt_s(near, rep)

"""Request-span lockdown: tiling invariant, engine byte-identity,
JAX reconstruction parity, and the SLO burn-rate alert.

``check_span_tiling`` is the core invariant — every sampled request's
segments tile the interval from arrival to last close contiguously
(every close *is* the next open) regardless of which taps fired in
which order.  It is checked on fixed seeded engine runs (request mode
and token+migration mode), on a seeded random tap driver, and driven
by hypothesis search where the ``property`` extra is installed (CI),
mirroring the repo's other property suites.

The byte-identity and parity tests pin the PR's tracing contract:

* legacy ``ServingSimulator`` and ``VectorizedServingEngine`` produce
  byte-identical span JSONL on the fixed token+migration scenario;
* ``JaxServingEngine``'s host-side reconstruction matches the vector
  spans byte-for-byte after filtering to completion-resolved
  single-attempt requests (the kernel records the final attempt only);
* the multi-window burn-rate monitor alerts on a pinned scenario whose
  SLO targets are unattainable.
"""

import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.cluster.traces import synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.migration.config import MigrationSpec
from repro.obs import ObsRecorder, dumps_jsonl
from repro.obs.slo import SLOBurnConfig
from repro.obs.spans import SpanCollector, span_sampled
from repro.serving.engine import VectorizedServingEngine
from repro.serving.jaxengine import JaxServingEngine
from repro.serving.sim import ServingSimulator
from repro.serving.token import TokenSchedulerConfig
from repro.workloads import make_workload

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

CFG = get_config("llama3.2-1b")
HOURS = 1.0


def _mini_trace(steps=int(HOURS * 60) + 60, seed=3):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


def _run(cls, *, replica_model="request", migration=None,
         trace_sample=1.0, slo_burn=None, token_scheduler=None):
    trace = _mini_trace()
    reqs = make_workload("poisson", rate_per_s=0.8, seed=3).generate(
        HOURS * 3600.0
    )
    kw = {}
    if token_scheduler is not None:
        kw["token_scheduler"] = token_scheduler
    sim = cls(
        trace, make_policy("spothedge"), reqs, CFG,
        itype="g5.48xlarge", autoscaler=ConstantTarget(3),
        timeout_s=60.0, concurrency=2, workload_name="poisson",
        replica_model=replica_model, migration=migration,
        obs=ObsRecorder(detail="full", trace_sample=trace_sample,
                        slo_burn=slo_burn),
        **kw,
    )
    return sim.run(HOURS * 3600.0 + 600.0)


# ---------------------------------------------------------------------------
# the tiling invariant


def check_span_tiling(records):
    """Every span record tiles [arrival, last close] contiguously."""
    assert records == sorted(records, key=lambda r: r["ordinal"])
    for rec in records:
        assert rec["schema"] == 1 and rec["event"] == "span"
        assert rec["attempts"] >= 1
        assert rec["outcome"] in (
            "ok", "timeout", "rejected", "unresolved"
        )
        segs = rec["segments"]
        assert segs, rec
        assert segs[0]["t0_s"] == rec["arrival_s"], rec
        prev_end = None
        for seg in segs:
            assert seg["t1_s"] >= seg["t0_s"], rec
            if prev_end is not None:
                assert seg["t0_s"] == prev_end, rec
            prev_end = seg["t1_s"]


#: tap language of the random driver (arbitrary call orders must
#: preserve tiling — out-of-protocol calls are no-ops by construction)
_OPS = (
    "dispatch", "start", "finish", "expire", "reject", "preempt",
    "token_join", "token_chunk", "token_prefill_done", "finish_token",
    "migrate", "migrate_arrive",
)


def drive_collector(ops):
    """Replay (op_code, dt) pairs into a one-request collector and
    check the tiling invariant on whatever comes out."""
    col = SpanCollector(1.0, [SimpleNamespace(id=0, arrival_s=0.0)])
    t = 0.0
    for code, dt in ops:
        t += dt
        op = _OPS[code % len(_OPS)]
        if op == "dispatch":
            col.dispatch(0, t, 1, 0.01, 0.0, token=bool(code % 2))
        elif op == "start":
            col.start(0, t)
        elif op == "finish":
            col.finish(0, t, "ok", t)
        elif op == "expire":
            col.expire(0, t, 0.0)
        elif op == "reject":
            col.reject(0, t)
        elif op == "preempt":
            col.preempt(0, t)
        elif op == "token_join":
            col.token_join(0, t, prefilling=bool(code % 2))
        elif op == "token_chunk":
            col.token_chunk(0, 7)
        elif op == "token_prefill_done":
            col.token_prefill_done(0, t)
        elif op == "finish_token":
            col.finish_token(0, t, t, 0.0, "ok", t)
        elif op == "migrate":
            col.migrate(0, t, to_replica=2, transfer_s=0.5, plan_t=t)
        elif op == "migrate_arrive":
            col.migrate_arrive(0, t, replica=2)
    col.finalize(t + 1.0)
    recs = col.records()
    check_span_tiling(recs)
    return recs


def test_span_tiling_driver_fixed_sample():
    rng = np.random.default_rng(0)
    for _ in range(100):
        n = int(rng.integers(0, 40))
        ops = [
            (int(rng.integers(0, len(_OPS))), float(rng.uniform(0, 30)))
            for _ in range(n)
        ]
        drive_collector(ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_span_tiling_hypothesis():
    @settings(max_examples=80, deadline=None)
    @given(st.lists(
        st.tuples(
            st.integers(0, len(_OPS) - 1),
            st.floats(0.0, 30.0, allow_nan=False,
                      allow_infinity=False),
        ),
        max_size=40,
    ))
    def prop(ops):
        drive_collector(ops)

    prop()


def test_span_sampled_deterministic():
    assert not any(span_sampled(o, 0.0) for o in range(1000))
    assert all(span_sampled(o, 1.0) for o in range(1000))
    picks = [span_sampled(o, 0.25) for o in range(4000)]
    assert picks == [span_sampled(o, 0.25) for o in range(4000)]
    frac = sum(picks) / len(picks)
    assert 0.15 < frac < 0.35


# ---------------------------------------------------------------------------
# engine runs: tiling + byte identity


@pytest.fixture(scope="module")
def token_migration_runs():
    spec = MigrationSpec(enabled=True, drain_threshold_s=2.0)
    legacy = _run(ServingSimulator, replica_model="token",
                  migration=spec)
    vector = _run(VectorizedServingEngine, replica_model="token",
                  migration=spec)
    return legacy, vector


def test_span_tiling_token_migration(token_migration_runs):
    _, vector = token_migration_runs
    recs = vector.obs.span_records()
    assert recs
    check_span_tiling(recs)
    kinds = {s["name"] for r in recs for s in r["segments"]}
    assert {"queue", "admit", "prefill", "decode"} <= kinds
    if vector.token.n_migrated_seqs:
        assert "transfer" in kinds


def test_span_bytes_identical_token_migration(token_migration_runs):
    legacy, vector = token_migration_runs
    a = dumps_jsonl(legacy.obs.span_records())
    b = dumps_jsonl(vector.obs.span_records())
    assert a and a == b


def test_sampling_subset_matches_hash(token_migration_runs):
    del token_migration_runs   # ordering only; this run is cheap
    res = _run(VectorizedServingEngine, trace_sample=0.25)
    recs = res.obs.span_records()
    assert recs
    assert all(span_sampled(r["ordinal"], 0.25) for r in recs)


# ---------------------------------------------------------------------------
# jax reconstruction parity


def test_jax_span_parity_request_mode():
    vector = _run(VectorizedServingEngine)
    jaxr = _run(JaxServingEngine)
    sv = vector.obs.span_records()
    sj = jaxr.obs.span_records()
    assert sv and sj
    check_span_tiling(sv)
    check_span_tiling(sj)

    def served(r):
        return any(s["name"] == "service" for s in r["segments"])

    want = {
        r["ordinal"]: r for r in sv
        if r["attempts"] == 1 and served(r)
        and r["outcome"] in ("ok", "timeout")
    }
    got = {r["ordinal"]: r for r in sj}
    # the kernel resolves spans exactly for completion-scattered
    # requests; this fixture retries none of them, so the filtered
    # vector set and the jax set coincide ordinal-for-ordinal
    assert set(got) == set(want)
    for o, rec in want.items():
        assert json.dumps(got[o], sort_keys=True) == \
            json.dumps(rec, sort_keys=True)
    # headline metrics stay oracle-equal with tracing on
    assert jaxr.n_completed == vector.n_completed
    assert jaxr.n_failed == vector.n_failed


# ---------------------------------------------------------------------------
# burn-rate alert


def test_burn_alert_fires_pinned():
    res = _run(
        VectorizedServingEngine, replica_model="token",
        slo_burn=SLOBurnConfig(),   # SRE-workbook defaults
        token_scheduler=TokenSchedulerConfig(
            slo_ttft_s=0.2, slo_tpot_s=0.0008
        ),
    )
    burns = [e.to_record() for e in res.obs.events
             if e.KIND == "slo_burn"]
    assert burns
    alerting = [r for r in burns if r.get("alerting")]
    assert alerting, "unattainable SLO targets must trip the alert"
    names = {n for r in alerting for n in r["alerting"]}
    assert names & {"ttft", "tpot"}
    summ = res.obs.slo_burn_summary()
    assert summ is not None
    assert summ["alert_windows"] == len(alerting)
    assert summ["windows"] == len(burns)

"""Lockdown for repro.obs: events, registry, exporters, engine parity.

The heavy fixtures run one fixed scenario (the differential-test mini
trace) through all three engines at observability detail ``full`` and
pin:

* **byte identity** — legacy ``ServingSimulator`` and
  ``VectorizedServingEngine`` serialize to byte-identical JSONL
  (request mode *and* token+migration mode);
* **JAX parity** — ``JaxServingEngine``'s phase-A replay reproduces the
  control-plane stream exactly (the vector stream minus data-plane
  records);
* **golden counts** — per-kind event totals for the fixed seed, so an
  emit-site regression (dropped or doubled events) fails loudly;
* **zero observation cost** — detail ``off`` vs ``full`` leaves every
  ``ServingResult`` metric identical (recording is pure observation).

Plus unit coverage for the run-scoped ``MetricsRegistry`` (the
``FALLBACK_COUNTS`` replacement), the exporters (JSONL round-trip,
Perfetto-loadable Chrome trace), the attribution report, the
``observability:`` spec section, and the ``python -m repro.obs`` CLI.
"""

import json

import numpy as np
import pytest

from repro.cluster.traces import synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.experiments.report import CellResult
from repro.migration.config import MigrationSpec
from repro.obs import (
    MetricsRegistry,
    ObsRecorder,
    attribution_report,
    chrome_trace,
    control_plane_records,
    diff_summaries,
    dumps_jsonl,
    get_registry,
    read_jsonl,
    summarize,
    use_registry,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.__main__ import main as obs_main
from repro.serving.engine import VectorizedServingEngine
from repro.serving.jaxengine import JaxServingEngine
from repro.serving.sim import ServingSimulator
from repro.service import Service, SpecError, spec_from_dict
from repro.workloads import make_workload

CFG = get_config("llama3.2-1b")
HOURS = 2.0

# per-kind event totals for the fixed fixture below (detail "full");
# a changed emit site shows up here before it reaches the goldens
GOLDEN_COUNTS = {
    "autoscaler_target": 1,
    "decision": 498,
    "launch_failure": 478,
    "lifecycle": 40,
    "slo_burn": 130,
    "warning": 14,
    "window": 130,
}


def _mini_trace(steps=int(HOURS * 60) + 60, seed=3):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


def _run(cls, *, detail="full", replica_model="request", migration=None,
         hours=HOURS):
    trace = _mini_trace(steps=int(hours * 60) + 60)
    reqs = make_workload("poisson", rate_per_s=0.8, seed=3).generate(
        hours * 3600.0
    )
    sim = cls(
        trace, make_policy("spothedge"), reqs, CFG,
        itype="g5.48xlarge", autoscaler=ConstantTarget(3),
        timeout_s=60.0, concurrency=2, workload_name="poisson",
        replica_model=replica_model, migration=migration,
        obs=ObsRecorder(detail=detail),
    )
    return sim.run(hours * 3600.0 + 600.0)


@pytest.fixture(scope="module")
def three_runs():
    """(legacy, vector, jax) results for the fixed request-mode scenario."""
    return (
        _run(ServingSimulator),
        _run(VectorizedServingEngine),
        _run(JaxServingEngine),
    )


# ---------------------------------------------------------------------------
# metrics registry


def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    assert not reg
    reg.inc("launches", zone="us-west-2a")
    reg.inc("launches", 2, zone="us-west-2a")
    reg.gauge("target", 3)
    reg.observe("latency_s", 1.0)
    reg.observe("latency_s", 3.0)
    assert reg
    assert reg.counter("launches", zone="us-west-2a") == 3
    assert reg.counter("launches", zone="nowhere") == 0
    snap = reg.snapshot()
    assert snap["counters"] == {"launches{zone=us-west-2a}": 3}
    assert snap["gauges"] == {"target": 3}
    h = snap["histograms"]["latency_s"]
    assert h == {"count": 2, "sum": 4.0, "min": 1.0, "max": 3.0}


def test_registry_label_order_is_canonical():
    reg = MetricsRegistry()
    reg.inc("x", a=1, b=2)
    reg.inc("x", b=2, a=1)
    assert reg.snapshot()["counters"] == {"x{a=1,b=2}": 2}


def test_merge_snapshots_adds_counters_and_histograms():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("n", 2)
    b.inc("n", 3)
    a.observe("h", 1.0)
    b.observe("h", 5.0)
    a.gauge("g", 1)
    b.gauge("g", 9)
    merged = MetricsRegistry.merge_snapshots(
        [a.snapshot(), None, {}, b.snapshot()]
    )
    assert merged["counters"] == {"n": 5}
    assert merged["gauges"] == {"g": 9}           # last write wins
    assert merged["histograms"]["h"] == {
        "count": 2, "sum": 6.0, "min": 1.0, "max": 5.0,
    }
    assert MetricsRegistry.merge_snapshots([]) == {}


def test_use_registry_scoping_and_nesting():
    outer, inner = MetricsRegistry(), MetricsRegistry()
    default = get_registry()
    with use_registry(outer):
        get_registry().inc("k")
        with use_registry(inner):
            get_registry().inc("k")
        get_registry().inc("k")
    assert get_registry() is default
    assert outer.counter("k") == 2
    assert inner.counter("k") == 1


def test_latency_profile_fallback_is_run_scoped():
    """The old FALLBACK_COUNTS module global bled across runs; the
    registry counter lands on whichever run is active."""
    from repro.cluster.catalog import default_catalog
    from repro.serving.latency import make_latency_model

    itype = default_catalog().instance_type("g5.48xlarge")
    runs = [MetricsRegistry(), MetricsRegistry()]
    for reg in runs:
        with use_registry(reg), pytest.warns(UserWarning):
            make_latency_model(
                CFG, itype, model_id="no-such-model", source="profile",
                profile="does/not/exist.json",
            )
    for reg in runs:
        assert reg.counter(
            "latency_profile_fallback",
            model="no-such-model", accelerator=itype.accelerator,
        ) == 1


# ---------------------------------------------------------------------------
# recorder


def test_recorder_rejects_bad_config():
    with pytest.raises(ValueError):
        ObsRecorder(detail="verbose")
    with pytest.raises(ValueError):
        ObsRecorder(window_s=0.0)


def test_recorder_replica_ordinals_are_dense_and_stable():
    obs = ObsRecorder()
    assert obs.replica_ordinal(1234) == 0
    assert obs.replica_ordinal(99) == 1
    assert obs.replica_ordinal(1234) == 0
    fresh = obs.fresh()
    assert fresh.detail == obs.detail
    assert fresh.window_s == obs.window_s
    assert fresh.events == []
    assert fresh.replica_ordinal(99) == 0       # fresh map too


# ---------------------------------------------------------------------------
# engine parity (the tentpole contract)


def test_legacy_vector_jsonl_byte_identical(three_runs):
    legacy, vector, _ = three_runs
    a = dumps_jsonl(legacy.obs.events)
    b = dumps_jsonl(vector.obs.events)
    assert a == b
    assert len(a.splitlines()) == sum(GOLDEN_COUNTS.values())


def test_golden_event_counts(three_runs):
    legacy, vector, jx = three_runs
    assert legacy.obs.event_counts() == GOLDEN_COUNTS
    assert vector.obs.event_counts() == GOLDEN_COUNTS
    # jax phase-A replays the control plane; no data-plane windows and
    # hence no per-window burn-rate events either
    assert jx.obs.event_counts() == {
        k: v for k, v in GOLDEN_COUNTS.items()
        if k not in ("window", "slo_burn")
    }


def test_jax_matches_vector_control_plane(three_runs):
    _, vector, jx = three_runs
    want = control_plane_records(vector.obs.records())
    assert dumps_jsonl(jx.obs.records()) == dumps_jsonl(want)


def test_decisions_carry_reasons_and_replica_links(three_runs):
    _, vector, _ = three_runs
    decisions = [r for r in vector.obs.records() if r["event"] == "decision"]
    launches = [d for d in decisions if d["action"].startswith("launch")]
    assert launches
    assert any(d.get("reason") for d in decisions)
    # every successful launch links the replica it produced, and that
    # replica's provision event precedes the decision record
    provisioned = {
        r["instance_id"] for r in vector.obs.records()
        if r["event"] == "lifecycle" and r["phase"] == "provision"
    }
    linked = [d["instance_id"] for d in launches if "instance_id" in d]
    assert linked and set(linked) <= provisioned


def test_detail_off_and_full_are_metric_identical():
    off = _run(VectorizedServingEngine, detail="off", hours=1.0)
    full = _run(VectorizedServingEngine, detail="full", hours=1.0)
    assert off.obs is None and off.metrics is None
    assert full.obs is not None and full.obs.events
    assert off.n_requests == full.n_requests
    assert off.n_completed == full.n_completed
    assert off.n_failed == full.n_failed
    assert off.n_preemptions == full.n_preemptions
    assert off.total_cost == full.total_cost
    np.testing.assert_array_equal(
        np.sort(off.latencies_s), np.sort(full.latencies_s)
    )


def test_token_migration_byte_identical():
    spec = MigrationSpec(enabled=True, drain_threshold_s=2.0)
    legacy = _run(ServingSimulator, replica_model="token",
                  migration=spec, hours=1.0)
    vector = _run(VectorizedServingEngine, replica_model="token",
                  migration=spec, hours=1.0)
    assert dumps_jsonl(legacy.obs.events) == dumps_jsonl(vector.obs.events)
    counts = vector.obs.event_counts()
    assert counts.get("migration_plan", 0) > 0


# ---------------------------------------------------------------------------
# exporters


def test_jsonl_roundtrip(tmp_path, three_runs):
    _, vector, _ = three_runs
    path = write_jsonl(vector.obs.events, str(tmp_path / "run.jsonl"))
    records = read_jsonl(path)
    # compare serialized: JSON turns reason tuples into lists
    assert dumps_jsonl(records) == dumps_jsonl(vector.obs.events)
    assert all(r["schema"] == 1 for r in records)


def test_chrome_trace_roundtrip(tmp_path, three_runs):
    _, vector, _ = three_runs
    path = write_chrome_trace(
        vector.obs.events, str(tmp_path / "run.trace.json")
    )
    with open(path) as f:
        trace = json.load(f)
    events = trace["traceEvents"]
    assert trace["otherData"]["schema"] == 1
    phases = {e["ph"] for e in events}
    assert {"M", "X", "i", "C"} <= phases        # spans, markers, counters
    # every complete span is well-formed
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0
    assert json.dumps(
        chrome_trace(vector.obs.records()), sort_keys=True
    ) == json.dumps(trace, sort_keys=True)


def test_summarize_and_diff(three_runs):
    _, vector, jx = three_runs
    s = summarize(vector.obs.records())
    assert s["n_events"] == sum(GOLDEN_COUNTS.values())
    assert s["event_counts"] == GOLDEN_COUNTS
    assert s["decisions"]                        # per-action breakdown
    same = diff_summaries(vector.obs.records(), vector.obs.records())
    assert same["identical"]
    diff = diff_summaries(vector.obs.records(), jx.obs.records())
    assert not diff["identical"]
    assert diff["event_counts"]["window"]["delta"] == -GOLDEN_COUNTS["window"]


def test_attribution_report(three_runs):
    _, vector, _ = three_runs
    rep = attribution_report(vector.obs.records(), top=5)
    assert rep["n_decisions"] == GOLDEN_COUNTS["decision"]
    assert rep["n_replicas"] > 0
    assert rep["total_cost_usd"] == pytest.approx(
        sum(a["cost_usd"] for a in rep["cost_by_action"].values())
    )
    assert len(rep["top_decisions"]) == 5
    tops = [d["cost_usd"] for d in rep["top_decisions"]]
    assert tops == sorted(tops, reverse=True)


def test_cli_smoke(tmp_path, three_runs, capsys):
    _, vector, jx = three_runs
    a = write_jsonl(vector.obs.events, str(tmp_path / "a.jsonl"))
    b = write_jsonl(jx.obs.records(), str(tmp_path / "b.jsonl"))
    assert obs_main(["summarize", a]) == 0
    capsys.readouterr()                         # drop the text output
    assert obs_main(["summarize", a, "--json"]) == 0
    out = capsys.readouterr().out
    assert json.loads(out)["n_events"] == sum(GOLDEN_COUNTS.values())
    assert obs_main(["diff", a, a]) == 0        # identical → exit 0
    assert obs_main(["diff", a, b]) == 1        # different → exit 1
    assert obs_main(["attribute", a, "--top", "3"]) == 0
    trace_out = str(tmp_path / "a.trace.json")
    assert obs_main(["trace", a, "-o", trace_out]) == 0
    with open(trace_out) as f:
        assert json.load(f)["traceEvents"]


# ---------------------------------------------------------------------------
# spec / service / report plumbing


def _spec_dict(**obs):
    d = {
        "name": "obs-smoke",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 2},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 7},
        "sim": {"duration_hours": 0.5, "timeout_s": 60.0,
                "concurrency": 2},
    }
    if obs:
        d["observability"] = obs
    return d


def test_observability_spec_defaults_and_validation():
    spec = spec_from_dict(_spec_dict())
    assert spec.observability.detail == "decisions"
    assert spec.observability.window_s == 60.0
    spec = spec_from_dict(_spec_dict(detail="full", window_s=30.0))
    assert spec.observability.detail == "full"
    assert spec.to_dict()["observability"]["window_s"] == 30.0
    with pytest.raises(SpecError):
        spec_from_dict(_spec_dict(detail="everything"))
    with pytest.raises(SpecError):
        spec_from_dict(_spec_dict(window_s=0))
    with pytest.raises(SpecError):
        spec_from_dict(_spec_dict(verbosity=3))   # unknown key


def test_service_exports_artifacts_at_full_detail(tmp_path):
    svc = Service(_spec_dict(detail="full", out_dir=str(tmp_path)))
    res = svc.run()
    assert res.obs is not None
    assert set(svc.artifacts) == {"events", "spans", "trace"}
    assert dumps_jsonl(read_jsonl(svc.artifacts["events"])) \
        == dumps_jsonl(res.obs.records())
    assert dumps_jsonl(read_jsonl(svc.artifacts["spans"])) \
        == dumps_jsonl(res.obs.span_records())
    with open(svc.artifacts["trace"]) as f:
        assert json.load(f)["traceEvents"]
    status = svc.status()
    assert status["obs_event_counts"] == res.obs.event_counts()
    assert status["obs_artifacts"] == svc.artifacts


def test_service_default_detail_writes_nothing(tmp_path):
    svc = Service(_spec_dict(out_dir=str(tmp_path)))
    res = svc.run()
    assert res.obs is not None                  # decisions recorded…
    assert svc.artifacts == {}                  # …but no artifacts
    assert list(tmp_path.iterdir()) == []


def test_cell_result_carries_obs_snapshots(three_runs):
    _, vector, _ = three_runs
    cell = CellResult.from_result({"policy": "spothedge"}, vector, 0.1)
    assert cell.obs_event_counts == GOLDEN_COUNTS
    assert cell.obs_windows is not None
    assert len(cell.obs_windows) == GOLDEN_COUNTS["window"]
    d = cell.to_dict()
    assert d["obs_event_counts"] == GOLDEN_COUNTS

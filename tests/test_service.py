"""Tests for the declarative service API (spec / loader / builder /
Service) and the typed controller contract."""

import dataclasses
import typing

import numpy as np
import pytest

from repro.cluster.simulator import SimConfig
from repro.cluster.traces import load_trace, synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import (
    Action,
    ControllerEvent,
    EventKind,
    LaunchOnDemand,
    LaunchSpot,
    Policy,
    Terminate,
    make_policy,
)
from repro.serving.load_balancer import LeastLoadedBalancer
from repro.serving.sim import ServingSimulator
from repro.service import (
    PlacementFilter,
    ReplicaPolicySpec,
    ResourceSpec,
    Service,
    ServiceSpec,
    SpecError,
    build_service,
    resolve_zones,
    spec_from_dict,
    spec_from_json,
)
from repro.workloads import make_workload


# ---------------------------------------------------------------------------
# spec round-trips
# ---------------------------------------------------------------------------


def test_default_spec_roundtrip():
    spec = ServiceSpec()
    assert spec_from_dict(spec.to_dict()) == spec


def test_full_spec_roundtrip():
    spec = spec_from_dict({
        "name": "svc",
        "model": "command-r-35b",
        "trace": "aws-3",
        "resources": {
            "instance_type": "g5.48xlarge",
            "any_of": [{"region": "us-west-2"}, {"cloud": "gcp"}],
            "exclude_zones": ["us-west-2c"],
        },
        "replica_policy": {
            "name": "spothedge",
            "overprovision": 3,
            "dynamic_fallback": False,
            "args": {"warning_ttl_s": 60.0},
        },
        "autoscaler": {"kind": "load", "target": 6,
                       "qps_per_replica": 1.5},
        "workload": {"kind": "arena", "rate_per_s": 2.0, "seed": 9},
        "sim": {"duration_hours": 1.5, "cold_start_s": 90.0},
        "load_balancer": "round_robin",
    })
    again = spec_from_dict(spec.to_dict())
    assert again == spec
    assert again.resources.any_of[0].region == "us-west-2"
    assert again.replica_policy.policy_kwargs() == {
        "num_overprovision": 3,
        "dynamic_ondemand_fallback": False,
        "warning_ttl_s": 60.0,
    }


def test_spec_from_json_text_and_listing_wrapper():
    spec = spec_from_json(
        '{"service": {"name": "j", "model": "llama3.2-1b",'
        ' "trace": "gcp-1"}}'
    )
    assert spec.name == "j"
    assert spec.trace == "gcp-1"


def test_spec_from_yaml_text():
    yaml = pytest.importorskip("yaml")  # noqa: F841
    from repro.service import spec_from_yaml

    spec = spec_from_yaml(
        "service:\n"
        "  name: y\n"
        "  model: llama3.2-1b\n"
        "  trace: aws-1\n"
        "  resources:\n"
        "    instance_type: p3.2xlarge\n"
        "    any_of:\n"
        "      - region: us-west-2\n"
    )
    assert spec.name == "y"
    assert spec.resources.any_of == (PlacementFilter(region="us-west-2"),)


# ---------------------------------------------------------------------------
# validation errors are actionable
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "overrides, fragment",
    [
        ({"replica_policy": {"name": "not_a_policy"}}, "registered policies"),
        ({"resources": {"any_of": []}}, "any_of is empty"),
        ({"workload": {"rate_per_s": -0.5}}, "must be positive"),
        ({"workload": {"kind": "bogus"}}, "workload.kind"),
        ({"autoscaler": {"kind": "magic"}}, "autoscaler.kind"),
        ({"autoscaler": {"min_replicas": 5, "max_replicas": 2}},
         "min_replicas <= max_replicas"),
        ({"model": "gpt-17"}, "unknown model"),
        ({"trace": "azure-9"}, "unknown trace"),
        ({"resources": {"instance_type": "q9.mega"}}, "instance_type"),
        ({"sim": {"duration_hours": -1}}, "duration_hours"),
        ({"sim": {"drain_s": -600.0}}, "drain_s"),
        ({"load_balancer": "random"}, "load_balancer"),
        ({"typo_key": 1}, "unknown keys"),
        ({"replica_policy": {"overprovision": -1}}, "overprovision"),
    ],
)
def test_validation_errors(overrides, fragment):
    with pytest.raises(SpecError, match=fragment):
        spec_from_dict(overrides)


def test_duration_shorter_than_drain_is_spec_error():
    spec = spec_from_dict({
        "workload": {"kind": "poisson", "rate_per_s": 1.0},
        "sim": {"duration_hours": 0.1},     # 360 s < default drain 600 s
    })
    with pytest.raises(SpecError, match="drain_s"):
        build_service(spec, trace=_tiny_trace())


def test_policy_kwarg_mismatch_is_spec_error():
    # round_robin takes no knobs; overprovision must fail loudly at build
    spec = spec_from_dict({
        "replica_policy": {"name": "round_robin", "overprovision": 2},
        "workload": {"kind": "none"},
    })
    with pytest.raises(SpecError, match="rejected its knobs"):
        build_service(spec)


# ---------------------------------------------------------------------------
# zone resolution (any_of)
# ---------------------------------------------------------------------------


def test_resolve_zones_filters_trace():
    from repro.cluster.catalog import default_catalog

    trace = load_trace("aws-3")
    cat = default_catalog()
    res = ResourceSpec(
        any_of=(PlacementFilter(region="us-west-2"),),
        exclude_zones=("us-west-2c",),
    )
    assert resolve_zones(res, trace, cat) == ["us-west-2a", "us-west-2b"]
    with pytest.raises(SpecError, match="matches no zone"):
        resolve_zones(
            ResourceSpec(any_of=(PlacementFilter(cloud="azure"),)),
            trace, cat,
        )


# ---------------------------------------------------------------------------
# builder smoke: Service reproduces a hand-assembled simulator exactly
# ---------------------------------------------------------------------------


def _tiny_trace():
    zones = ["us-west-2a", "us-west-2b", "us-east-1a"]
    return synth_correlated_trace(
        zones, {z: z[:-1] for z in zones},
        steps=120, dt=60.0, max_capacity=3, seed=9, name="tiny",
    )


def test_service_run_matches_hand_assembled_defaults():
    trace = _tiny_trace()
    duration = 1800.0
    spec = spec_from_dict({
        "name": "smoke",
        "model": "llama3.2-1b",
        "trace": "aws-1",            # overridden by the tiny trace below
        "resources": {"instance_type": "p3.2xlarge"},
        "replica_policy": {"name": "spothedge"},
        "autoscaler": {"kind": "constant", "target": 2},
        "workload": {"kind": "poisson", "rate_per_s": 0.4, "seed": 2},
        "sim": {"duration_hours": duration / 3600.0,
                "control_interval_s": 15.0, "timeout_s": 100.0,
                "concurrency": 4},
    })
    got = Service(spec, trace=trace).run()

    # the same run, hand-wired the way launch/serve.py used to do it
    reqs = make_workload("poisson", rate_per_s=0.4, seed=2).generate(
        duration - 600.0
    )
    sim = ServingSimulator(
        trace, make_policy("spothedge"), reqs, get_config("llama3.2-1b"),
        itype="p3.2xlarge",
        autoscaler=ConstantTarget(2),
        lb=LeastLoadedBalancer(),
        sim_config=SimConfig(itype="p3.2xlarge", cold_start_s=183.0,
                             control_interval_s=15.0, seed=0),
        timeout_s=100.0, sub_step_s=1.0, workload_name="poisson",
        concurrency=4,
    )
    want = sim.run(duration)

    assert got.n_requests == want.n_requests
    assert got.n_completed == want.n_completed
    assert got.n_failed == want.n_failed
    assert got.availability == want.availability
    assert got.n_preemptions == want.n_preemptions
    np.testing.assert_allclose(got.total_cost, want.total_cost)
    np.testing.assert_allclose(
        np.sort(got.latencies_s), np.sort(want.latencies_s)
    )


def test_service_rerun_is_deterministic():
    spec = spec_from_dict({
        "workload": {"kind": "none"},
        "autoscaler": {"kind": "constant", "target": 2},
        "sim": {"duration_hours": 0.5, "control_interval_s": 30.0},
    })
    trace = _tiny_trace()
    svc = Service(spec, trace=trace)
    a, b = svc.run(), svc.run()          # fresh simulator per run
    assert a.availability == b.availability
    assert a.total_cost == b.total_cost


def test_status_progression():
    spec = spec_from_dict({
        "workload": {"kind": "none"},
        "sim": {"duration_hours": 0.25, "control_interval_s": 30.0},
    })
    svc = Service(spec, trace=_tiny_trace())
    assert svc.status()["state"] == "declared"
    svc.resolve()
    st = svc.status()
    assert st["state"] == "resolved"
    assert st["zones"] == ["us-west-2a", "us-west-2b", "us-east-1a"]
    svc.run()
    st = svc.status()
    assert st["state"] == "finished"
    assert 0.0 <= st["availability"] <= 1.0
    assert st["n_events"] >= 0


# ---------------------------------------------------------------------------
# typed controller contract
# ---------------------------------------------------------------------------


def test_action_is_a_real_union():
    assert set(typing.get_args(Action)) == {
        LaunchSpot, LaunchOnDemand, Terminate
    }


def test_on_event_dispatches_to_hooks():
    seen = []

    class Probe(Policy):
        name = "probe"

        def on_preemption(self, zone, now):
            seen.append(("preempt", zone, now))

        def on_warning(self, zone, now):
            seen.append(("warn", zone, now))

        def decide(self, obs):
            return []

    p = Probe()
    p.on_event(ControllerEvent(EventKind.PREEMPTION, "us-west-2a", 30.0,
                               instance_id=7))
    p.on_event(ControllerEvent(EventKind.WARNING, "us-east-1a", 60.0))
    p.on_event(ControllerEvent(EventKind.LAUNCH_FAILURE, "us-west-2b", 90.0))
    assert seen == [("preempt", "us-west-2a", 30.0),
                    ("warn", "us-east-1a", 60.0)]
    # the base LAUNCH_FAILURE hook records the cooldown
    assert not p._cooled("us-west-2b", 100.0)


def test_cluster_simulator_logs_events():
    spec = spec_from_dict({
        "workload": {"kind": "none"},
        "autoscaler": {"kind": "constant", "target": 3},
        "sim": {"duration_hours": 1.0, "control_interval_s": 30.0},
    })
    resolved = build_service(spec, trace=_tiny_trace())
    resolved.simulator.run(3600.0)
    events = resolved.simulator.cluster.events
    assert events, "an hour against a volatile trace must produce events"
    assert all(isinstance(e, ControllerEvent) for e in events)
    assert any(e.kind is EventKind.READY for e in events)
    ready = next(e for e in events if e.kind is EventKind.READY)
    assert ready.instance_id is not None


# ---------------------------------------------------------------------------
# SimConfig sharing regression (satellite fix)
# ---------------------------------------------------------------------------


def test_serving_sim_does_not_mutate_shared_sim_config():
    shared = SimConfig(itype="p3.2xlarge", control_interval_s=30.0)
    trace = _tiny_trace()
    reqs = make_workload("poisson", rate_per_s=0.2, seed=1).generate(300.0)
    ServingSimulator(
        trace, make_policy("spothedge"), reqs, get_config("llama3.2-1b"),
        itype="g5.48xlarge", sim_config=shared,
    )
    assert shared.itype == "p3.2xlarge"

"""Differential tests: the vectorized engine is decision-for-decision
equivalent to the legacy per-request ServingSimulator.

Each scenario runs the same trace / policy / request tape / autoscaler /
LB through both engines and asserts identical completion, failure and
preemption counts, identical cost, and (sorted) latency arrays equal to
1e-6 — the lockdown the ISSUE's vectorization rests on.  Scenarios are
chosen to cross the behavioral regimes: multi-zone spot churn, round-robin
vs least-loaded balancing, load autoscaling with terminations, saturation
with queue expiry, and an on-demand-only fleet.
"""

import numpy as np
import pytest

from repro.cluster.traces import synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget, LoadAutoscaler
from repro.core.policy import make_policy
from repro.serving.engine import VectorizedServingEngine
from repro.serving.load_balancer import RoundRobinBalancer
from repro.serving.sim import ServingSimulator
from repro.workloads import make_workload

CFG = get_config("llama3.2-1b")


def _mini_trace(steps, seed):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


def _run_both(policy, workload, *, hours=2.0, seed=3, rate=0.8,
              autoscaler=None, lb_cls=None, timeout_s=60.0,
              concurrency=2):
    trace = _mini_trace(steps=int(hours * 60) + 60, seed=seed)
    rate_key = "rate_per_s" if workload == "poisson" else "base_rate_per_s"
    reqs = make_workload(workload, **{rate_key: rate}, seed=seed).generate(
        hours * 3600.0
    )
    results = []
    for cls in (ServingSimulator, VectorizedServingEngine):
        kwargs = dict(
            itype="g5.48xlarge",
            autoscaler=autoscaler() if autoscaler else ConstantTarget(3),
            timeout_s=timeout_s,
            concurrency=concurrency,
            workload_name=workload,
        )
        if lb_cls is not None:
            kwargs["lb"] = lb_cls()
        sim = cls(trace, make_policy(policy), reqs, CFG, **kwargs)
        results.append(sim.run(hours * 3600.0 + 600.0))
    return results


def _assert_equivalent(legacy, vector):
    assert vector.n_requests == legacy.n_requests
    assert vector.n_completed == legacy.n_completed
    assert vector.n_failed == legacy.n_failed
    assert vector.n_preemptions == legacy.n_preemptions
    assert vector.n_launch_failures == legacy.n_launch_failures
    assert vector.total_cost == pytest.approx(legacy.total_cost, abs=1e-9)
    assert vector.availability == pytest.approx(
        legacy.availability, abs=1e-12
    )
    lat_l = np.sort(legacy.latencies_s)
    lat_v = np.sort(vector.latencies_s)
    assert len(lat_l) == len(lat_v)
    if len(lat_l):
        np.testing.assert_allclose(lat_v, lat_l, atol=1e-6, rtol=0)


def test_spothedge_poisson_least_loaded():
    """Spot churn + retries through the least-loaded balancer."""
    legacy, vector = _run_both("spothedge", "poisson")
    assert legacy.n_completed > 0
    _assert_equivalent(legacy, vector)


def test_even_spread_arena_round_robin():
    """Bursty arrivals through the round-robin balancer."""
    legacy, vector = _run_both(
        "even_spread", "arena", lb_cls=RoundRobinBalancer
    )
    assert legacy.n_completed > 0
    _assert_equivalent(legacy, vector)


def test_aws_spot_maf_load_autoscaler():
    """Diurnal load + autoscaler-driven launches AND terminations."""
    legacy, vector = _run_both(
        "aws_spot", "maf",
        autoscaler=lambda: LoadAutoscaler(
            0.8, min_replicas=1, max_replicas=6, initial_target=2,
            upscale_delay_s=60.0, downscale_delay_s=300.0,
        ),
    )
    assert legacy.n_completed > 0
    _assert_equivalent(legacy, vector)


def test_ondemand_only_stable_fleet():
    """No preemptions; exercises the steady immediate-start fast path."""
    legacy, vector = _run_both("ondemand_only", "poisson")
    assert legacy.n_preemptions == 0
    _assert_equivalent(legacy, vector)


def test_saturated_queues_and_expiry():
    """Overload: deep queues, client-timeout expiry, request failures."""
    legacy, vector = _run_both(
        "spothedge", "poisson", rate=6.0, concurrency=1,
        timeout_s=30.0, hours=1.0,
    )
    assert legacy.n_failed > 0          # saturation must actually occur
    _assert_equivalent(legacy, vector)


def test_engine_via_service_spec_matches_legacy():
    """The spec-level engine switch drives the same equivalence."""
    import dataclasses

    from repro.service import Service, spec_from_dict

    spec = spec_from_dict({
        "name": "diff", "model": "llama3.2-1b", "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 2},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 11},
        "sim": {"duration_hours": 1.0, "timeout_s": 60.0,
                "concurrency": 2, "drain_s": 300.0},
    })
    res_v = Service(spec).run()
    spec_l = dataclasses.replace(
        spec, sim=dataclasses.replace(spec.sim, engine="legacy")
    )
    res_l = Service(spec_l).run()
    _assert_equivalent(res_l, res_v)

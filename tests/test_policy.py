"""SpotHedge policy unit tests (Alg. 1 semantics + Dynamic Fallback)."""

import pytest

from repro.cluster.catalog import default_catalog
from repro.cluster.instance import Instance, InstanceKind
from repro.core.policy import (
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Terminate,
    make_policy,
    registered_policies,
)
from repro.core.spothedge import SpotHedgePolicy


CAT = default_catalog()
ZONES = CAT.zones_in_region("us-west-2") + CAT.zones_in_region("us-east-2")
ITYPE = "p3.2xlarge"


def mk_policy(**kw) -> SpotHedgePolicy:
    p = SpotHedgePolicy(**kw)
    p.reset(ZONES, CAT, ITYPE)
    return p


def mk_inst(zone: str, kind=InstanceKind.SPOT, t=0.0, ready=True,
            itype=ITYPE) -> Instance:
    z = CAT.zone(zone)
    inst = Instance(
        zone=zone, region=z.region, cloud=z.cloud, kind=kind, itype=itype,
        hourly_price=1.0, launched_at=t, cold_start_s=183.0,
    )
    if ready:
        inst.step_to(t + 1000.0)
    return inst


def obs(now=0.0, n_target=4, spot_ready=(), spot_prov=(), od_ready=(),
        od_prov=()):
    return Observation(
        now=now, n_target=n_target,
        spot_ready=list(spot_ready), spot_provisioning=list(spot_prov),
        od_ready=list(od_ready), od_provisioning=list(od_prov),
    )


# ---------------------------------------------------------------------------
# Alg. 1: Dynamic Placement
# ---------------------------------------------------------------------------


def test_initial_za_is_all_zones():
    p = mk_policy()
    assert set(p.available_zones) == {z.name for z in ZONES}
    assert p.preempting_zones == []


def test_preemption_moves_zone_to_zp():
    p = mk_policy()
    p.on_preemption("us-west-2a", 10.0)
    assert "us-west-2a" in p.preempting_zones
    assert "us-west-2a" not in p.available_zones


def test_ready_moves_zone_back_to_za():
    p = mk_policy()
    p.on_preemption("us-west-2a", 10.0)
    p.on_ready("us-west-2a", 400.0)
    assert "us-west-2a" in p.available_zones


def test_launch_failure_also_moves_to_zp():
    p = mk_policy()
    p.on_launch_failure("us-west-2b", 5.0)
    assert "us-west-2b" in p.preempting_zones


def test_rebalance_when_za_below_two():
    """Alg. 1 line 7-9: |Z_A| < 2 recycles Z_P into Z_A."""
    p = mk_policy()
    names = [z.name for z in ZONES]
    for z in names[:-1]:
        p.on_preemption(z, 1.0)
    # after pushing all but one into Z_P, the rebalance must have fired
    assert len(p.available_zones) >= 2
    assert p.preempting_zones == []


def test_select_next_zone_prefers_unoccupied():
    p = mk_policy()
    counts = {z.name: 1 for z in ZONES[:-1]}
    pick = p._select_next_zone(counts, 0.0)
    assert pick == ZONES[-1].name


def test_select_next_zone_prefers_cheap_on_tie():
    p = mk_policy()
    pick = p._select_next_zone({}, 0.0)
    prices = {z.name: CAT.spot_price(ITYPE, z.name) for z in ZONES}
    assert prices[pick] == min(prices.values())


# ---------------------------------------------------------------------------
# Overprovision + Dynamic Fallback (§3.2)
# ---------------------------------------------------------------------------


def test_initial_decide_launches_spot_goal_and_fallback():
    p = mk_policy(num_overprovision=2)
    acts = p.decide(obs(n_target=4))
    spots = [a for a in acts if isinstance(a, LaunchSpot)]
    ods = [a for a in acts if isinstance(a, LaunchOnDemand)]
    assert len(spots) == 6          # N_Tar + N_Extra
    assert len(ods) == 4            # O = min(N_Tar, N_Tar+N_Extra-0)


def test_fallback_formula():
    p = mk_policy(num_overprovision=2)
    ready = [mk_inst(f"us-west-2{s}") for s in "abc"]   # S_r = 3
    acts = p.decide(obs(n_target=4, spot_ready=ready))
    ods = [a for a in acts if isinstance(a, LaunchOnDemand)]
    # O = min(4, 4+2-3) = 3
    assert len(ods) == 3


def test_od_scaled_down_when_spot_healthy():
    p = mk_policy(num_overprovision=2)
    ready = [mk_inst("us-west-2a") for _ in range(6)]    # S_r = 6
    od = [mk_inst("us-east-2a", InstanceKind.ON_DEMAND) for _ in range(2)]
    acts = p.decide(obs(n_target=4, spot_ready=ready, od_ready=od))
    terms = [a for a in acts if isinstance(a, Terminate)]
    assert len(terms) == 2          # O = min(4, 6-6) = 0


def test_spot_spread_across_zones():
    """Replacements must not pile onto one zone in a single tick."""
    p = mk_policy(num_overprovision=2, max_launch_per_zone_per_tick=2)
    acts = p.decide(obs(n_target=8))
    spots = [a.zone for a in acts if isinstance(a, LaunchSpot)]
    from collections import Counter

    assert max(Counter(spots).values()) <= 2


def test_warning_discounts_at_risk_replicas():
    p = mk_policy(num_overprovision=2, warning_ttl_s=240.0)
    ready = [mk_inst("us-west-2a") for _ in range(6)]
    p.on_warning("us-west-2a", 100.0)
    acts = p.decide(obs(now=110.0, n_target=4, spot_ready=ready))
    ods = [a for a in acts if isinstance(a, LaunchOnDemand)]
    # all 6 ready replicas are at risk -> S_r_eff = 0 -> O = 4
    assert len(ods) == 4


def test_warning_expires():
    p = mk_policy(num_overprovision=2, warning_ttl_s=240.0)
    ready = [mk_inst("us-west-2a") for _ in range(6)]
    p.on_warning("us-west-2a", 100.0)
    acts = p.decide(obs(now=500.0, n_target=4, spot_ready=ready))
    assert not [a for a in acts if isinstance(a, LaunchOnDemand)]


def test_no_fallback_variant():
    p = SpotHedgePolicy(dynamic_ondemand_fallback=False)
    p.reset(ZONES, CAT, ITYPE)
    acts = p.decide(obs(n_target=4))
    assert not [a for a in acts if isinstance(a, LaunchOnDemand)]


def test_min_ondemand_floor():
    p = mk_policy(num_overprovision=2, min_ondemand=1)
    ready = [mk_inst("us-west-2a") for _ in range(6)]
    acts = p.decide(obs(n_target=4, spot_ready=ready))
    ods = [a for a in acts if isinstance(a, LaunchOnDemand)]
    assert len(ods) == 1


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_contains_all():
    names = registered_policies()
    for n in ("spothedge", "even_spread", "round_robin", "static_mixture",
              "aws_spot", "mark_like", "ondemand_only", "spot_only",
              "omniscient"):
        assert n in names


def test_make_policy_kwargs():
    p = make_policy("spothedge", num_overprovision=3)
    assert p.n_extra == 3

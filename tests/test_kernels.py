"""Pallas kernels vs. jnp oracles (interpret=True on CPU), sweeping
shapes/dtypes per the deliverable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.flash_decode import flash_decode_bhd
from repro.kernels.moe_gmm import moe_gmm_ecf
from repro.kernels.selective_scan import selective_scan_bqcn


def rnd(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, Kv, Sq, Skv, D, causal, window, prefix)
    (1, 4, 4, 128, 128, 64, True, None, 0),
    (2, 4, 2, 256, 256, 64, True, None, 0),          # GQA
    (1, 8, 1, 128, 128, 128, True, None, 0),         # MQA (paligemma-like)
    (2, 4, 4, 192, 192, 64, True, None, 0),          # non-multiple of block
    (1, 4, 4, 128, 128, 64, False, None, 0),         # bidirectional (enc)
    (1, 4, 4, 256, 256, 64, True, 96, 0),            # sliding window
    (1, 4, 4, 128, 128, 64, True, None, 32),         # prefix-LM
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, H, Kv, Sq, Skv, D, causal, window, prefix = case
    q = rnd(1, (B, H, Sq, D), dtype)
    k = rnd(2, (B, Kv, Skv, D), dtype)
    v = rnd(3, (B, Kv, Skv, D), dtype)
    got = flash_attention_bhsd(
        q, k, v, causal=causal, window=window, prefix_len=prefix,
        block_q=64, block_kv=64, interpret=True,
    )
    want = ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, prefix_len=prefix
    )
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype],
    )


def test_flash_attention_model_layout_wrapper():
    B, S, H, D = 2, 128, 4, 64
    q = rnd(4, (B, S, H, D), jnp.float32)
    k = rnd(5, (B, S, H, D), jnp.float32)
    v = rnd(6, (B, S, H, D), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (1, 4, 4, 256, 64, 256),     # full cache
    (2, 8, 2, 512, 64, 300),     # GQA + partial validity
    (1, 8, 1, 1024, 128, 700),   # MQA long cache
    (2, 4, 4, 384, 64, 100),     # short occupancy
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(case, dtype):
    B, H, Kv, S, D, n_valid = case
    q = rnd(7, (B, H, D), dtype)
    k = rnd(8, (B, Kv, S, D), dtype)
    v = rnd(9, (B, Kv, S, D), dtype)
    valid = (jnp.arange(S)[None, :] < n_valid).astype(jnp.int8)
    valid = jnp.broadcast_to(valid, (B, S))
    got = flash_decode_bhd(q, k, v, valid, block_kv=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype],
    )


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [
    (1, 32, 64, 16),
    (2, 64, 128, 16),
    (2, 17, 256, 8),      # odd chunk length
]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_selective_scan_matches_ref(case):
    B, Q, C, N = case
    # a in (0,1) like exp(delta·A); b small
    a = jax.nn.sigmoid(rnd(10, (B, Q, C, N), jnp.float32))
    b = rnd(11, (B, Q, C, N), jnp.float32) * 0.1
    h0 = rnd(12, (B, C, N), jnp.float32)
    got = selective_scan_bqcn(a, b, h0, block_c=64, interpret=True)
    want = ref.selective_scan_ref(a, b, h0)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_selective_scan_equals_mamba_chunked_path():
    """The kernel slots into mamba1_full's chunk loop: same h sequence."""
    a = jax.nn.sigmoid(rnd(13, (1, 16, 32, 8), jnp.float32))
    b = rnd(14, (1, 16, 32, 8), jnp.float32) * 0.1
    h0 = jnp.zeros((1, 32, 8), jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    want = b_s + a_s * h0[:, None]
    got = selective_scan_bqcn(a, b, h0, block_c=32, interpret=True)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------

GMM_CASES = [
    (4, 64, 128, 256),
    (8, 96, 200, 64),       # non-aligned dims exercise padding
    (2, 256, 512, 512),
]


@pytest.mark.parametrize("case", GMM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_matches_ref(case, dtype):
    E, C, D, F = case
    x = rnd(15, (E, C, D), dtype)
    w = rnd(16, (E, D, F), dtype)
    got = moe_gmm_ecf(x, w, block_c=64, block_d=64, block_f=64,
                      interpret=True)
    want = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32),
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_moe_ffn_matches_dense_path():
    """ops.moe_ffn == the model's einsum expert FFN."""
    E, C, D, F = 4, 32, 64, 96
    xe = rnd(17, (E, C, D), jnp.float32)
    wi = rnd(18, (E, D, F), jnp.float32)
    wg = rnd(19, (E, D, F), jnp.float32)
    wo = rnd(20, (E, F, D), jnp.float32)
    got = ops.moe_ffn(xe, wi, wg, wo, act="silu", interpret=True)
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    want = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)

"""Tests for the spot-availability forecasting subsystem.

Covers the Forecaster contract (probabilities, registry), the degenerate
monotonicity properties from the issue (all-available traces drive
``p_available`` up, all-preempting traces drive it down), the regional
Markov estimator's sibling-correlation mechanics, the backtest harness
and its versioned artifact, the ``forecast:`` spec plumbing through
loader/builder/suite, and RiskAwareSpotHedgePolicy behaviour.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.cluster.traces import (
    SpotTrace,
    infer_region,
    load_trace,
    trace_stats,
)
from repro.core.policy import ControllerEvent, EventKind, make_policy
from repro.forecast import (
    BacktestReport,
    Forecaster,
    MarkovRegionalForecaster,
    ZoneForecast,
    make_forecaster,
    registered_forecasters,
    run_backtest,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False


ZONES = ["us-west-2a", "us-west-2b", "us-west-2c"]
REGIONS = {z: "us-west-2" for z in ZONES}
ALL_FORECASTERS = ("persistence", "ewma", "markov")


def _fresh(name: str, dt: float = 60.0) -> Forecaster:
    fc = make_forecaster(name)
    fc.reset(ZONES, REGIONS, dt=dt)
    return fc


def _feed_constant(fc: Forecaster, up: bool, steps: int,
                   dt: float = 60.0) -> None:
    for t in range(steps):
        fc.observe(t * dt, {z: up for z in ZONES})


# ---------------------------------------------------------------------------
# registry + interface contract
# ---------------------------------------------------------------------------


def test_registry_has_the_three_builtins():
    names = registered_forecasters()
    for expected in ALL_FORECASTERS:
        assert expected in names


def test_unknown_forecaster_raises():
    with pytest.raises(KeyError, match="unknown forecaster"):
        make_forecaster("nope")


@pytest.mark.parametrize("name", ("persistence", "ewma"))
def test_forecaster_priors_validated_as_probabilities(name):
    with pytest.raises(ValueError, match="probability"):
        make_forecaster(name, prior=5.0)


def test_zone_forecast_rejects_non_probabilities():
    with pytest.raises(ValueError, match="probability"):
        ZoneForecast(zone="z", p_available=1.2, p_preempt=0.0)
    with pytest.raises(ValueError, match="probability"):
        ZoneForecast(zone="z", p_available=0.5, p_preempt=-0.1)


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_predict_requires_positive_horizon(name):
    fc = _fresh(name)
    with pytest.raises(ValueError, match="horizon_s"):
        fc.predict(0.0, 0.0)


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_unobserved_zones_still_get_valid_scores(name):
    fc = _fresh(name)
    out = fc.predict(0.0, 600.0)
    assert set(out) == set(ZONES)
    for f in out.values():
        assert 0.0 <= f.p_available <= 1.0
        assert 0.0 <= f.p_preempt <= 1.0


# ---------------------------------------------------------------------------
# degenerate-trace monotonicity (the issue's property requirements)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_all_available_history_predicts_high_availability(name):
    fc = _fresh(name)
    _feed_constant(fc, up=True, steps=200)
    for f in fc.predict(200 * 60.0, 600.0).values():
        assert f.p_available >= 0.9
        assert f.p_preempt <= 0.25


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_all_preempting_history_predicts_low_availability(name):
    fc = _fresh(name)
    _feed_constant(fc, up=False, steps=200)
    for f in fc.predict(200 * 60.0, 600.0).values():
        assert f.p_available <= 0.1
        assert f.p_preempt >= 0.9


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_degenerate_histories_order_the_forecasts(name):
    """An all-up zone must always score above an all-down zone."""
    fc = _fresh(name)
    for t in range(100):
        fc.observe(
            t * 60.0,
            {ZONES[0]: True, ZONES[1]: False, ZONES[2]: True},
        )
    out = fc.predict(100 * 60.0, 900.0)
    assert out[ZONES[0]].p_available > out[ZONES[1]].p_available
    assert out[ZONES[0]].p_preempt < out[ZONES[1]].p_preempt


def test_event_channel_maps_transitions_to_observations():
    fc = _fresh("persistence")
    fc.observe_event(ControllerEvent(
        kind=EventKind.READY, zone=ZONES[0], now=0.0, instance_id=1
    ))
    fc.observe_event(ControllerEvent(
        kind=EventKind.PREEMPTION, zone=ZONES[1], now=0.0, instance_id=2
    ))
    fc.observe_event(ControllerEvent(
        kind=EventKind.LAUNCH_FAILURE, zone=ZONES[2], now=0.0
    ))
    out = fc.predict(60.0, 60.0)
    assert out[ZONES[0]].p_available == 1.0
    assert out[ZONES[1]].p_available == 0.0
    assert out[ZONES[2]].p_available == 0.0


# ---------------------------------------------------------------------------
# hypothesis: probability validity over arbitrary observation streams
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        name=st.sampled_from(ALL_FORECASTERS),
        pattern=st.lists(
            st.tuples(st.integers(0, 2), st.booleans()),
            min_size=0, max_size=60,
        ),
        horizon_steps=st.integers(1, 120),
    )
    def test_scores_are_valid_probabilities(name, pattern, horizon_steps):
        """Any observation stream, any horizon: scores stay in [0, 1]."""
        fc = make_forecaster(name)
        fc.reset(ZONES, REGIONS, dt=60.0)
        for t, (zi, up) in enumerate(pattern):
            fc.observe(t * 60.0, {ZONES[zi]: up})
        out = fc.predict(len(pattern) * 60.0, horizon_steps * 60.0)
        for f in out.values():
            assert 0.0 <= f.p_available <= 1.0
            assert 0.0 <= f.p_preempt <= 1.0

    @settings(max_examples=20, deadline=None)
    @given(
        name=st.sampled_from(ALL_FORECASTERS),
        up=st.booleans(),
        steps=st.integers(30, 300),
    )
    def test_degenerate_monotonicity_property(name, up, steps):
        """All-available history -> p_available >= 0.9; all-preempting
        history -> p_available <= 0.1, for every estimator and length."""
        fc = make_forecaster(name)
        fc.reset(ZONES, REGIONS, dt=60.0)
        _feed_constant(fc, up=up, steps=steps)
        for f in fc.predict(steps * 60.0, 300.0).values():
            if up:
                assert f.p_available >= 0.9
            else:
                assert f.p_available <= 0.1


# ---------------------------------------------------------------------------
# regional Markov: sibling correlation is actually exploited
# ---------------------------------------------------------------------------


def test_markov_learns_higher_hazard_under_sibling_crunch():
    """Feed a correlated history (sibling down precedes own drop) and
    check the crunch bucket's up->down rate exceeds the calm bucket's."""
    fc = MarkovRegionalForecaster(smoothing=1.0)
    fc.reset(ZONES, REGIONS, dt=60.0)
    rng = np.random.default_rng(7)
    state = {z: True for z in ZONES}
    for t in range(3000):
        # region-level crunch process: 10% of time in crunch
        crunch = (t % 100) >= 90
        for i, z in enumerate(ZONES):
            if crunch:
                # zones fall one step after the first sibling (lagged)
                state[z] = False if (t % 100) >= 90 + i else state[z]
            else:
                state[z] = True
        fc.observe(t * 60.0, dict(state))
    p_calm, _ = fc.rates(ZONES[1])["calm"]
    p_crunch, _ = fc.rates(ZONES[1])["crunch"]
    assert p_crunch > p_calm


def test_markov_sibling_state_raises_risk_now():
    """Same own-history, sibling down vs. up: risk must be higher (and
    availability lower) when the sibling is in crunch.

    The probed zone is a *late faller* (its drops trail its siblings'),
    so its up->down transitions land in the crunch bucket — the
    predictive half of the Fig. 3 correlation.  The first domino of a
    crunch is unpredictable by construction.
    """
    def build(sib_up: bool):
        fc = MarkovRegionalForecaster()
        fc.reset(ZONES, REGIONS, dt=60.0)
        state = {z: True for z in ZONES}
        # history with real crunches so the buckets separate; zone i
        # falls at crunch onset + i (ZONES[2] always falls last)
        for t in range(2000):
            crunch = (t % 200) >= 180
            for i, z in enumerate(ZONES):
                state[z] = not crunch or (t % 200) < 180 + i
            fc.observe(t * 60.0, dict(state))
        now = 2000 * 60.0
        fc.observe(now, {ZONES[0]: sib_up, ZONES[1]: sib_up,
                         ZONES[2]: True})
        return fc.predict(now, 900.0)[ZONES[2]]

    calm = build(sib_up=True)
    crunch = build(sib_up=False)
    assert crunch.p_preempt > calm.p_preempt
    assert crunch.p_available < calm.p_available


def test_infer_region_heuristics():
    assert infer_region("us-west-2a") == "us-west-2"
    assert infer_region("us-central1-a") == "us-central1"
    assert infer_region("weird") == "weird"


# ---------------------------------------------------------------------------
# backtest harness + artifact
# ---------------------------------------------------------------------------


def _tiny_trace(seed: int = 3) -> SpotTrace:
    rng = np.random.default_rng(seed)
    T = 400
    cap = np.zeros((T, len(ZONES)), dtype=np.int32)
    up = np.ones(len(ZONES), dtype=bool)
    for t in range(T):
        flip = rng.random(len(ZONES)) < 0.05
        up = np.where(flip, ~up, up)
        cap[t] = np.where(up, 4, 0)
    return SpotTrace(zones=tuple(ZONES), cap=cap, dt=60.0, name="tiny")


@pytest.mark.parametrize("name", ALL_FORECASTERS)
def test_backtest_scores_are_finite_and_bounded(name):
    report = run_backtest(
        _tiny_trace(), name, horizons=(1, 5), warmup_steps=50
    )
    assert report.trace == "tiny"
    assert report.forecaster == name
    for h in report.horizons:
        assert 0.0 <= h.brier_avail <= 1.0
        assert 0.0 <= h.brier_preempt <= 1.0
        assert 0.0 <= h.hit_rate <= 1.0
        assert h.n > 0
        for bin_ in h.calibration:
            assert 0.0 <= bin_["p_mean"] <= 1.0
            assert 0.0 <= bin_["freq"] <= 1.0


def test_backtest_artifact_roundtrip(tmp_path):
    report = run_backtest(
        _tiny_trace(), "markov", horizons=(1, 5), warmup_steps=50
    )
    path = report.save(str(tmp_path))
    with open(path) as f:
        d = json.load(f)
    assert d["schema"] == 1
    assert d["kind"] == "forecast-backtest"
    assert d["mean_brier_avail"] == pytest.approx(
        report.mean_brier_avail, abs=1e-6
    )
    again = BacktestReport.load(path)
    assert again.trace == report.trace
    assert len(again.horizons) == len(report.horizons)


def test_backtest_rejects_bad_schema(tmp_path):
    path = os.path.join(str(tmp_path), "bad.json")
    with open(path, "w") as f:
        json.dump({"schema": 99}, f)
    with pytest.raises(ValueError, match="schema"):
        BacktestReport.load(path)


def test_backtest_perfect_forecaster_on_constant_trace():
    """On an always-available trace every estimator converges to Brier ~0
    and the persistence baseline is exactly 0."""
    cap = np.full((300, len(ZONES)), 2, dtype=np.int32)
    tr = SpotTrace(zones=tuple(ZONES), cap=cap, dt=60.0, name="const")
    for name in ALL_FORECASTERS:
        report = run_backtest(tr, name, horizons=(5,), warmup_steps=100)
        assert report.horizons[0].brier_avail <= 0.01
    persist = run_backtest(tr, "persistence", horizons=(5,),
                           warmup_steps=100)
    assert persist.horizons[0].brier_avail == 0.0


def test_committed_backtest_artifacts_prove_markov_beats_persistence():
    """The acceptance artifact: committed backtests must show the Markov
    forecaster strictly beating persistence (Brier) on >= 2 named traces."""
    art = os.path.join(os.path.dirname(__file__), "..",
                       "artifacts", "forecast")
    wins = 0
    for tname in ("aws-1", "aws-2", "aws-3", "gcp-1"):
        mk = os.path.join(art, f"backtest_{tname}_markov.json")
        ps = os.path.join(art, f"backtest_{tname}_persistence.json")
        if not (os.path.exists(mk) and os.path.exists(ps)):
            continue
        if (BacktestReport.load(mk).mean_brier_avail
                < BacktestReport.load(ps).mean_brier_avail):
            wins += 1
    assert wins >= 2


# ---------------------------------------------------------------------------
# trace stats helper (satellite: the quantities forecasters consume)
# ---------------------------------------------------------------------------


def test_trace_stats_structure_and_ranges():
    stats = trace_stats(load_trace("aws-1"))
    assert stats["name"] == "aws-1"
    assert set(stats["zones"]) == set(load_trace("aws-1").zones)
    for s in stats["zones"].values():
        assert 0.0 <= s["availability"] <= 1.0
        assert s["preemptions_per_day"] >= 0.0
        assert -1.0 <= s["mean_sibling_corr"] <= 1.0
        assert s["region"] == "us-west-2"
    assert 0.0 <= stats["mean_availability"] <= 1.0


def test_traces_cli_prints_stats(capsys):
    from repro.cluster.traces import main

    assert main(["aws-1"]) == 0
    out = capsys.readouterr().out
    assert "aws-1" in out and "us-west-2a" in out


def test_traces_cli_json_mode(capsys):
    from repro.cluster.traces import main

    assert main(["aws-1", "--json"]) == 0
    data = json.loads(capsys.readouterr().out)
    assert data[0]["name"] == "aws-1"


# ---------------------------------------------------------------------------
# spec / builder / suite plumbing
# ---------------------------------------------------------------------------


def _spec_dict(policy: str = "risk_spothedge", **forecast):
    d = {
        "name": "fc-test",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "replica_policy": {"name": policy},
        "workload": {"kind": "none"},
        "sim": {"duration_hours": 1.0},
    }
    if forecast:
        d["forecast"] = forecast
    return d


def test_forecast_section_reaches_the_policy():
    from repro.service import spec_from_dict
    from repro.service.builder import build_service

    spec = spec_from_dict(_spec_dict(
        name="ewma", horizon_s=300.0, risk_threshold=0.7,
        calm_threshold=0.05, args={"halflife_s": 1200.0},
    ))
    policy = build_service(spec).policy
    assert policy.forecaster.name == "ewma"
    assert policy.forecaster.halflife_s == 1200.0
    assert policy.horizon_s == 300.0
    assert policy.risk_threshold == 0.7
    assert policy.calm_threshold == 0.05


def test_forecast_section_ignored_by_vanilla_policies():
    from repro.core.spothedge import SpotHedgePolicy
    from repro.service import spec_from_dict
    from repro.service.builder import build_service

    spec = spec_from_dict(_spec_dict(policy="spothedge", name="markov"))
    policy = build_service(spec).policy
    assert type(policy) is SpotHedgePolicy


def test_forecast_section_validation():
    from repro.service import SpecError, spec_from_dict

    with pytest.raises(SpecError, match="forecast.name"):
        spec_from_dict(_spec_dict(name="definitely-not-registered"))
    with pytest.raises(SpecError, match="horizon_s"):
        spec_from_dict(_spec_dict(name="markov", horizon_s=-5.0))
    with pytest.raises(SpecError, match="risk_threshold"):
        spec_from_dict(_spec_dict(name="markov", risk_threshold=1.5))


def test_forecast_spec_roundtrips():
    from repro.service import spec_from_dict

    spec = spec_from_dict(_spec_dict(name="markov", horizon_s=450.0))
    again = spec_from_dict(spec.to_dict())
    assert again == spec


def test_sweep_forecaster_axis_expands_and_labels():
    from repro.experiments import ScenarioSuite
    from repro.service import spec_from_dict

    d = _spec_dict()
    d["sweep"] = {
        "policies": ["spothedge", "risk_spothedge"],
        "forecasters": ["persistence", "markov"],
    }
    suite = ScenarioSuite.from_spec(spec_from_dict(d))
    # spothedge ignores the forecast section, so its cells collapse to
    # one per (trace, workload, seed) — no duplicate identical runs
    assert len(suite) == 3
    risk = [sc for sc in suite.scenarios
            if sc.labels["policy"] == "risk_spothedge"]
    vanilla = [sc for sc in suite.scenarios
               if sc.labels["policy"] == "spothedge"]
    assert len(risk) == 2 and len(vanilla) == 1
    assert {sc.labels["forecaster"] for sc in risk} == {
        "persistence", "markov"
    }
    for sc in risk:
        assert sc.spec.forecast is not None
        assert sc.spec.forecast.name == sc.labels["forecaster"]
    assert "forecaster" not in vanilla[0].labels


def test_sweep_unknown_forecaster_rejected():
    from repro.service import SpecError, spec_from_dict

    d = _spec_dict()
    d["sweep"] = {"forecasters": ["nope"]}
    with pytest.raises(SpecError, match="sweep forecaster"):
        spec_from_dict(d)


# ---------------------------------------------------------------------------
# RiskAwareSpotHedgePolicy behaviour
# ---------------------------------------------------------------------------


def test_risk_policy_registered_and_constructible():
    policy = make_policy("risk_spothedge")
    assert policy.name == "risk_spothedge"
    assert policy.uses_forecast
    assert policy.forecaster.name == "markov"


def test_risk_policy_accepts_zero_overprovision():
    """overprovision: 0 is a legal vanilla knob; the trim floor must
    clamp to it rather than failing its own validation."""
    policy = make_policy("risk_spothedge", num_overprovision=0)
    assert policy.min_overprovision == 0

    from repro.service import spec_from_dict
    from repro.service.builder import build_service

    d = _spec_dict(name="markov")
    d["replica_policy"]["overprovision"] = 0
    assert build_service(spec_from_dict(d)).policy.min_overprovision == 0


def test_builder_wraps_policy_value_errors_as_spec_errors():
    from repro.service import SpecError, spec_from_dict
    from repro.service.builder import build_service

    d = _spec_dict(name="markov")
    d["replica_policy"]["args"] = {"obs_interval_s": -1.0}
    with pytest.raises(SpecError, match="rejected its knobs"):
        build_service(spec_from_dict(d))


def test_risk_policy_rejects_bad_knobs():
    with pytest.raises(ValueError, match="horizon_s"):
        make_policy("risk_spothedge", horizon_s=0)
    with pytest.raises(ValueError, match="risk_threshold"):
        make_policy("risk_spothedge", risk_threshold=2.0)
    with pytest.raises(ValueError, match="min_overprovision"):
        make_policy("risk_spothedge", min_overprovision=5)
    with pytest.raises(ValueError, match="forecaster_args"):
        from repro.forecast import PersistenceForecaster

        make_policy(
            "risk_spothedge",
            forecaster=PersistenceForecaster(),
            forecaster_args={"prior": 0.4},
        )


def test_risk_policy_runs_and_differs_from_vanilla():
    """End to end on gcp-1: the risk-aware run must be valid and must
    actually diverge from vanilla (the forecaster is in the loop)."""
    from repro.cluster.simulator import run_policy_on_trace

    tr = load_trace("gcp-1")
    base = run_policy_on_trace("spothedge", tr, n_target=4,
                               duration_s=36 * 3600.0)
    risk = run_policy_on_trace("risk_spothedge", tr, n_target=4,
                               duration_s=36 * 3600.0)
    assert 0.0 <= risk.availability <= 1.0
    assert risk.total_cost > 0
    assert (
        risk.total_cost != base.total_cost
        or risk.n_preemptions != base.n_preemptions
    )


def test_risk_policy_surges_buffer_under_predicted_risk():
    """Force a high-risk forecast and check the spot goal surges; force
    calm and check it trims."""
    from repro.cluster.catalog import default_catalog
    from repro.core.policy import Observation

    catalog = default_catalog()
    policy = make_policy("risk_spothedge", num_overprovision=2,
                         surge_overprovision=2, min_overprovision=1)
    zones = [catalog.zone(z) for z in ZONES]
    policy.reset(zones, catalog, "p3.2xlarge")

    class _Inst:
        def __init__(self, zone):
            self.zone = zone
            self.launched_at = 0.0
            self.id = 1

    obs = Observation(
        now=0.0, n_target=4,
        spot_ready=[_Inst(ZONES[0])], spot_provisioning=[],
        od_ready=[], od_provisioning=[],
    )
    policy._forecast = {
        z: ZoneForecast(zone=z, p_available=0.2, p_preempt=0.9)
        for z in ZONES
    }
    assert policy._spot_goal(obs) == 4 + 2 + 2          # surge
    policy._forecast = {
        z: ZoneForecast(zone=z, p_available=0.99, p_preempt=0.01)
        for z in ZONES
    }
    assert policy._spot_goal(obs) == 4 + 1              # calm trim
    policy._forecast = {
        z: ZoneForecast(zone=z, p_available=0.9, p_preempt=0.3)
        for z in ZONES
    }
    assert policy._spot_goal(obs) == 4 + 2              # base


def test_surge_is_spot_only_insurance():
    """A surged spot goal must not leak into the on-demand fallback: a
    healthy fleet under surge launches spot, never on-demand."""
    from repro.cluster.catalog import default_catalog
    from repro.core.policy import LaunchOnDemand, LaunchSpot, Observation

    catalog = default_catalog()
    policy = make_policy("risk_spothedge", num_overprovision=2,
                         surge_overprovision=1)
    policy.reset([catalog.zone(z) for z in ZONES], catalog, "p3.2xlarge")

    class _Inst:
        def __init__(self, zone, iid):
            self.zone = zone
            self.launched_at = 0.0
            self.id = iid

    # full healthy fleet (6 ready >= n_tar + n_extra), one risky zone
    ready = [_Inst(ZONES[k % 3], k) for k in range(6)]
    obs = Observation(now=0.0, n_target=4, spot_ready=ready,
                      spot_provisioning=[], od_ready=[],
                      od_provisioning=[])
    policy._feed_forecaster(obs)
    policy._forecast = {
        ZONES[0]: ZoneForecast(zone=ZONES[0], p_available=0.2,
                               p_preempt=0.9),
        ZONES[1]: ZoneForecast(zone=ZONES[1], p_available=0.99,
                               p_preempt=0.01),
        ZONES[2]: ZoneForecast(zone=ZONES[2], p_available=0.99,
                               p_preempt=0.01),
    }
    actions = super(type(policy), policy).decide(obs)
    spot = [a for a in actions if isinstance(a, LaunchSpot)]
    od = [a for a in actions if isinstance(a, LaunchOnDemand)]
    assert len(spot) == 1          # the surge replica
    assert od == []                # ...and no on-demand leak
    # the surge replica avoids the predicted-collapse zone
    assert spot[0].zone != ZONES[0]


def test_surge_launch_avoids_predicted_collapse_zone():
    """Even when the risky zone has the fewest replicas (count-first
    ordering would pick it), the surge lands in a forecast-safe zone."""
    policy = make_policy("risk_spothedge")
    from repro.cluster.catalog import default_catalog

    catalog = default_catalog()
    policy.reset([catalog.zone(z) for z in ZONES], catalog, "p3.2xlarge")
    policy._forecast = {
        ZONES[0]: ZoneForecast(zone=ZONES[0], p_available=0.2,
                               p_preempt=0.9),
        ZONES[1]: ZoneForecast(zone=ZONES[1], p_available=0.99,
                               p_preempt=0.01),
        ZONES[2]: ZoneForecast(zone=ZONES[2], p_available=0.99,
                               p_preempt=0.01),
    }
    # the risky zone is least loaded — vanilla ordering would pick it
    counts = {ZONES[0]: 1, ZONES[1]: 2, ZONES[2]: 3}
    assert policy._select_next_zone(counts, 0.0) == ZONES[1]
    # ...unless every zone is predicted to collapse (no safe harbor)
    policy._forecast = {
        z: ZoneForecast(zone=z, p_available=0.2, p_preempt=0.9)
        for z in ZONES
    }
    assert policy._select_next_zone(counts, 0.0) == ZONES[0]


def test_risk_policy_hedges_only_on_predicted_collapse():
    """The forecast discount only fires when predicted survivors < N_Tar
    (losses the spot buffer can absorb are not hedged)."""
    from repro.cluster.catalog import default_catalog
    from repro.core.policy import Observation

    catalog = default_catalog()
    policy = make_policy("risk_spothedge", num_overprovision=2)
    zones = [catalog.zone(z) for z in ZONES]
    policy.reset(zones, catalog, "p3.2xlarge")

    class _Inst:
        def __init__(self, zone, iid):
            self.zone = zone
            self.launched_at = 0.0
            self.id = iid

    risky = {
        z: ZoneForecast(zone=z, p_available=0.3, p_preempt=0.95)
        for z in ZONES[:2]
    }
    safe = {
        ZONES[2]: ZoneForecast(
            zone=ZONES[2], p_available=0.99, p_preempt=0.01
        )
    }
    policy._forecast = {**risky, **safe}
    # 6 ready, 2 in risky zones: survivors 4 >= target 4 -> no hedge
    ready = [_Inst(ZONES[0], 1), _Inst(ZONES[1], 2)] + [
        _Inst(ZONES[2], 3 + k) for k in range(4)
    ]
    obs = Observation(now=0.0, n_target=4, spot_ready=ready,
                      spot_provisioning=[], od_ready=[],
                      od_provisioning=[])
    assert policy._at_risk_ready(obs) == 0
    # 4 ready, 2 in risky zones: survivors 2 < target 4 -> hedge fires
    ready = [_Inst(ZONES[0], 1), _Inst(ZONES[1], 2),
             _Inst(ZONES[2], 3), _Inst(ZONES[2], 4)]
    obs = Observation(now=0.0, n_target=4, spot_ready=ready,
                      spot_provisioning=[], od_ready=[],
                      od_provisioning=[])
    assert policy._at_risk_ready(obs) == 2

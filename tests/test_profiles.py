"""Kernel-profile subsystem: compat shim, step-time tables, profiled latency.

Covers the three layers the profile data plane spans:

* ``kernels/compat.py`` resolves the Pallas TPU API under both historical
  spellings (``CompilerParams`` vs ``TPUCompilerParams``) — exercised via
  stand-in modules, independent of the installed JAX;
* ``profiles/`` schema round-trips, version gating, directory merging,
  and a real (tiny) profiler run through the interpret-mode kernels;
* ``ProfiledLatencyModel`` reproduces the measured step times from a
  profile JSON (the round-trip the serving layer depends on), and the
  spec/builder wiring falls back to the roofline when no entry matches.
"""

import dataclasses
import json
import math
import types

import pytest

from repro.cluster.catalog import (
    ACCEL_HBM_BYTES_PER_S,
    InstanceType,
    default_catalog,
    hbm_bandwidth,
)
from repro.configs import get_config
from repro.kernels import compat
from repro.profiles import (
    ProfileEntry,
    ProfileSchemaError,
    ProfileTable,
    load_profiles,
    profile_model,
)
from repro.serving.latency import (
    LatencyModel,
    ProfiledLatencyModel,
    make_latency_model,
)

CAT = default_catalog()


# ---------------------------------------------------------------------------
# compat shim
# ---------------------------------------------------------------------------


class _Params:
    def __init__(self, dimension_semantics=None, **kw):
        self.dimension_semantics = dimension_semantics
        self.kw = kw


def test_compat_resolves_new_spelling():
    mod = types.SimpleNamespace(CompilerParams=_Params)
    assert compat.resolve_compiler_params_cls(mod) is _Params


def test_compat_resolves_old_spelling():
    mod = types.SimpleNamespace(TPUCompilerParams=_Params)
    assert compat.resolve_compiler_params_cls(mod) is _Params


def test_compat_prefers_current_spelling_when_both_exist():
    class Old(_Params):
        pass

    mod = types.SimpleNamespace(CompilerParams=_Params,
                                TPUCompilerParams=Old)
    assert compat.resolve_compiler_params_cls(mod) is _Params


def test_compat_raises_outside_supported_range():
    with pytest.raises(ImportError, match="pyproject"):
        compat.resolve_compiler_params_cls(types.SimpleNamespace())
    with pytest.raises(ImportError):
        compat.resolve_vmem(types.SimpleNamespace())


def test_compat_vmem_falls_back_to_memoryspace_enum():
    sentinel = object()
    mod = types.SimpleNamespace(
        MemorySpace=types.SimpleNamespace(VMEM=sentinel)
    )
    assert compat.resolve_vmem(mod) is sentinel


def test_compat_installed_jax_resolves(monkeypatch):
    """Whatever JAX is installed, the shim found a working class."""
    p = compat.compiler_params(
        dimension_semantics=("parallel", "arbitrary")
    )
    assert tuple(p.dimension_semantics) == ("parallel", "arbitrary")
    # both spellings route through the same resolver under monkeypatching
    import jax.experimental.pallas.tpu as pltpu

    cls = compat.resolve_compiler_params_cls(pltpu)
    for name in ("CompilerParams", "TPUCompilerParams"):
        shadow = types.SimpleNamespace(**{name: cls})
        assert compat.resolve_compiler_params_cls(shadow) is cls


# ---------------------------------------------------------------------------
# catalog HBM bandwidth table
# ---------------------------------------------------------------------------


def test_catalog_itypes_have_bandwidth():
    for t in CAT.instance_types:
        assert t.hbm_bytes_per_s == ACCEL_HBM_BYTES_PER_S[t.accelerator]


def test_unknown_accelerator_raises():
    with pytest.raises(KeyError, match="HBM bandwidth"):
        hbm_bandwidth("H9000")
    with pytest.raises(KeyError, match="H9000"):
        InstanceType("x1", "aws", "H9000", 1, 1.0, 0.3)


def test_unknown_accelerator_with_explicit_bandwidth_ok():
    t = InstanceType("x1", "aws", "H9000", 2, 1.0, 0.3,
                     hbm_bytes_per_s=1.5e12)
    assert t.hbm_bytes_per_s == 1.5e12
    lm = LatencyModel.for_model(get_config("llama3.2-1b"), t)
    assert lm.hbm_bytes_per_s == 2 * 1.5e12 * lm.mbu_decode


def test_latency_bandwidth_comes_from_catalog():
    """No silent 0.8 TB/s default: model uses the instance's table value."""
    t = CAT.instance_type("g5.48xlarge")     # A10G: 0.6 TB/s
    lm = LatencyModel.for_model(get_config("llama3.2-1b"), t)
    assert lm.hbm_bytes_per_s == pytest.approx(
        t.accel_count * 0.6e12 * lm.mbu_decode
    )


# ---------------------------------------------------------------------------
# profile schema
# ---------------------------------------------------------------------------


def _entry(model="llama3.2-1b", accel="A10G", mfu=0.31, mbu=0.55):
    return ProfileEntry(
        model=model, accelerator=accel, backend="tpu", mode="compiled",
        prefill_tokens=256, prefill_flops=1e12, prefill_wall_s=0.01,
        decode_cache_tokens=512, decode_steps=4,
        decode_bytes=1e9, decode_wall_s=0.001,
        mfu_prefill=mfu, mbu_decode=mbu,
    )


def test_profile_table_json_round_trip(tmp_path):
    table = ProfileTable(jax_version="0.0.0", backend="tpu",
                         mode="compiled")
    table.add(_entry())
    path = str(tmp_path / "t.json")
    table.save(path)
    back = ProfileTable.load(path)
    assert back.lookup("llama3.2-1b", "A10G") == _entry()
    assert back.lookup("llama3.2-1b", "V100") is None


def test_profile_schema_version_gate(tmp_path):
    path = tmp_path / "bad.json"
    d = ProfileTable().to_dict()
    d["schema_version"] = 99
    path.write_text(json.dumps(d))
    with pytest.raises(ProfileSchemaError, match="schema_version"):
        ProfileTable.load(str(path))


def test_profile_entry_key_mismatch_rejected(tmp_path):
    path = tmp_path / "bad.json"
    d = ProfileTable().to_dict()
    d["entries"] = {"wrong|key": _entry().to_dict()}
    path.write_text(json.dumps(d))
    with pytest.raises(ProfileSchemaError, match="keyed"):
        ProfileTable.load(str(path))


def test_load_profiles_directory_merge(tmp_path):
    a = ProfileTable()
    a.add(_entry(accel="A10G", mfu=0.1))
    a.save(str(tmp_path / "a.json"))
    b = ProfileTable()
    b.add(_entry(accel="A10G", mfu=0.9))   # later file wins
    b.add(_entry(accel="V100"))
    b.save(str(tmp_path / "b.json"))
    merged = load_profiles(str(tmp_path))
    assert len(merged.entries) == 2
    assert merged.lookup("llama3.2-1b", "A10G").mfu_prefill == 0.9


def test_load_profiles_missing_ok(tmp_path):
    assert load_profiles(str(tmp_path / "nope"), missing_ok=True).entries \
        == {}
    with pytest.raises(ProfileSchemaError):
        load_profiles(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# profiler (tiny real run through the interpret kernels)
# ---------------------------------------------------------------------------


def test_profiler_measures_llama_interpret():
    itype = CAT.instance_type("v5e-8")
    e = profile_model(
        "llama3.2-1b", itype,
        prefill_tokens=64, cache_tokens=128, repeats=1,
    )
    assert e.mode == "interpret" and e.accelerator == "TPUv5e"
    assert e.prefill_wall_s > 0 and e.decode_wall_s > 0
    assert 0 < e.mfu_prefill < 1 and 0 < e.mbu_decode < 1
    assert math.isclose(
        e.prefill_flops_per_s * (itype.accel_count
                                 * itype.peak_bf16_tflops * 1e12) ** -1,
        e.mfu_prefill,
    )


def test_run_cli_refuses_to_clobber_unreadable_table(tmp_path, capsys):
    from repro.profiles import run as profiles_run

    out = tmp_path / "t.json"
    out.write_text("{not json")
    rc = profiles_run.main([
        "--models", "llama3.2-1b", "--itype", "v5e-8",
        "--prefill-tokens", "64", "--cache-tokens", "128",
        "--repeats", "1", "--out", str(out),
    ])
    assert rc == 1
    assert "cannot be merged" in capsys.readouterr().err
    assert out.read_text() == "{not json"   # untouched


# ---------------------------------------------------------------------------
# ProfiledLatencyModel round trip
# ---------------------------------------------------------------------------


def test_profiled_latency_reproduces_measured_step_times(tmp_path):
    """profile JSON -> service_s consistent with the table's throughputs:
    prefill_s = 2·N_active·P / measured_flops_per_s and decode seconds/token
    = weight_bytes / measured_bytes_per_s (the roofline with measured
    MFU/MBU collapses to exactly the measured throughput)."""
    cfg = get_config("llama3.2-1b")
    itype = CAT.instance_type("g5.48xlarge")
    entry = _entry(accel=itype.accelerator)
    table = ProfileTable(jax_version="0", backend="tpu", mode="compiled")
    table.add(entry)
    path = str(tmp_path / "p.json")
    table.save(path)

    lm = make_latency_model(
        cfg, itype, model_id="llama3.2-1b", source="profile", profile=path
    )
    assert isinstance(lm, ProfiledLatencyModel)
    assert lm.profile_mode == "compiled"

    peak_flops = itype.accel_count * itype.peak_bf16_tflops * 1e12
    peak_bytes = itype.accel_count * itype.hbm_bytes_per_s
    P = 200
    want_prefill = 2.0 * lm._active_params * P / (
        peak_flops * entry.mfu_prefill
    )
    assert lm.prefill_s(P) == pytest.approx(want_prefill, rel=1e-12)
    want_decode = 2.0 * lm._active_params / (peak_bytes * entry.mbu_decode)
    assert lm.decode_s_per_token() == pytest.approx(want_decode, rel=1e-12)
    assert lm.service_s(P, 10) == pytest.approx(
        lm.overhead_s + want_prefill + 10 * want_decode, rel=1e-12
    )


def test_make_latency_model_roofline_matches_legacy():
    cfg = get_config("llama3.2-1b")
    itype = CAT.instance_type("g5.48xlarge")
    a = make_latency_model(cfg, itype, model_id="llama3.2-1b")
    b = LatencyModel.for_model(cfg, itype)
    assert a.service_s(100, 50) == b.service_s(100, 50)
    assert not isinstance(a, ProfiledLatencyModel)


def test_make_latency_model_profile_fallback_warns(tmp_path):
    cfg = get_config("llama3.2-1b")
    itype = CAT.instance_type("g5.48xlarge")
    with pytest.warns(UserWarning, match="falling back"):
        lm = make_latency_model(
            cfg, itype, model_id="llama3.2-1b", source="profile",
            profile=str(tmp_path / "absent"),
        )
    assert type(lm) is LatencyModel


def test_make_latency_model_rejects_unknown_source():
    cfg = get_config("llama3.2-1b")
    itype = CAT.instance_type("g5.48xlarge")
    with pytest.raises(ValueError, match="latency source"):
        make_latency_model(cfg, itype, model_id="llama3.2-1b",
                           source="vibes")


# ---------------------------------------------------------------------------
# spec wiring
# ---------------------------------------------------------------------------


def test_latency_spec_round_trip_and_validation():
    from repro.service import LatencySpec, SpecError, spec_from_dict

    spec = spec_from_dict({
        "name": "x", "model": "llama3.2-1b", "trace": "aws-1",
        "latency": {"source": "profile", "profile": "some/dir"},
    })
    assert spec.latency == LatencySpec(source="profile",
                                       profile="some/dir")
    assert spec_from_dict(spec.to_dict()) == spec
    with pytest.raises(SpecError, match="latency.source"):
        spec_from_dict({
            "name": "x", "model": "llama3.2-1b", "trace": "aws-1",
            "latency": {"source": "vibes"},
        })
    with pytest.raises(SpecError, match="unknown keys"):
        spec_from_dict({
            "name": "x", "model": "llama3.2-1b", "trace": "aws-1",
            "latency": {"src": "roofline"},
        })


def test_builder_injects_profiled_model(tmp_path):
    from repro.service import spec_from_dict
    from repro.service.builder import build_service

    itype = CAT.instance_type("g5.48xlarge")
    table = ProfileTable(jax_version="0", backend="tpu", mode="compiled")
    table.add(_entry(accel=itype.accelerator))
    path = str(tmp_path / "p.json")
    table.save(path)

    base = {
        "name": "x", "model": "llama3.2-1b", "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "workload": {"kind": "poisson", "rate_per_s": 0.5},
        "sim": {"duration_hours": 1.0},
    }
    for engine in ("vector", "legacy"):
        spec = spec_from_dict({
            **base,
            "latency": {"source": "profile", "profile": path},
            "sim": {"duration_hours": 1.0, "engine": engine},
        })
        sim = build_service(spec).simulator
        assert isinstance(sim.latency_model, ProfiledLatencyModel), engine
        assert sim.latency_model.mfu_prefill == 0.31


def test_profiled_model_dataclass_provenance():
    cfg = get_config("llama3.2-1b")
    itype = CAT.instance_type("g5.48xlarge")
    lm = ProfiledLatencyModel.from_entry(
        cfg, itype, _entry(accel=itype.accelerator), path="p.json"
    )
    d = dataclasses.asdict(lm)
    assert d["profile_path"] == "p.json"
    assert d["profile_backend"] == "tpu"
    assert d["mfu_prefill"] == 0.31 and d["mbu_decode"] == 0.55

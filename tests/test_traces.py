"""Spot-trace substrate: replay format + the Fig. 3/4/5 statistics."""

import json
import os

import numpy as np
import pytest

from repro.cluster.traces import (
    SpotTrace,
    TraceLibrary,
    load_trace,
    synth_correlated_trace,
)


@pytest.fixture(scope="module")
def lib():
    return TraceLibrary()


def _regions_of(zones):
    return [
        z.rsplit("-", 1)[0] if (z[-1].isdigit() or z[-2] == "-") else z[:-1]
        for z in zones
    ]


def test_datasets_exist(lib):
    assert set(lib.names()) >= {"aws-1", "aws-2", "aws-3", "gcp-1",
                                "cpu-ref"}


def test_trace_shapes(lib):
    tr = lib.get("aws-1")
    assert tr.cap.shape == (tr.steps, len(tr.zones))
    assert tr.duration_s == tr.steps * tr.dt


def test_capacity_lookup(lib):
    tr = lib.get("gcp-1")
    z = tr.zones[0]
    assert tr.capacity(z, 0.0) == int(tr.cap[0, 0])
    assert tr.capacity(z, tr.duration_s + 999) == int(tr.cap[-1, 0])


def test_gpu_volatility_vs_cpu(lib):
    """Fig. 4: spot GPUs far less available than spot CPUs."""
    gpu = lib.get("gcp-1")
    cpu = lib.get("cpu-ref")
    gpu_avail = np.mean([gpu.availability(z) for z in gpu.zones])
    cpu_avail = np.mean([cpu.availability(z) for z in cpu.zones])
    assert cpu_avail > 0.95
    assert gpu_avail < 0.85


def test_intra_region_correlation_exceeds_inter(lib):
    """Fig. 3c: preemptions correlate within a region, not across."""
    tr = lib.get("aws-3")
    corr = tr.zone_correlation()
    regions = _regions_of(tr.zones)
    intra, inter = [], []
    for i in range(len(tr.zones)):
        for j in range(i + 1, len(tr.zones)):
            (intra if regions[i] == regions[j] else inter).append(
                corr[i, j]
            )
    assert np.mean(intra) > 0.15
    assert np.mean(intra) > 3 * abs(np.mean(inter))


def test_region_dropout_rate(lib):
    """§2.2: AWS-2 sees whole-region dropout ~33% of the time."""
    tr = lib.get("aws-2")
    all_down = (tr.cap == 0).all(axis=1).mean()
    assert 0.2 < all_down < 0.45


def test_availability_grows_with_search_space(lib):
    """Fig. 5: union availability rises as zones/regions are added."""
    tr = lib.get("aws-3")
    one = (tr.cap[:, :1] > 0).any(axis=1).mean()
    three = (tr.cap[:, :3] > 0).any(axis=1).mean()
    all_z = (tr.cap > 0).any(axis=1).mean()
    assert one < three < all_z
    assert all_z > 0.95


def test_roundtrip_npz(tmp_path, lib):
    tr = lib.get("gcp-1")
    path = os.path.join(tmp_path, "t.npz")
    tr.save(path)
    back = SpotTrace.load(path)
    assert back.zones == tr.zones
    assert np.array_equal(back.cap, tr.cap)


def test_json_format(tmp_path):
    path = os.path.join(tmp_path, "t.json")
    with open(path, "w") as f:
        json.dump(
            {"dt": 60, "zones": ["a", "b"], "cap": [[1, 0], [2, 2]]}, f
        )
    tr = load_trace(path)
    assert tr.capacity("b", 61.0) == 2


def test_slice_zones(lib):
    tr = lib.get("aws-3")
    sub = tr.slice_zones(tr.zones[:2])
    assert sub.cap.shape[1] == 2


def test_synth_determinism():
    zones = ["r1a", "r1b", "r2a"]
    zmap = {"r1a": "r1", "r1b": "r1", "r2a": "r2"}
    a = synth_correlated_trace(zones, zmap, steps=500, seed=3)
    b = synth_correlated_trace(zones, zmap, steps=500, seed=3)
    assert np.array_equal(a.cap, b.cap)

"""Golden regression tests: exact fixed-seed end-to-end metrics.

These pin the complete serving pipeline — synthetic aws-1 trace, policy,
cluster FSM, autoscaler, LB, vectorized engine, billing — to the exact
numbers produced at the time this file was written.  Every stage is
seed-deterministic and uses plain IEEE-754 double arithmetic, so any
diff here means a semantic change to the pipeline, not noise.  If a
change is *intended*, rerun the scenario and update the constants in the
same commit (the diff then documents the metric shift).

The spec runs the default engine ("vector"); the differential suite
(tests/test_differential.py) guarantees the legacy simulator produces
the same numbers.

Timeout-semantics note: queue expiry became RTT-inclusive
(``t - arrival + rtt > timeout``, matching the deadline long applied to
completed responses).  The constants below were re-verified after that
change and are *unchanged*: these cells serve same-geo clients whose RTT
is 2 ms, and no queued request sits within 2 ms of the 60 s timeout
boundary at any expiry check.  Cross-region scenarios (where the unified
deadline does shift counts) are covered in tests/test_jax_engine.py.
"""

import dataclasses

import pytest

from repro.service import Service, spec_from_dict


@dataclasses.dataclass(frozen=True)
class GoldenMetrics:
    n_requests: int
    n_completed: int
    n_failed: int
    n_preemptions: int
    n_launch_failures: int
    total_cost: float
    p50_s: float
    p99_s: float
    availability: float


# aws-1 @ 2h, poisson(0.5/s, seed 17), constant N_Tar=3, g5.48xlarge,
# concurrency 2, timeout 60s, drain 300s, sim seed 0
GOLDEN = {
    "spothedge": GoldenMetrics(
        n_requests=3571, n_completed=3501,
        n_failed=70, n_preemptions=1,
        n_launch_failures=0,
        total_cost=50.733135, p50_s=0.703607,
        p99_s=1.692754, availability=0.972917,
    ),
    "even_spread": GoldenMetrics(
        n_requests=3571, n_completed=3501,
        n_failed=70, n_preemptions=1,
        n_launch_failures=12,
        total_cost=28.109217, p50_s=0.703671,
        p99_s=1.692754, availability=0.920833,
    ),
    "ondemand_only": GoldenMetrics(
        n_requests=3571, n_completed=3501,
        n_failed=70, n_preemptions=0,
        n_launch_failures=0,
        total_cost=92.910000, p50_s=0.703671,
        p99_s=1.692754, availability=0.972917,
    ),
    # risk-aware SpotHedge (markov forecaster in the loop): identical
    # serving quality to vanilla spothedge on this calm aws-1 window at
    # ~15% lower cost — the forecast-calm buffer trim at work
    "risk_spothedge": GoldenMetrics(
        n_requests=3571, n_completed=3501,
        n_failed=70, n_preemptions=1,
        n_launch_failures=0,
        total_cost=43.052385, p50_s=0.703607,
        p99_s=1.692754, availability=0.972917,
    ),
}


def _spec(policy: str):
    d = {
        "name": f"golden-{policy}",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "replica_policy": {"name": policy},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 17},
        "sim": {"duration_hours": 2.0, "timeout_s": 60.0,
                "concurrency": 2, "drain_s": 300.0, "seed": 0},
    }
    if policy == "risk_spothedge":
        d["forecast"] = {"name": "markov"}
    return spec_from_dict(d)


@pytest.mark.parametrize("policy", sorted(GOLDEN))
def test_golden_end_to_end_metrics(policy):
    want = GOLDEN[policy]
    res = Service(_spec(policy)).run()
    assert res.n_requests == want.n_requests
    assert res.n_completed == want.n_completed
    assert res.n_failed == want.n_failed
    assert res.n_preemptions == want.n_preemptions
    assert res.n_launch_failures == want.n_launch_failures
    assert res.total_cost == pytest.approx(want.total_cost, abs=1e-6)
    assert res.pct(50) == pytest.approx(want.p50_s, abs=1e-6)
    assert res.pct(99) == pytest.approx(want.p99_s, abs=1e-6)
    assert res.availability == pytest.approx(want.availability, abs=1e-6)


def test_golden_byte_identical_with_explicit_roofline_source():
    """`latency: {source: roofline}` (the default, spelled out) must
    reproduce the golden metrics byte-for-byte — the profile subsystem
    must not perturb default-priced runs in any way."""
    want = GOLDEN["spothedge"]
    d = _spec("spothedge").to_dict()
    assert d["latency"] == {"source": "roofline"}
    res = Service(spec_from_dict(d)).run()        # explicit roofline
    base = Service(_spec("spothedge")).run()      # implicit default
    # bit-identical to the defaulted run...
    assert res.n_requests == base.n_requests
    assert res.n_completed == base.n_completed
    assert res.n_failed == base.n_failed
    assert res.total_cost == base.total_cost
    assert float(res.pct(50)) == float(base.pct(50))
    assert float(res.pct(99)) == float(base.pct(99))
    # ...and on the pinned golden numbers
    assert res.n_requests == want.n_requests
    assert res.total_cost == pytest.approx(want.total_cost, abs=1e-6)
    assert res.pct(50) == pytest.approx(want.p50_s, abs=1e-6)
    assert res.pct(99) == pytest.approx(want.p99_s, abs=1e-6)


def test_golden_token_mode_end_to_end_metrics():
    """Token-mode golden: the same aws-1 scenario priced by the
    continuous-batching engine (sim.replica_model: token).  Pins both the
    classic metrics and the token-level TTFT/TPOT/goodput surface; the
    request-level goldens above prove the opt-in changes nothing else."""
    d = _spec("spothedge").to_dict()
    d["serving"]["slo"] = {"ttft_s": 2.0, "tpot_s": 0.002}
    d["sim"]["replica_model"] = "token"
    res = Service(spec_from_dict(d)).run()
    assert res.n_requests == 3571
    assert res.n_completed == 3501
    assert res.n_failed == 70
    assert res.n_preemptions == 1
    assert res.total_cost == pytest.approx(50.733135, abs=1e-6)
    assert res.pct(50) == pytest.approx(0.704981, abs=1e-6)
    assert res.pct(99) == pytest.approx(1.701918, abs=1e-6)
    tok = res.token
    assert tok is not None and tok.n_recorded == 3501
    assert tok.ttft_pct(50) == pytest.approx(0.562341, abs=1e-6)
    assert tok.ttft_pct(99) == pytest.approx(1.052005, abs=1e-6)
    assert tok.tpot_pct(50) == pytest.approx(0.000739, abs=1e-6)
    assert tok.n_slo_ok == 3477
    assert tok.slo_attainment == pytest.approx(0.973677, abs=1e-6)
    assert tok.goodput_rps == pytest.approx(0.482917, abs=1e-6)


def test_golden_is_reproducible_within_process():
    """Two runs of the same spec are bit-identical (no hidden state)."""
    a = Service(_spec("spothedge")).run()
    b = Service(_spec("spothedge")).run()
    assert a.n_completed == b.n_completed
    assert a.n_failed == b.n_failed
    assert a.total_cost == b.total_cost
    assert a.pct(50) == b.pct(50) and a.pct(99) == b.pct(99)

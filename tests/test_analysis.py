"""Tests for repro.analysis — the repo-aware static invariant checker.

Each pass gets a known-bad fixture tree that must be flagged and a
known-good twin that must not; a pass that silently stopped firing
fails its bad-fixture test.  The final gate test runs the full checker
against this repository checkout and requires a clean (fully exempted)
report — the same bar CI enforces.
"""

import json
import os
import textwrap

import pytest

from repro.analysis import (
    AnalysisReport,
    ExemptionError,
    RULES,
    load_exemptions,
    rule_ids,
    run_analysis,
)
from repro.analysis.core import RepoContext
from repro.analysis.__main__ import main as analysis_main

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_RULES = (
    "determinism",
    "engine-parity",
    "silent-fallback",
    "spec-drift",
    "tracing-hazard",
)


def _write(root, rel, text):
    path = os.path.join(root, *rel.split("/"))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        f.write(textwrap.dedent(text))


def _findings(report, rule):
    return [f.finding for f in report.findings if f.finding.rule == rule]


def test_all_five_rules_registered():
    assert set(ALL_RULES) <= set(rule_ids())
    for rid in ALL_RULES:
        assert RULES[rid].description


# -- engine-parity -------------------------------------------------------

def _parity_tree(root, engine_body):
    _write(root, "src/repro/service/spec.py", """\
        import dataclasses

        @dataclasses.dataclass
        class SimSpec:
            timeout_s: float = 100.0
            concurrency: int = 4
    """)
    _write(root, "src/repro/serving/sim.py", """\
        class ServingSimulator:
            def run(self, spec):
                return spec.timeout_s + spec.concurrency
    """)
    _write(root, "src/repro/serving/engine.py", engine_body)


def test_engine_parity_flags_one_sided_field(tmp_path):
    root = str(tmp_path)
    # vector engine never consumes timeout_s -> parity violation
    _parity_tree(root, """\
        class VectorizedServingEngine:
            def run(self, spec):
                return spec.concurrency
    """)
    report = run_analysis(root, rules=["engine-parity"])
    found = _findings(report, "engine-parity")
    assert [f.symbol for f in found] == ["SimSpec.timeout_s"]
    assert found[0].path == "src/repro/service/spec.py"
    assert found[0].line > 0
    assert "legacy" in found[0].message


def test_engine_parity_clean_when_both_consume(tmp_path):
    root = str(tmp_path)
    _parity_tree(root, """\
        class VectorizedServingEngine:
            def run(self, spec):
                return spec.timeout_s * spec.concurrency
    """)
    report = run_analysis(root, rules=["engine-parity"])
    assert _findings(report, "engine-parity") == []


def test_engine_parity_silent_when_rule_disabled(tmp_path):
    root = str(tmp_path)
    _parity_tree(root, """\
        class VectorizedServingEngine:
            def run(self, spec):
                return spec.concurrency
    """)
    others = [r for r in ALL_RULES if r != "engine-parity"]
    report = run_analysis(root, rules=others)
    assert _findings(report, "engine-parity") == []
    assert not report.ok or True  # disabled rule must not leak findings


# -- determinism ---------------------------------------------------------

BAD_DETERMINISM = """\
    import time

    def stamp(results, done):
        started = time.time()
        out = [k for k in set(results) - set(done)]
        return started, out
"""

GOOD_DETERMINISM = """\
    import time

    def stamp(results, done, clock):
        started = clock.now()
        elapsed = time.perf_counter()
        out = [k for k in sorted(set(results) - set(done))]
        return started, elapsed, out
"""


def test_determinism_flags_wall_clock_and_set_iteration(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", BAD_DETERMINISM)
    report = run_analysis(root, rules=["determinism"])
    symbols = {f.symbol for f in _findings(report, "determinism")}
    assert "time.time" in symbols
    assert "set-iteration" in symbols


def test_determinism_clean_twin(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", GOOD_DETERMINISM)
    report = run_analysis(root, rules=["determinism"])
    assert _findings(report, "determinism") == []


def test_determinism_flags_repr_keys_and_unseeded_rng(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/experiments/tape.py", """\
        import json
        import numpy as np

        def tape_key(spec):
            return json.dumps(spec, default=repr)

        def jitter():
            rng = np.random.default_rng()
            return rng.random()

        def label(obj):
            return f"cell-{id(obj)}"
    """)
    report = run_analysis(root, rules=["determinism"])
    symbols = {f.symbol for f in _findings(report, "determinism")}
    assert "json.dumps" in symbols
    assert "default_rng" in symbols
    assert "id" in symbols


# -- tracing-hazard ------------------------------------------------------

def test_tracing_flags_backend_query_in_jit(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/kernels/k.py", """\
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def step(x, n):
            if jax.default_backend() == "cpu":
                return x
            return x * n
    """)
    report = run_analysis(root, rules=["tracing-hazard"])
    found = _findings(report, "tracing-hazard")
    assert any("default_backend" in f.message for f in found)
    assert all(f.symbol == "step" for f in found)


def test_tracing_clean_when_query_hoisted(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/kernels/k.py", """\
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("interpret",))
        def step(x, interpret):
            return x * 2

        def run(x):
            interpret = jax.default_backend() == "cpu"
            return step(x, interpret)
    """)
    report = run_analysis(root, rules=["tracing-hazard"])
    assert _findings(report, "tracing-hazard") == []


def test_tracing_follows_helpers_called_from_traced_bodies(tmp_path):
    root = str(tmp_path)
    # hazard is two calls deep: jit body -> helper -> .item()
    _write(root, "src/repro/serving/jaxengine/fastpath.py", """\
        import jax

        def _peek(x):
            return x.item()

        @jax.jit
        def step(x):
            return _peek(x) + 1
    """)
    report = run_analysis(root, rules=["tracing-hazard"])
    found = _findings(report, "tracing-hazard")
    assert any(f.symbol == "_peek" for f in found)


# -- silent-fallback -----------------------------------------------------

def test_silent_fallback_flags_warn_only_handler(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/loader.py", """\
        import warnings

        def load(path):
            try:
                return open(path).read()
            except OSError:
                warnings.warn(f"could not read {path}; using default")
                return ""
    """)
    report = run_analysis(root, rules=["silent-fallback"])
    found = _findings(report, "silent-fallback")
    assert [f.symbol for f in found] == ["warn-only-fallback"]


def test_silent_fallback_clean_with_counter(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/loader.py", """\
        import collections
        import warnings

        FALLBACK_COUNTS = collections.Counter()

        def load(path):
            try:
                return open(path).read()
            except OSError:
                FALLBACK_COUNTS[path] += 1
                warnings.warn(f"could not read {path}; using default")
                return ""
    """)
    report = run_analysis(root, rules=["silent-fallback"])
    assert _findings(report, "silent-fallback") == []


def test_silent_fallback_flags_swallowed_exception(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/cluster/util.py", """\
        def maybe(x):
            try:
                return x.compute()
            except Exception:
                return None
    """)
    report = run_analysis(root, rules=["silent-fallback"])
    found = _findings(report, "silent-fallback")
    assert [f.symbol for f in found] == ["swallowed-except"]


def test_silent_fallback_flags_announced_fallback_without_counter(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/pick.py", """\
        import warnings

        def pick(entry, default):
            if entry is None:
                warnings.warn("no entry; falling back to the default model")
                return default
            return entry
    """)
    report = run_analysis(root, rules=["silent-fallback"])
    found = _findings(report, "silent-fallback")
    assert [f.symbol for f in found] == ["pick"]


# -- spec-drift ----------------------------------------------------------

def _drift_tree(root, *, loader_mentions, example_mentions):
    _write(root, "src/repro/service/spec.py", """\
        import dataclasses

        @dataclasses.dataclass
        class SimSpec:
            duration_hours: float = 4.0
            shiny_knob: int = 3
    """)
    loader = "def load(d):\n    return d['duration_hours']\n"
    if loader_mentions:
        loader += "\n\ndef load2(d):\n    return d['shiny_knob']\n"
    _write(root, "src/repro/service/loader.py", loader)
    _write(root, "src/repro/service/builder.py",
           "def build(spec):\n    return spec\n")
    example = "sim:\n  duration_hours: 4.0\n"
    if example_mentions:
        example += "  # shiny_knob: 3\n"
    _write(root, "examples/service.yaml", example)


def test_spec_drift_flags_unhandled_and_undemonstrated(tmp_path):
    root = str(tmp_path)
    _drift_tree(root, loader_mentions=False, example_mentions=False)
    report = run_analysis(root, rules=["spec-drift"])
    found = _findings(report, "spec-drift")
    assert {f.symbol for f in found} == {"SimSpec.shiny_knob"}
    messages = " ".join(f.message for f in found)
    assert "loader/builder" in messages and "examples/" in messages


def test_spec_drift_clean_twin_commented_key_counts(tmp_path):
    root = str(tmp_path)
    # a commented '# shiny_knob: 3' line demonstrates the knob
    _drift_tree(root, loader_mentions=True, example_mentions=True)
    report = run_analysis(root, rules=["spec-drift"])
    assert _findings(report, "spec-drift") == []


# -- parse errors --------------------------------------------------------

def test_parse_error_becomes_finding(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/broken.py", "def f(:\n")
    report = run_analysis(root, rules=["determinism"])
    assert [f.finding.rule for f in report.findings] == ["parse-error"]
    assert not report.ok


# -- report schema -------------------------------------------------------

def test_report_round_trip(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", BAD_DETERMINISM)
    report = run_analysis(root, rules=["determinism"])
    assert not report.ok
    out = os.path.join(root, "artifacts", "analysis", "report.json")
    report.save(out)
    loaded = AnalysisReport.load(out)
    assert loaded.to_dict() == report.to_dict()
    assert loaded.n_active == report.n_active
    # byte-determinism: saving the loaded report reproduces the file
    out2 = os.path.join(root, "report2.json")
    loaded.save(out2)
    with open(out) as a, open(out2) as b:
        assert a.read() == b.read()


def test_report_schema_gate(tmp_path):
    with pytest.raises(ValueError, match="schema"):
        AnalysisReport.from_dict({"schema": 99, "findings": []})


def test_report_json_shape(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", GOOD_DETERMINISM)
    report = run_analysis(root, rules=["determinism"])
    d = report.to_dict()
    assert d["schema"] == 1
    assert d["tool"] == "repro.analysis"
    for key in ("rules", "n_files_scanned", "n_findings", "n_active",
                "n_exempted", "findings_by_rule", "findings",
                "unused_exemptions"):
        assert key in d


# -- exemptions ----------------------------------------------------------

def _exemptions_tree(root, entries):
    _write(root, "src/repro/serving/keys.py", BAD_DETERMINISM)
    doc = {"schema": 1, "exemptions": entries}
    _write(root, "analysis_exemptions.json", json.dumps(doc))


def test_exemption_silences_finding_and_records_justification(tmp_path):
    root = str(tmp_path)
    _exemptions_tree(root, [
        {"rule": "determinism", "path": "src/repro/serving/keys.py",
         "justification": "fixture: keys module is measurement-only"},
    ])
    report = run_analysis(root, rules=["determinism"])
    assert report.ok
    assert report.n_exempted > 0
    assert all(
        f.justification == "fixture: keys module is measurement-only"
        for f in report.findings
    )


def test_exemption_unknown_rule_errors(tmp_path):
    root = str(tmp_path)
    _exemptions_tree(root, [
        {"rule": "no-such-rule", "path": "src/repro/serving/keys.py",
         "justification": "x"},
    ])
    with pytest.raises(ExemptionError, match="unknown rule"):
        run_analysis(root, rules=["determinism"])


def test_exemption_stale_path_errors(tmp_path):
    root = str(tmp_path)
    _exemptions_tree(root, [
        {"rule": "determinism", "path": "src/repro/serving/gone.py",
         "justification": "x"},
    ])
    with pytest.raises(ExemptionError, match="does not exist"):
        run_analysis(root, rules=["determinism"])


def test_exemption_missing_justification_errors(tmp_path):
    root = str(tmp_path)
    _exemptions_tree(root, [
        {"rule": "determinism", "path": "src/repro/serving/keys.py"},
    ])
    with pytest.raises(ExemptionError, match="justification"):
        run_analysis(root, rules=["determinism"])


def test_exemption_unknown_key_errors(tmp_path):
    root = str(tmp_path)
    _exemptions_tree(root, [
        {"rule": "determinism", "path": "src/repro/serving/keys.py",
         "justification": "x", "reviewer": "me"},
    ])
    with pytest.raises(ExemptionError, match="unknown keys"):
        run_analysis(root, rules=["determinism"])


def test_unused_exemption_is_reported_and_fails_cli(tmp_path):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", GOOD_DETERMINISM)
    doc = {"schema": 1, "exemptions": [
        {"rule": "determinism", "path": "src/repro/serving/keys.py",
         "justification": "stale: nothing to exempt any more"},
    ]}
    _write(root, "analysis_exemptions.json", json.dumps(doc))
    report = run_analysis(root, rules=["determinism"])
    assert report.ok  # no active findings ...
    assert len(report.unused_exemptions) == 1  # ... but a stale entry
    rc = analysis_main(["--root", root, "--rules", "determinism",
                        "--out", "-"])
    assert rc == 1


# -- CLI -----------------------------------------------------------------

def test_cli_exit_codes_and_report_artifact(tmp_path, capsys):
    root = str(tmp_path)
    _write(root, "src/repro/serving/keys.py", BAD_DETERMINISM)
    rc = analysis_main(["--root", root, "--rules", "determinism",
                        "--format", "json"])
    assert rc == 1
    out = json.loads(capsys.readouterr().out)
    assert out["n_active"] > 0
    # default artifact path, resolved against --root
    assert os.path.isfile(
        os.path.join(root, "artifacts", "analysis", "report.json")
    )

    _write(root, "src/repro/serving/keys.py", GOOD_DETERMINISM)
    rc = analysis_main(["--root", root, "--rules", "determinism",
                        "--out", "-"])
    assert rc == 0
    assert "analysis: OK" in capsys.readouterr().out

    rc = analysis_main(["--root", root, "--rules", "no-such-rule",
                        "--out", "-"])
    assert rc == 2


def test_cli_list_rules(capsys):
    rc = analysis_main(["--list-rules"])
    assert rc == 0
    out = capsys.readouterr().out
    for rid in ALL_RULES:
        assert rid in out


def test_unknown_rule_raises():
    with pytest.raises(KeyError, match="no-such-rule"):
        run_analysis(REPO_ROOT, rules=["no-such-rule"])


# -- the gate: this repository must be clean -----------------------------

def test_repository_is_clean_under_all_rules():
    report = run_analysis(REPO_ROOT)
    assert sorted(report.rules) == sorted(rule_ids())
    active = [f.finding.location() for f in report.active]
    assert active == [], (
        "repo has non-exempted analysis findings:\n" + "\n".join(active)
    )
    assert not report.unused_exemptions, (
        "stale exemptions: " + ", ".join(
            f"{e.rule}@{e.path}" for e in report.unused_exemptions
        )
    )
    # every exemption that IS used carries a justification
    for f in report.findings:
        if f.exempted:
            assert f.justification.strip()


def test_repository_exemption_file_is_valid():
    ctx = RepoContext(REPO_ROOT)
    exemptions = load_exemptions(ctx, known_rules=rule_ids())
    assert exemptions, "repo exemption file should exist and have entries"
    for e in exemptions:
        assert e.justification.strip()

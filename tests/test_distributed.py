"""Sharding rules, checkpointing, ZeRO-1 axes, compression, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.compression import ef_quantize_tree
from repro.distributed.elastic import build_mesh, plan_remesh
from repro.distributed.sharding import logical_to_pspec, make_rules
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    lr_schedule,
    zero1_logical,
)


def mesh_2d():
    # 1x1 on this CPU — the rule logic is what's under test
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_tp_rules_shard_heads_and_mlp():
    mesh = mesh_2d()
    rules = make_rules("tp")
    spec = logical_to_pspec(("embed", "heads", "head_dim"),
                            (512, 16, 64), mesh, rules)
    assert spec == P(None, "model")
    spec = logical_to_pspec(("embed", "mlp"), (512, 2048), mesh, rules)
    assert spec == P(None, "model")


def test_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dict(make_rules("tp"))
    # force a 16-way virtual check by monkeypatching size via a fake mesh is
    # heavy; instead check the code path with a non-dividing dim on size-1
    # mesh (always divides) plus unit test of the rule table itself
    assert rules["heads"] == "model"
    assert rules["layers"] is None


def test_decode_cp_rules_no_duplicate_axes():
    rules = make_rules("decode_cp")
    assert rules["kv_seq"] == "model"
    assert rules["kv_heads"] is None     # prevents duplicate-axis specs


def test_missing_pod_axis_dropped():
    mesh = mesh_2d()          # no 'pod'
    rules = make_rules("tp")
    spec = logical_to_pspec(("batch", "seq"), (8, 128), mesh, rules)
    assert spec == P("data")


# ---------------------------------------------------------------------------
# ZeRO-1 logical rewrite
# ---------------------------------------------------------------------------


def test_zero1_takes_first_free_axis():
    logical = zero1_logical(("embed", "mlp"), (1024, 4096), data_size=16)
    assert logical == ("zero", "mlp")


def test_zero1_skips_tp_axes():
    logical = zero1_logical(("vocab", "embed"), (32000, 1024),
                            data_size=16)
    assert logical == ("vocab", "zero")


def test_zero1_nondividing_untouched():
    logical = zero1_logical((None,), (7,), data_size=16)
    assert logical == (None,)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    end = float(lr_schedule(cfg, jnp.asarray(100)))
    assert end == pytest.approx(0.1, abs=0.01)


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    _, opt2 = adamw_update(cfg, {"w": jnp.full(3, 100.0)}, opt, params)
    # first moment reflects the clipped gradient
    assert float(jnp.abs(opt2["m"]["w"]).max()) <= (1 - 0.9) * 1.0 + 1e-6


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree():
    return {
        "params": {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": {"c": jnp.ones((4,), jnp.bfloat16)}},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 10, tree["params"])
    assert latest_step(d) == 10
    restored, step = restore_checkpoint(d, tree)
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["a"],
                                  tree["params"]["a"])
    assert restored["params"]["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 1, tree["params"])
    # a stale tmp dir from a preempted writer must be ignored + GC'd
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1
    save_checkpoint(d, 3, tree["params"])
    assert latest_step(d) == 3
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_prunes_old(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, tree["params"], keep=2)
    steps = sorted(
        int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step")
    )
    assert steps == [4, 5]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"params": {"a": jnp.zeros((3, 3))}})


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------


def test_plan_remesh_shrinks_data_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = plan_remesh(mesh, surviving_chips=1)
    assert plan.new_shape == (1, 1)
    m2 = build_mesh(plan)
    assert m2.axis_names == ("data", "model")


def test_plan_remesh_rejects_impossible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        plan_remesh(mesh, surviving_chips=0)


# ---------------------------------------------------------------------------
# compression tree API
# ---------------------------------------------------------------------------


def test_ef_quantize_tree_roundtrip():
    g = {"a": jnp.linspace(-1, 1, 64), "b": jnp.zeros(8)}
    g_hat, err = ef_quantize_tree(g, None)
    assert g_hat["a"].shape == (64,)
    g_hat2, err2 = ef_quantize_tree(g, err)
    assert jnp.all(jnp.isfinite(err2["a"]))

import os
import sys

# src/ layout import path (tests run as PYTHONPATH=src pytest tests/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only the dry-run forces 512.

"""Omniscient ILP oracle (§3.3, Eq. 1-5)."""

import numpy as np
import pytest

from repro.cluster.simulator import run_policy_on_trace
from repro.cluster.traces import SpotTrace
from repro.core.omniscient import solve_omniscient


def flat_trace(cap_val=4, steps=40, zones=("us-west-2a", "us-east-2a")):
    cap = np.full((steps, len(zones)), cap_val, dtype=np.int32)
    return SpotTrace(zones=tuple(zones), cap=cap, dt=600.0, name="flat")


def test_prefers_spot_when_available():
    tr = flat_trace()
    sched = solve_omniscient(
        tr, n_target=2, cold_start_s=183.0, k_ratio=6.0,
        avail_target=0.9, bucket_s=600.0,
    )
    # plenty of spot capacity: no on-demand should appear
    assert sched.od_plan.sum() == 0
    assert (sched.spot_plan.sum(axis=1) >= 2).mean() >= 0.85


def test_falls_back_to_od_when_no_spot():
    zones = ("us-west-2a",)
    cap = np.zeros((40, 1), dtype=np.int32)
    tr = SpotTrace(zones=zones, cap=cap, dt=600.0, name="none")
    sched = solve_omniscient(
        tr, n_target=2, cold_start_s=183.0, k_ratio=6.0,
        avail_target=0.8, bucket_s=600.0,
    )
    assert sched.spot_plan.sum() == 0
    assert (sched.od_plan >= 2).mean() >= 0.7


def test_respects_capacity_constraint():
    zones = ("a1x", "b1x")
    cap = np.array([[1, 0]] * 30, dtype=np.int32)
    tr = SpotTrace(zones=zones, cap=cap, dt=600.0, name="c")
    sched = solve_omniscient(
        tr, n_target=3, cold_start_s=100.0, k_ratio=5.0,
        avail_target=0.8, bucket_s=600.0,
    )
    assert (sched.spot_plan[:, 0] <= 1).all()
    assert (sched.spot_plan[:, 1] == 0).all()
    # remaining capacity must come from OD in availability buckets
    assert sched.od_plan.max() >= 2


def test_availability_constraint_met():
    tr = flat_trace(cap_val=2)
    sched = solve_omniscient(
        tr, n_target=4, cold_start_s=183.0, k_ratio=6.0,
        avail_target=0.9, bucket_s=600.0,
    )
    assert sched.availability_ind.mean() >= 0.9


def test_cheaper_than_all_ondemand():
    tr = flat_trace()
    k = 6.0
    sched = solve_omniscient(
        tr, n_target=2, cold_start_s=183.0, k_ratio=k,
        avail_target=0.9, bucket_s=600.0,
    )
    od_cost = 2 * k * tr.steps       # N_Tar OD replicas every bucket
    assert sched.objective < od_cost * 0.5


def test_omniscient_runs_in_simulator():
    """End-to-end: the solved plan replays against the simulator."""
    from repro.cluster.traces import synth_correlated_trace

    zones = ["us-west-2a", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    tr = synth_correlated_trace(zones, zmap, steps=120, dt=60.0, seed=5,
                                max_capacity=4)
    res = run_policy_on_trace(
        "omniscient", tr, n_target=2, control_interval_s=60.0
    )
    assert res.availability > 0.5
    assert res.total_cost > 0

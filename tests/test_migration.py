"""Grace-period KV migration on preemption (repro.migration).

Covers the ISSUE-6 acceptance surface: drain/migrate/kill decision
boundaries (grace budget exhausted, target KV budget full, bandwidth
starvation, int8 rescue, NIC serialization), the transfer / elastic
re-shard cost model, ContinuousBatch KV injection, the runtime
executor, spec/loader plumbing (``migration:`` section, the
``sweep.migration`` axis, the ``preemption_warning_s`` trace override),
retried/lost-KV accounting symmetry across engines, the legacy-vs-
vector differential with migration ON, and the migration-off
byte-identical golden property (hypothesis).
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.cluster.catalog import (
    INTER_CLOUD_GBPS,
    INTRA_ZONE_GBPS,
    default_catalog,
    link_bandwidth_gbps,
)
from repro.cluster.simulator import ClusterSimulator, SimConfig
from repro.cluster.traces import SpotTrace, load_trace, synth_correlated_trace
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.migration import (
    INT8_KV_FACTOR,
    MigratedSeq,
    MigrationRuntime,
    MigrationSpec,
    ReshardCost,
    SeqState,
    TargetInfo,
    compression_factor,
    kv_transfer_bytes,
    kv_transfer_s,
    plan_preemption,
    plan_reshard,
)
from repro.serving.engine import VectorizedServingEngine
from repro.serving.latency import LatencyModel
from repro.serving.sim import ServingSimulator
from repro.serving.token import (
    ContinuousBatch,
    TokenEngineConfig,
    TokenSchedulerConfig,
)
from repro.service import SpecError, spec_from_dict
from repro.service.builder import build_service
from repro.workloads import make_workload

CAT = default_catalog()
CFG = get_config("llama3.2-1b")
ITYPE = CAT.instance_type("g5.48xlarge")
LM = LatencyModel.for_model(CFG, ITYPE)

# a hand-sized engine config so planner byte/second math is exact:
# 1 MB per KV token, 20 ms/decode-token, 1 ms/prefill-token
PCFG = TokenEngineConfig(
    weight_read_s=0.02,
    kv_read_s_per_token=0.0,
    prefill_s_per_token=0.001,
    overhead_s=0.0,
    iter_overhead_s=0.0,
    kv_budget_tokens=100_000,
    prefill_chunk_tokens=512,
    max_batch=1 << 30,
    kv_bytes_per_token=1e6,
)


def _gbps(g: float) -> float:
    return g * 1e9 / 8.0


def _seq(key, prompt=100, out=200, pref=100, dec=0, arrival=0.0):
    return SeqState(key, prompt, out, pref, dec, arrival, arrival,
                    float("nan"))


def _tgt(rid=0, headroom=50_000, gbps=10.0):
    return TargetInfo(rid, headroom, _gbps(gbps))


def _mini_trace(steps=180, seed=3):
    zones = ["us-west-2a", "us-west-2b", "us-east-2a"]
    zmap = {z: z[:-1] for z in zones}
    return synth_correlated_trace(zones, zmap, steps=steps, dt=60.0,
                                  seed=seed, max_capacity=4, name="mini")


# ---------------------------------------------------------------------------
# planner: drain/migrate/kill boundaries
# ---------------------------------------------------------------------------


def test_drain_when_remaining_work_fits_threshold():
    spec = MigrationSpec(enabled=True, drain_threshold_s=2.0)
    # fully prefilled, 50 decode tokens left -> 1.0s remaining <= 2.0s
    s = _seq(1, prompt=100, out=200, pref=100, dec=150)
    tgt = _tgt()
    [d] = plan_preemption([s], [tgt], 120.0, PCFG, spec)
    assert d.action == "drain"
    assert d.transfer_s == 0.0 and d.target_rid is None
    assert tgt.headroom_tokens == 50_000   # drains ship nothing


def test_drain_cap_is_min_of_threshold_and_grace():
    # same sequence, but the grace window undercuts the drain threshold
    spec = MigrationSpec(enabled=True, drain_threshold_s=2.0,
                         link_latency_s=0.0)
    s = _seq(1, prompt=100, out=200, pref=100, dec=150)  # 1.0s remaining
    [d] = plan_preemption([s], [_tgt()], 0.5, PCFG, spec)
    assert d.action != "drain"
    # zero threshold: nothing ever drains, even trivially-finished seqs
    spec0 = dataclasses.replace(spec, drain_threshold_s=0.0)
    [d0] = plan_preemption([s], [_tgt()], 120.0, PCFG, spec0)
    assert d0.action == "migrate"


def test_kill_when_no_target_has_kv_headroom():
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0)
    s = _seq(1, prompt=100, out=200, pref=100, dec=0)   # needs 300 tokens
    [d] = plan_preemption([s], [_tgt(headroom=299)], 120.0, PCFG, spec)
    assert d.action == "kill"
    [d2] = plan_preemption([s], [_tgt(headroom=300)], 120.0, PCFG, spec)
    assert d2.action == "migrate"


def test_kill_when_bandwidth_starved_cross_cloud():
    """1000 resident tokens x 1 MB = 1 GB.  Over the 1 Gbps inter-cloud
    tier that is 8s of wire time — too slow for a 5s grace window; over
    the 25 Gbps intra-zone tier it fits easily."""
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0,
                         link_latency_s=0.0)
    s = _seq(1, prompt=1000, out=2000, pref=1000, dec=0)
    [slow] = plan_preemption(
        [s], [_tgt(gbps=INTER_CLOUD_GBPS)], 5.0, PCFG, spec
    )
    assert slow.action == "kill"
    [fast] = plan_preemption(
        [s], [_tgt(gbps=INTRA_ZONE_GBPS)], 5.0, PCFG, spec
    )
    assert fast.action == "migrate"
    assert fast.transfer_s == pytest.approx(1e9 / _gbps(INTRA_ZONE_GBPS))


def test_int8_compression_rescues_a_transfer():
    # 1 GB over 1 Gbps = 8s > 5s grace uncompressed; int8 halves the
    # payload to 4s, which fits
    s = _seq(1, prompt=1000, out=2000, pref=1000, dec=0)
    none = MigrationSpec(enabled=True, drain_threshold_s=0.0,
                         compression="none", link_latency_s=0.0)
    [d] = plan_preemption([s], [_tgt(gbps=1.0)], 5.0, PCFG, none)
    assert d.action == "kill"
    int8 = dataclasses.replace(none, compression="int8")
    [d8] = plan_preemption([s], [_tgt(gbps=1.0)], 5.0, PCFG, int8)
    assert d8.action == "migrate"
    assert d8.transfer_s == pytest.approx(
        INT8_KV_FACTOR * 1e9 / _gbps(1.0)
    )


def test_transfers_serialize_on_source_nic():
    """Two 3s transfers against a 5s grace: the first (largest resident)
    ships, the second would finish at 6s > grace and is killed."""
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0,
                         link_latency_s=0.0)
    big = _seq(1, prompt=3000, out=4000, pref=3000, dec=0)
    small = _seq(2, prompt=2999, out=4000, pref=2999, dec=0)
    # 3 GB / (8 Gbps = 1e9 B/s) = 3s each
    ds = plan_preemption([small, big], [_tgt(gbps=8.0)], 5.0, PCFG, spec)
    by_key = {d.state.key: d for d in ds}
    assert by_key[1].action == "migrate"       # larger resident goes first
    assert by_key[1].resume_offset_s == pytest.approx(3.0)
    assert by_key[2].action == "kill"


def test_target_ranking_prefers_bandwidth_then_headroom():
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0,
                         link_latency_s=0.0)
    slow_roomy = _tgt(rid=0, headroom=50_000, gbps=5.0)
    fast_tight = _tgt(rid=1, headroom=700, gbps=25.0)
    a = _seq(1, prompt=200, out=300, pref=200, dec=100)   # need 500
    b = _seq(2, prompt=150, out=300, pref=150, dec=100)   # need 450
    ds = plan_preemption([a, b], [slow_roomy, fast_tight], 120.0, PCFG,
                         spec)
    by_key = {d.state.key: d for d in ds}
    # a (larger resident) takes the fast NIC; its reservation leaves only
    # 200 tokens of headroom there, so b falls back to the roomy target
    assert by_key[1].target_rid == 1
    assert by_key[2].target_rid == 0
    assert fast_tight.headroom_tokens == 200
    assert slow_roomy.headroom_tokens == 50_000 - 450


def test_migrate_threshold_tokens_gates_small_caches():
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0,
                         migrate_threshold_tokens=10_000)
    s = _seq(1, prompt=1000, out=2000, pref=1000, dec=0)
    [d] = plan_preemption([s], [_tgt()], 120.0, PCFG, spec)
    assert d.action == "kill"


def test_queued_sequence_with_no_kv_never_migrates():
    # resident 0 < default migrate_threshold_tokens (1): nothing to ship
    spec = MigrationSpec(enabled=True, drain_threshold_s=0.0)
    s = _seq(1, prompt=1000, out=2000, pref=0, dec=0)
    [d] = plan_preemption([s], [_tgt()], 120.0, PCFG, spec)
    assert d.action == "kill"


# ---------------------------------------------------------------------------
# cost model: transfer + elastic re-shard
# ---------------------------------------------------------------------------


def test_compression_factor():
    assert compression_factor("none") == 1.0
    assert compression_factor("int8") == INT8_KV_FACTOR == 0.5
    with pytest.raises(ValueError, match="compression"):
        compression_factor("fp4")


def test_kv_transfer_math():
    assert kv_transfer_bytes(1000, 163840.0) == pytest.approx(1.6384e8)
    assert kv_transfer_bytes(1000, 163840.0, "int8") == pytest.approx(
        0.5 * 1.6384e8
    )
    # zero payload costs only the link latency
    assert kv_transfer_s(0.0, _gbps(10.0), link_latency_s=0.05) == 0.05
    # a dead link never completes
    assert kv_transfer_s(1e9, 0.0) == math.inf
    assert kv_transfer_s(1e9, _gbps(8.0), link_latency_s=0.05) == (
        pytest.approx(0.05 + 1.0)
    )


def test_plan_reshard_shrinks_data_axis():
    rc = plan_reshard(
        (4, 2), ("data", "model"), 6,
        kv_resident_bytes=8e9, weight_bytes=70e9,
        bandwidth_bytes_per_s=_gbps(25.0), link_latency_s=0.05,
        relower_s=2.0,
    )
    assert rc.new_shape == (2, 2) and rc.dropped_chips == 4
    assert rc.new_chip_count == 4
    # data-parallel shrink replays only KV (weights already replicated)
    assert rc.moved_bytes == pytest.approx(8e9 * 0.5)
    assert rc.transfer_s == pytest.approx(0.05 + 4e9 / _gbps(25.0))
    assert rc.total_s == pytest.approx(rc.transfer_s + 2.0)


def test_plan_reshard_model_axis_moves_weights_too():
    rc = plan_reshard(
        (2, 4), ("data", "model"), 6, shrink_axis="model",
        kv_resident_bytes=8e9, weight_bytes=70e9,
        bandwidth_bytes_per_s=_gbps(25.0),
    )
    assert rc.new_shape == (2, 2)
    assert rc.moved_bytes == pytest.approx((8e9 + 70e9) * 0.5)


def test_plan_reshard_none_when_nothing_fits():
    assert plan_reshard(
        (1, 2), ("data", "model"), 1, bandwidth_bytes_per_s=_gbps(10.0)
    ) is None


def test_plan_reshard_validates_inputs():
    with pytest.raises(ValueError):
        plan_reshard((4, 2), ("data",), 4,
                     bandwidth_bytes_per_s=_gbps(10.0))
    with pytest.raises(ValueError):
        plan_reshard((4, 2), ("data", "model"), 4, shrink_axis="expert",
                     bandwidth_bytes_per_s=_gbps(10.0))


def test_reshard_cost_exports_remesh_plan():
    rc = plan_reshard(
        (4, 2), ("data", "model"), 6,
        bandwidth_bytes_per_s=_gbps(10.0),
    )
    plan = rc.to_remesh_plan()
    assert tuple(plan.old_shape) == (4, 2)
    assert tuple(plan.new_shape) == (2, 2)
    assert tuple(plan.axis_names) == ("data", "model")
    assert plan.dropped_chips == 4
    assert plan.new_chip_count == 4


def test_int8_kv_roundtrip_error_bound_on_real_shapes():
    """Quantize a real model's KV block (layers x 2 x kv-heads x T x
    head-dim from configs/) and bound the round-trip error by half a
    quantization step; the payload shrink matches INT8_KV_FACTOR."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.distributed.compression import dequantize_int8, quantize_int8

    shape = (CFG.num_layers, 2, CFG.num_kv_heads, 64,
             CFG.resolved_head_dim)
    kv = 3.0 * jax.random.normal(jax.random.PRNGKey(0), shape,
                                 dtype=jnp.float32)
    q, scale = quantize_int8(kv)
    assert q.dtype == jnp.int8
    rt = dequantize_int8(q, scale)
    err = float(jnp.max(jnp.abs(rt - kv.astype(jnp.float32))))
    assert err <= float(scale) / 2 + 1e-6
    # int8 payload vs the fp16 KV cache the cost model assumes
    fp16_bytes = kv.size * 2
    assert q.size / fp16_bytes == pytest.approx(INT8_KV_FACTOR)


# ---------------------------------------------------------------------------
# ContinuousBatch: migrated-KV injection
# ---------------------------------------------------------------------------


def _small_batch(**over):
    kw = dict(
        weight_read_s=0.02, kv_read_s_per_token=0.0,
        prefill_s_per_token=0.001, overhead_s=0.0, iter_overhead_s=0.0,
        kv_budget_tokens=10_000, prefill_chunk_tokens=512,
        max_batch=1 << 30, kv_bytes_per_token=1e6,
    )
    kw.update(over)
    return ContinuousBatch(TokenEngineConfig(**kw))


def test_enqueue_migrated_resumes_without_reprefill():
    mig = _small_batch()
    assert mig.enqueue_migrated(7, 500, 100, 0.0, 10.0, 500, 40, 2.0)
    assert mig.committed_tokens == 600
    [done] = mig.advance(1e9)
    assert done.key == 7
    assert done.first_token_s == 2.0          # preserved across the move
    # only the 60 remaining decode steps run — no prefill
    assert done.finish_s == pytest.approx(10.0 + 60 * 0.02)
    # a cold retry of the same request pays prefill + full decode
    fresh = _small_batch()
    fresh.enqueue(7, 500, 100, 0.0, 10.0)
    [redo] = fresh.advance(1e9)
    assert redo.finish_s > done.finish_s + 0.5 - 1e-9


def test_enqueue_migrated_respects_kv_budget():
    b = _small_batch(kv_budget_tokens=500)
    assert not b.enqueue_migrated(1, 400, 200, 0.0, 0.0, 400, 10, 1.0)
    assert b.committed_tokens == 0 and len(b.queue) == 0


def test_kill_counts_pending_migrated_kv_as_lost():
    b = _small_batch()
    b.enqueue_migrated(7, 500, 100, 0.0, 10.0, 500, 40, 2.0)
    kr = b.kill()                              # dies before admission
    assert 7 in kr.keys
    assert kr.lost_prefill_tokens == 500
    assert kr.lost_decode_tokens == 40


def test_remove_frees_reservation_and_rows():
    b = _small_batch()
    b.enqueue(1, 100, 50, 0.0, 0.0)
    b.enqueue(2, 100, 50, 0.0, 0.0)
    b.advance(0.2)                             # admit both, work underway
    assert b.reserved_tokens == 300
    b.remove([1])
    assert b.reserved_tokens == 150
    assert [row[0] for row in b.iter_states()] == [2]
    [done] = b.advance(1e9)
    assert done.key == 2


# ---------------------------------------------------------------------------
# runtime executor
# ---------------------------------------------------------------------------


def _inst(zone):
    class _I:
        pass

    i = _I()
    i.zone = zone
    z = CAT.zone(zone)
    i.region = z.region
    i.cloud = z.cloud
    return i


def test_runtime_executes_plan_and_accounts_savings():
    spec = MigrationSpec(enabled=True, drain_threshold_s=2.0,
                         link_latency_s=0.0)
    rt = MigrationRuntime(spec, PCFG)
    src = ContinuousBatch(PCFG)
    # seed exact progress: seq 1 is 0.1s from done (drains), seq 2 has
    # ~38s of decode left (migrates)
    src.enqueue_migrated(1, 100, 200, 0.0, 0.0, 100, 195, 1.0)
    src.enqueue_migrated(2, 1000, 2000, 0.0, 0.0, 1000, 100, 1.0)
    src.advance(1e-9)                          # admit both
    rows = {r[0]: r for r in src.iter_states()}
    assert rows[1][3] == 100 and rows[2][3] == 1000
    assert (200 - rows[1][4]) * PCFG.weight_read_s <= 2.0
    assert (2000 - rows[2][4]) * PCFG.weight_read_s > 2.0
    tgt = ContinuousBatch(PCFG)
    out = rt.execute_preemption(
        src, _inst("us-west-2a"),
        [(42, tgt, _inst("us-west-2b"))], now=100.0, grace_s=120.0,
    )
    assert [s.key for s in out.drained] == [1]
    assert [m.state.key for m in out.migrated] == [2]
    assert out.migrated[0].target_rid == 42
    resident2 = rows[2][3] + rows[2][4]
    assert out.migrated_kv_tokens == resident2
    assert out.saved_prefill_tokens == 100 + 1000
    assert out.transfer_s_total == pytest.approx(
        resident2 * 1e6 / _gbps(link_bandwidth_gbps(
            "aws", "us-west-2", "us-west-2a",
            "aws", "us-west-2", "us-west-2b",
        ))
    )
    assert out.recompute_saved_s == pytest.approx(
        out.saved_prefill_tokens * PCFG.prefill_s_per_token
        + out.saved_decode_tokens * PCFG.weight_read_s
    )
    # the migrated sequence is queued on the target with KV intact
    assert tgt.committed_tokens == 3000
    assert out.kill_report.n_batch == 0        # nothing was abandoned
    # the source batch is dead either way
    assert len(src.iter_states()) == 0


def test_runtime_requires_enabled_spec():
    with pytest.raises(ValueError, match="enabled"):
        MigrationRuntime(MigrationSpec(), PCFG)


def test_runtime_bandwidth_override_beats_locality():
    rt = MigrationRuntime(
        MigrationSpec(enabled=True, bandwidth_gbps=2.5), PCFG
    )
    bw = rt.bandwidth_bytes_per_s(_inst("us-west-2a"), _inst("us-east-2a"))
    assert bw == pytest.approx(_gbps(2.5))


# ---------------------------------------------------------------------------
# spec / loader / sweep plumbing
# ---------------------------------------------------------------------------


def _spec_dict(**over):
    d = {
        "name": "mig", "model": "llama3.2-1b", "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "replica_policy": {"name": "spothedge"},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 0.5, "seed": 17},
        "sim": {"duration_hours": 1.0, "timeout_s": 60.0,
                "drain_s": 300.0},
    }
    d.update(over)
    return d


def test_migration_spec_validation():
    with pytest.raises(ValueError, match="compression"):
        MigrationSpec(compression="fp4")
    with pytest.raises(ValueError):
        MigrationSpec(bandwidth_gbps=0.0)
    with pytest.raises(ValueError):
        MigrationSpec(drain_threshold_s=-1.0)
    with pytest.raises(ValueError):
        MigrationSpec(migrate_threshold_tokens=-1)
    s = MigrationSpec(enabled=True, compression="int8",
                      bandwidth_gbps=5.0)
    assert MigrationSpec(**s.to_dict()) == s


def test_migration_section_round_trip():
    d = _spec_dict(
        serving={"replica_model": "token"},
        migration={"enabled": True, "compression": "int8",
                   "drain_threshold_s": 2.0},
    )
    spec = spec_from_dict(d)
    assert spec.migration.enabled
    assert spec.migration.compression == "int8"
    assert spec.migration.drain_threshold_s == 2.0
    assert spec_from_dict(spec.to_dict()) == spec


def test_migration_requires_token_engine():
    d = _spec_dict(migration={"enabled": True})
    with pytest.raises(SpecError, match="token"):
        spec_from_dict(d)
    # a token entry in sweep.replica_models satisfies the requirement
    d = _spec_dict(
        migration={"enabled": True},
        sweep={"replica_models": ["request", "token"]},
    )
    assert spec_from_dict(d).migration.enabled


def test_loader_rejects_bad_migration_knobs_as_spec_errors():
    d = _spec_dict(serving={"replica_model": "token"},
                   migration={"enabled": True, "compression": "fp4"})
    with pytest.raises(SpecError, match="migration"):
        spec_from_dict(d)
    d = _spec_dict(serving={"replica_model": "token"},
                   migration={"enabled": True, "unknown_knob": 1})
    with pytest.raises(SpecError, match="unknown"):
        spec_from_dict(d)
    d = _spec_dict(serving={"replica_model": "token"},
                   sweep={"migration": ["yes"]})
    with pytest.raises(SpecError, match="sweep.migration"):
        spec_from_dict(d)


def test_sweep_migration_axis_expands_cells():
    from repro.experiments import ScenarioSuite

    d = _spec_dict(
        serving={"replica_model": "token"},
        migration={"enabled": False, "drain_threshold_s": 2.0},
        sweep={"migration": [False, True]},
    )
    suite = ScenarioSuite.from_spec(d)
    assert len(suite) == 2
    labels = sorted(sc.labels["migration"] for sc in suite.scenarios)
    assert labels == ["off", "on"]
    # the toggle inherits the base section's knobs
    for sc in suite.scenarios:
        assert sc.spec.migration.drain_threshold_s == 2.0
    # same tape across the axis (fair comparison)
    assert len({sc.tape_key for sc in suite.scenarios}) == 1


def test_sweep_migration_axis_collapses_for_request_cells():
    from repro.experiments import ScenarioSuite

    d = _spec_dict(sweep={
        "replica_models": ["request", "token"],
        "migration": [False, True],
    })
    suite = ScenarioSuite.from_spec(d)
    # request cells have no KV: the migration axis applies to token
    # cells only, and the request model keeps exactly one unlabeled cell
    per_model = {"request": 0, "token": 0}
    for sc in suite.scenarios:
        per_model[sc.labels["replica_model"]] += 1
    assert per_model == {"request": 1, "token": 2}
    for sc in suite.scenarios:
        if sc.labels["replica_model"] == "request":
            assert "migration" not in sc.labels
            assert sc.spec.migration is None


# ---------------------------------------------------------------------------
# satellite: preemption_warning_s trace override
# ---------------------------------------------------------------------------


def test_trace_warning_override_roundtrip(tmp_path):
    tr = dataclasses.replace(_mini_trace(steps=10),
                             preemption_warning_s=77.0)
    assert tr.preemption_warning_s == 77.0
    p = str(tmp_path / "tr.npz")
    tr.save(p)
    assert load_trace(p).preemption_warning_s == 77.0
    # None round-trips too (nan-encoded in the npz)
    p2 = str(tmp_path / "tr2.npz")
    _mini_trace(steps=10).save(p2)
    assert load_trace(p2).preemption_warning_s is None
    # zone slicing preserves the override
    assert tr.slice_zones(["us-west-2a"]).preemption_warning_s == 77.0
    with pytest.raises(ValueError):
        dataclasses.replace(tr, preemption_warning_s=-1.0)


def test_trace_warning_override_from_json(tmp_path):
    tr = _mini_trace(steps=6)
    d = {
        "zones": list(tr.zones),
        "dt": tr.dt,
        "cap": tr.cap.tolist(),
        "preemption_warning_s": 45,
    }
    p = tmp_path / "tr.json"
    p.write_text(json.dumps(d))
    assert load_trace(str(p)).preemption_warning_s == 45.0


def test_simulator_warning_lead_honors_override():
    tr = _mini_trace(steps=30)
    cfg = SimConfig(itype="g5.48xlarge")

    def lead(trace):
        sim = ClusterSimulator(trace, make_policy("spothedge"),
                               config=cfg)
        sim._deliver_warnings()
        return sim._warn_info["us-west-2a"][0]

    assert lead(tr) == CAT.cloud("aws").preemption_warning_s == 120.0
    assert lead(dataclasses.replace(tr, preemption_warning_s=300.0)) \
        == 300.0
    # the lead can never undercut the trace resolution
    assert lead(dataclasses.replace(tr, preemption_warning_s=10.0)) \
        == tr.dt == 60.0


def test_sim_spec_warning_override_reaches_trace():
    d = _spec_dict()
    d["sim"]["preemption_warning_s"] = 45.0
    spec = spec_from_dict(d)
    assert spec_from_dict(spec.to_dict()) == spec
    svc = build_service(spec)
    assert svc.trace.preemption_warning_s == 45.0
    # the named trace's cached copy must stay pristine
    assert load_trace("aws-1").preemption_warning_s is None
    d["sim"]["preemption_warning_s"] = -5.0
    with pytest.raises((SpecError, ValueError)):
        spec_from_dict(d)


# ---------------------------------------------------------------------------
# engine integration: accounting symmetry + migration differential
# ---------------------------------------------------------------------------


CFG35 = get_config("command-r-35b")   # ~10s service times: preempted
                                      # replicas actually hold KV


def _run_engine(cls, migration, *, steps=180, seed=3, rate=0.8,
                target=3):
    tr = _mini_trace(steps=steps, seed=seed)
    reqs = make_workload("poisson", rate_per_s=rate, seed=seed).generate(
        2 * 3600.0
    )
    sim = cls(
        tr, make_policy("spothedge"), reqs, CFG35, itype="g5.48xlarge",
        autoscaler=ConstantTarget(target), timeout_s=60.0,
        replica_model="token",
        token_scheduler=TokenSchedulerConfig(),
        migration=migration,
    )
    return sim.run(2 * 3600.0 + 600.0)


def test_engines_reject_migration_without_token_mode():
    tr = _mini_trace(steps=10)
    for cls in (ServingSimulator, VectorizedServingEngine):
        with pytest.raises(ValueError, match="token"):
            cls(tr, make_policy("spothedge"), [], CFG,
                itype="g5.48xlarge", autoscaler=ConstantTarget(2),
                migration=MigrationSpec(enabled=True))


def test_retried_and_lost_kv_accounting_symmetry():
    """Satellite 2: both engines report identical retried-request and
    lost-KV-token counts, with or without migration."""
    legacy = _run_engine(ServingSimulator, None)
    vector = _run_engine(VectorizedServingEngine, None)
    assert legacy.n_preemptions > 0
    assert vector.n_retried_requests == legacy.n_retried_requests
    assert vector.lost_kv_tokens == legacy.lost_kv_tokens
    assert legacy.lost_kv_tokens == (
        legacy.token.lost_prefill_tokens + legacy.token.lost_decode_tokens
    )
    for res in (legacy, vector):
        tok = res.token
        assert tok.n_drained_seqs == tok.n_migrated_seqs == 0
        assert tok.migrated_kv_tokens == tok.saved_prefill_tokens == 0


def test_migration_differential_legacy_vs_vector():
    """Acceptance: with migration ON, the two engines make identical
    drain/migrate/kill decisions and identical accounting."""
    mig = MigrationSpec(enabled=True, compression="int8",
                        drain_threshold_s=0.0)
    legacy = _run_engine(ServingSimulator, mig)
    vector = _run_engine(VectorizedServingEngine, mig)
    ltok, vtok = legacy.token, vector.token
    # the scenario actually exercises the migrate path
    assert legacy.n_preemptions > 0
    assert ltok.n_drained_seqs + ltok.n_migrated_seqs > 0
    for name in ("n_drained_seqs", "n_migrated_seqs",
                 "migrated_kv_tokens", "saved_prefill_tokens",
                 "saved_decode_tokens"):
        assert getattr(vtok, name) == getattr(ltok, name), name
    assert vtok.migration_transfer_s == pytest.approx(
        ltok.migration_transfer_s
    )
    assert vector.n_retried_requests == legacy.n_retried_requests
    assert vector.lost_kv_tokens == legacy.lost_kv_tokens
    assert vector.n_completed == legacy.n_completed
    assert vector.n_failed == legacy.n_failed
    np.testing.assert_allclose(
        np.sort(vector.latencies_s), np.sort(legacy.latencies_s),
        atol=1e-9, rtol=0,
    )
    np.testing.assert_allclose(
        np.sort(vtok.ttft_s), np.sort(ltok.ttft_s), atol=1e-9, rtol=0
    )


def test_migration_saves_reprefill_work():
    """With migration on, strictly less KV is re-prefetched than the
    kill-everything baseline loses (same tape, same trace)."""
    mig = MigrationSpec(enabled=True, compression="int8",
                        drain_threshold_s=2.0)
    off = _run_engine(VectorizedServingEngine, None)
    on = _run_engine(VectorizedServingEngine, mig)
    assert on.token.saved_prefill_tokens + on.token.n_drained_seqs > 0
    assert on.lost_kv_tokens < off.lost_kv_tokens
    assert on.n_requests == off.n_requests


# ---------------------------------------------------------------------------
# golden property: migration off == no migration section, byte-identical
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    _HAS_HYPOTHESIS = False

_BASELINE = {}


def _golden_run(migration):
    tr = _mini_trace(steps=60, seed=5)
    reqs = make_workload("poisson", rate_per_s=0.3, seed=5).generate(
        1800.0
    )
    sim = VectorizedServingEngine(
        tr, make_policy("spothedge"), reqs, CFG, itype="g5.48xlarge",
        autoscaler=ConstantTarget(2), timeout_s=60.0,
        replica_model="token", migration=migration,
    )
    return sim.run(3600.0)


def test_migration_disabled_section_is_inert():
    """Deterministic twin of the hypothesis property below, for
    environments without hypothesis: a disabled migration section —
    whatever its knobs — must not perturb a single byte."""
    base = _golden_run(None)
    for spec in (
        MigrationSpec(enabled=False),
        MigrationSpec(enabled=False, compression="int8",
                      drain_threshold_s=0.0, bandwidth_gbps=0.5),
    ):
        res = _golden_run(spec)
        assert res.n_completed == base.n_completed
        assert res.n_failed == base.n_failed
        assert res.total_cost == base.total_cost
        assert np.array_equal(res.latencies_s, base.latencies_s)
        assert np.array_equal(res.token.ttft_s, base.token.ttft_s)
        assert res.token.n_drained_seqs == res.token.n_migrated_seqs == 0
        assert res.n_retried_requests == base.n_retried_requests
        assert res.lost_kv_tokens == base.lost_kv_tokens


if _HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(
        drain=st.floats(0.0, 300.0, allow_nan=False),
        compression=st.sampled_from(("none", "int8")),
        bandwidth=st.one_of(st.none(), st.floats(0.1, 100.0,
                                                 allow_nan=False)),
    )
    def test_migration_disabled_is_byte_identical(drain, compression,
                                                  bandwidth):
        if "res" not in _BASELINE:
            _BASELINE["res"] = _golden_run(None)
        base = _BASELINE["res"]
        res = _golden_run(MigrationSpec(
            enabled=False, drain_threshold_s=drain,
            compression=compression, bandwidth_gbps=bandwidth,
        ))
        assert res.n_completed == base.n_completed
        assert res.n_failed == base.n_failed
        assert res.total_cost == base.total_cost
        assert np.array_equal(res.latencies_s, base.latencies_s)
        assert np.array_equal(res.token.ttft_s, base.token.ttft_s)
        assert res.token.n_drained_seqs == 0
        assert res.token.n_migrated_seqs == 0
        assert res.n_retried_requests == base.n_retried_requests
        assert res.lost_kv_tokens == base.lost_kv_tokens

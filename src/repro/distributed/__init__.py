"""Distribution: sharding rules, checkpointing, ZeRO-1, elastic re-mesh,
gradient compression."""

from repro.distributed.sharding import (
    RULESETS,
    logical_to_pspec,
    make_rules,
    param_shardings,
    shard_pytree_specs,
)

__all__ = [
    "RULESETS",
    "logical_to_pspec",
    "make_rules",
    "param_shardings",
    "shard_pytree_specs",
]

"""int8 error-feedback gradient compression.

Cross-pod gradient all-reduce is the dominant multi-pod collective for
train_4k (fp32 grads × params over a 2-pod DCN/ICI link).  Quantizing to
int8 with per-tensor scale cuts those bytes 4× while error feedback keeps
the *accumulated* quantization error in the optimizer state and re-injects
it next step (so compression error is O(1) over training, not O(steps)).

Usage inside a pjit-ed train step: grads are quantize→dequantize'd before
the optimizer; XLA then all-reduces the int8 representation across the
``pod`` axis (the dequant happens after the psum in the lowered module when
quantization is placed before the gradient reduction boundary — see
EXPERIMENTS.md §Perf for the measured collective-byte reduction).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(
    g: jax.Array, err: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback quantize one gradient leaf.

    Returns (g_hat, new_err) with g_hat = dequant(quant(g + err)) and
    new_err = (g + err) - g_hat."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    q, scale = quantize_int8(gf)
    g_hat = dequantize_int8(q, scale)
    new_err = gf - g_hat
    return g_hat.astype(g.dtype), new_err


def ef_quantize_tree(
    grads: Any, err_tree: Optional[Any]
) -> Tuple[Any, Any]:
    """Apply error-feedback int8 quantization leaf-wise."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if err_tree is None:
        errs = [None] * len(leaves)
    else:
        errs = treedef.flatten_up_to(err_tree)
    out = [ef_quantize(g, e) for g, e in zip(leaves, errs)]
    g_hat = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return g_hat, new_err


def compression_ratio(nbytes_fp32: int) -> float:
    """Bytes int8+scale / bytes fp32 (the 4x headline)."""
    return (nbytes_fp32 // 4 + 4) / max(nbytes_fp32, 1)

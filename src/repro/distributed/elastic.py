"""Elastic re-mesh: continue after losing (or gaining) capacity.

The SpotHedge failure unit is a whole replica (= one pod slice), but a
production fleet also wants *training* jobs to survive losing part of the
data-parallel axis: checkpoint, rebuild a smaller mesh, re-shard, resume.
``plan_remesh`` computes the new mesh shape from surviving chip count;
``reshard`` moves a pytree onto the new shardings (device_put handles the
all-gather/redistribute); the launch layer re-lowers the train step for the
new mesh (proved by the dry-run at both 256- and 512-chip meshes).

Policy: shrink the ``data`` axis first (gradient math is invariant to DP
size modulo batch), never the ``model`` axis (TP degree is baked into the
layer math only through divisibility, but re-sharding TP mid-run changes
per-chip layouts and is where SpotServe-style re-parallelization applies —
the TPU-idiomatic analogue is re-lowering with the new mesh, which the
dry-run exercises).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import param_shardings


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_chips: int

    @property
    def new_chip_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def plan_remesh(
    mesh: Mesh,
    surviving_chips: int,
    *,
    shrink_axis: str = "data",
) -> RemeshPlan:
    """Largest mesh of the same axis structure that fits the survivors,
    shrinking only ``shrink_axis`` (power-of-two steps)."""
    names = tuple(mesh.axis_names)
    shape = tuple(mesh.shape[a] for a in names)
    if shrink_axis not in names:
        raise ValueError(f"mesh has no axis {shrink_axis!r}")
    idx = names.index(shrink_axis)
    other = 1
    for i, s in enumerate(shape):
        if i != idx:
            other *= s
    new_dim = shape[idx]
    while new_dim > 1 and other * new_dim > surviving_chips:
        new_dim //= 2
    if other * new_dim > surviving_chips:
        raise ValueError(
            f"cannot fit mesh {shape} into {surviving_chips} chips by "
            f"shrinking {shrink_axis!r} alone"
        )
    new_shape = tuple(
        new_dim if i == idx else s for i, s in enumerate(shape)
    )
    return RemeshPlan(
        old_shape=shape,
        new_shape=new_shape,
        axis_names=names,
        dropped_chips=int(jax.numpy.prod(jax.numpy.array(shape)))
        - other * new_dim,
    )


def build_mesh(plan: RemeshPlan) -> Mesh:
    return jax.make_mesh(plan.new_shape, plan.axis_names)


def reshard(
    tree: Any,
    logical_tree: Any,
    abstract_tree: Any,
    new_mesh: Mesh,
    rules: Any,
) -> Any:
    """device_put a pytree onto shardings derived for the new mesh."""
    shardings = param_shardings(logical_tree, abstract_tree, new_mesh, rules)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )

"""Sharding: logical axes -> mesh axes, with divisibility-aware fallback.

The model zoo annotates every parameter and activation with *logical* axis
names (``repro.models.base``).  This module maps them onto the production
mesh (``pod``, ``data``, ``model``) through named rule tables:

* ``tp``        Megatron-style tensor parallelism inside a replica:
                heads / mlp / experts / vocab over ``model``; batch over
                (``pod``, ``data``); ZeRO-1 shards optimizer state over
                ``data``.
* ``tp_sp``     tp + sequence-parallel residual stream (activations' seq
                axis over ``model`` between blocks).
* ``decode_cp`` decode-time context parallelism: the KV-cache *sequence*
                axis is sharded over ``model`` (works for every kv_heads
                count, incl. paligemma's kv=1) and batch over ``data``.

A rule is dropped per-tensor-dimension when the dimension size does not
divide the mesh axis (e.g. paligemma's 8 heads on a 16-way ``model`` axis
fall back to replicated weights while its attention still context-
parallelizes).  This fallback is logged once per (axis, size) pair.
"""

from __future__ import annotations

import logging
from typing import Any, Dict, List, Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger(__name__)

# rule: logical axis name -> mesh axis name (or tuple of mesh axes) or None
Rules = Mapping[str, Any]

_TP_RULES: Dict[str, Any] = {
    # weights
    "embed": None,               # residual dim replicated (activations SP'd)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "layers": None,
    "experts": "model",          # EP: experts over the TP axis
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "act_embed": None,
    # optimizer state (ZeRO-1): leading param axis over data — handled in
    # training/optimizer.py via these names
    "zero": "data",
}

_TP_SP_RULES = dict(_TP_RULES)
_TP_SP_RULES.update({
    "seq": "model",              # sequence-parallel residual stream
})

_DECODE_CP_RULES = dict(_TP_RULES)
_DECODE_CP_RULES.update({
    "kv_seq": "model",           # context-parallel KV cache
    "kv_heads": None,            # seq takes the axis; heads replicated
    "heads": None,               # (mamba2 hybrid decode state heads too)
    "batch": ("pod", "data"),
})

RULESETS: Dict[str, Dict[str, Any]] = {
    "tp": _TP_RULES,
    "tp_sp": _TP_SP_RULES,
    "decode_cp": _DECODE_CP_RULES,
}


def make_rules(name: str, overrides: Optional[Rules] = None) -> Dict[str, Any]:
    rules = dict(RULESETS[name])
    if overrides:
        rules.update(overrides)
    return rules


def _present(mesh: Mesh, axis: Any) -> Any:
    """Drop mesh axes the mesh doesn't have (e.g. 'pod' on single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in mesh.axis_names else None


def _mesh_axis_size(mesh: Mesh, axis: Any) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


_warned: set = set()


def logical_to_pspec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Rules,
) -> P:
    """Build a PartitionSpec for one tensor, dropping non-dividing axes."""
    spec: List[Any] = []
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name is not None else None
        axis = _present(mesh, axis)
        if axis is not None:
            n = _mesh_axis_size(mesh, axis)
            if dim % n != 0:
                key = (name, axis if not isinstance(axis, list) else
                       tuple(axis), dim, n)
                # run-scoped counter (repro.obs): counted on every
                # occurrence even though the log line is deduplicated,
                # so callers can assert a mesh actually sharded what
                # they expected without cross-run bleed
                from repro.obs.registry import get_registry

                get_registry().inc(
                    "sharding_replication_fallback",
                    axis=str(name),
                    mesh_axis=str(key[1]),
                    dim=dim,
                    size=n,
                )
                if key not in _warned:
                    _warned.add(key)
                    logger.info(
                        "sharding fallback: logical axis %r (dim %d) does "
                        "not divide mesh axis %r (size %d); replicating",
                        name, dim, axis, n,
                    )
                axis = None
        spec.append(tuple(axis) if isinstance(axis, list) else axis)
    # trim trailing Nones for tidiness
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def shard_pytree_specs(
    logical_tree: Any,
    abstract_tree: Any,
    mesh: Mesh,
    rules: Rules,
) -> Any:
    """PartitionSpec pytree for (logical axes, shapes) trees."""
    return jax.tree_util.tree_map(
        lambda logical, ab: logical_to_pspec(logical, ab.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def param_shardings(
    logical_tree: Any,
    abstract_tree: Any,
    mesh: Mesh,
    rules: Rules,
) -> Any:
    """NamedSharding pytree (jit in_shardings for parameters)."""
    specs = shard_pytree_specs(logical_tree, abstract_tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs
    )


# ---------------------------------------------------------------------------
# Activation constraint helper (used inside model step functions when a mesh
# is active).  No-op outside a mesh context.
# ---------------------------------------------------------------------------


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical activation axes, best-effort."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:  # pragma: no cover
            return x
    except Exception:  # pragma: no cover - older jax
        return x
    rules = RULESETS["tp"]
    spec = logical_to_pspec(list(axes), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)

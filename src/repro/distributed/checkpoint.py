"""Sharded checkpoint save/restore with atomic manifests.

Layout:

    <dir>/step_000100/
        manifest.json        # pytree structure, shapes, dtypes, paths
        leaf_00000.npy ...   # one file per leaf (host-local shard gather)
    <dir>/step_000100.tmp/   # written first, atomically renamed

Restart semantics (fault tolerance): ``latest_step`` scans for the highest
*complete* checkpoint (manifest present = rename completed); partially
written ``.tmp`` dirs from a preempted writer are ignored and garbage-
collected on the next save.  ``restore`` re-shards onto whatever mesh the
restarted job runs with (elastic restart after capacity loss — see
``elastic.py``).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/f8 with numpy's dtype system
import numpy as np


def _flatten_with_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Optional[Any] = None,
    *,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Write params (+opt state) atomically; prune old checkpoints."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        # exotic dtypes (bfloat16, float8_*) round-trip as raw bytes; the
        # true dtype lives in the manifest
        np.save(
            os.path.join(tmp, fname),
            np.frombuffer(np.ascontiguousarray(arr).tobytes(), np.uint8),
        )
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit

    # prune
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    # gc any stale tmp dirs from preempted writers
    for d in os.listdir(directory):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    template: Any,
    *,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` ({"params":..,
    "opt_state":..?}); optionally placing leaves with ``shardings``
    (a matching pytree of NamedSharding) for elastic restarts."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_t, treedef = _flatten_with_paths(template)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]

    leaves = []
    for i, (key, leaf_t) in enumerate(flat_t):
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        raw = np.load(os.path.join(path, entry["file"]))
        arr = np.frombuffer(
            raw.tobytes(), dtype=np.dtype(entry["dtype"])
        ).reshape(entry["shape"])
        expected = tuple(np.shape(leaf_t)) if hasattr(leaf_t, "shape") \
            else tuple(leaf_t.shape)
        if tuple(arr.shape) != tuple(expected):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template "
                f"{expected}"
            )
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jnp.asarray(arr))
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves
    )
    return restored, step

"""Request arrival processes (§5.2 workloads).

* ``PoissonWorkload`` — homogeneous Poisson arrivals (λ = 0.15/s default).
* ``ArenaWorkload``   — Chatbot-Arena-like: bursty traffic with load
  fluctuation.  We model it as a Markov-modulated Poisson process (regimes
  with different rates, heavy-tailed regime durations) plus lognormal
  prompt/output token lengths — matching Fig. 11's bursty interarrival
  distribution and "varying output lengths".
* ``MAFWorkload``     — Microsoft Azure Functions-like: strong diurnal
  pattern with sharp invocation spikes (the serverless trace shape used by
  AlpaServe/SpotServe and this paper).

All workloads yield :class:`Request` records sorted by arrival time; token
lengths drive per-request compute cost in the serving simulator and the live
JAX engine alike.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, List, Mapping, Optional, Sequence, Union

import numpy as np

_req_ids = itertools.count()

# client_regions accepts {region: weight} or a bare region sequence
ClientRegions = Union[Mapping[str, float], Sequence[str]]


@dataclasses.dataclass
class Request:
    arrival_s: float
    prompt_tokens: int
    output_tokens: int
    id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    # filled in by the serving layer:
    client_region: str = "us-west-2"

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.output_tokens


class Workload:
    """Base class: generate requests over [0, duration_s).

    ``client_regions`` mixes request origins across regions — either a
    ``{region: weight}`` mapping or a bare region list (equal weights).
    The default (``None``) keeps the historical single-region behaviour
    (every request from ``us-west-2``) and, crucially, draws *nothing*:
    region assignment uses its own RNG stream derived from ``seed``, so
    arrival times and token lengths are bit-identical with and without a
    mixture — only the ``client_region`` fields differ.
    """

    name = "workload"

    def __init__(self, seed: int = 0,
                 client_regions: Optional[ClientRegions] = None) -> None:
        self.seed = seed
        self.client_regions: Optional[List[str]] = None
        self._region_probs: Optional[np.ndarray] = None
        if client_regions is not None:
            if isinstance(client_regions, Mapping):
                regions = list(client_regions)
                weights = [float(client_regions[r]) for r in regions]
            else:
                regions = list(client_regions)
                weights = [1.0] * len(regions)
            if not regions:
                raise ValueError("client_regions must name >= 1 region")
            if any(not r or not isinstance(r, str) for r in regions):
                raise ValueError(
                    f"client_regions entries must be non-empty region "
                    f"strings, got {regions!r}"
                )
            if any(w < 0 for w in weights) or sum(weights) <= 0:
                raise ValueError(
                    f"client_regions weights must be >= 0 and sum > 0, "
                    f"got {weights!r}"
                )
            self.client_regions = regions
            self._region_probs = (
                np.asarray(weights, dtype=np.float64) / sum(weights)
            )

    def _assign_regions(self, requests: List[Request]) -> List[Request]:
        """Stamp client regions from the mixture (no-op by default)."""
        if self.client_regions is None or not requests:
            return requests
        # independent stream: never perturbs the arrival/length draws
        rng = np.random.default_rng([int(self.seed) & 0x7FFFFFFF, 0xC119])
        picks = rng.choice(
            len(self.client_regions), size=len(requests),
            p=self._region_probs,
        )
        for req, k in zip(requests, picks):
            req.client_region = self.client_regions[int(k)]
        return requests

    def generate(self, duration_s: float) -> List[Request]:
        raise NotImplementedError

    # -- shared samplers -------------------------------------------------
    @staticmethod
    def _sample_lengths(
        rng: np.random.Generator, n: int,
        prompt_mu: float = 5.3, prompt_sigma: float = 1.0,
        out_mu: float = 5.0, out_sigma: float = 0.8,
        max_tokens: int = 2048,
    ) -> tuple:
        """Lognormal token lengths (Arena-like medians ~200/150 tokens)."""
        p = np.clip(
            rng.lognormal(prompt_mu, prompt_sigma, n).astype(int), 1,
            max_tokens,
        )
        o = np.clip(
            rng.lognormal(out_mu, out_sigma, n).astype(int), 1, max_tokens
        )
        return p, o


class PoissonWorkload(Workload):
    """Homogeneous Poisson arrivals (§5.2: λ = 0.15)."""

    name = "poisson"

    def __init__(self, rate_per_s: float = 0.15, seed: int = 0,
                 client_regions: Optional[ClientRegions] = None) -> None:
        super().__init__(seed, client_regions=client_regions)
        if rate_per_s <= 0:
            raise ValueError("rate must be positive")
        self.rate = float(rate_per_s)

    def generate(self, duration_s: float) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        n_expect = int(self.rate * duration_s * 1.3) + 16
        gaps = rng.exponential(1.0 / self.rate, n_expect)
        times = np.cumsum(gaps)
        times = times[times < duration_s]
        p, o = self._sample_lengths(rng, len(times))
        return self._assign_regions([
            Request(arrival_s=float(t), prompt_tokens=int(pi),
                    output_tokens=int(oi))
            for t, pi, oi in zip(times, p, o)
        ])


class ArenaWorkload(Workload):
    """Markov-modulated Poisson: bursty Chatbot-Arena-like traffic.

    Three regimes (quiet / normal / burst) with mean rates
    ``base_rate * (0.3, 1.0, 4.0)`` and exponential sojourn times.  The paper
    reports up to ~50× traffic spikes on real AI workloads [51]; bursts
    against quiet give ~13×, and spike minutes (drawn on top) reach ~50×.
    """

    name = "arena"

    REGIME_MULT = (0.4, 1.0, 2.0)
    REGIME_MEAN_S = (1800.0, 3600.0, 900.0)
    TRANSITION = np.array(
        [
            [0.0, 0.9, 0.1],
            [0.4, 0.0, 0.6],
            [0.1, 0.9, 0.0],
        ]
    )

    def __init__(self, base_rate_per_s: float = 0.3, seed: int = 0,
                 spike_prob: float = 0.002, spike_mult: float = 12.0,
                 client_regions: Optional[ClientRegions] = None) -> None:
        super().__init__(seed, client_regions=client_regions)
        self.base_rate = float(base_rate_per_s)
        self.spike_prob = float(spike_prob)
        self.spike_mult = float(spike_mult)

    def generate(self, duration_s: float) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        # 1) sample the regime path
        t, regime = 0.0, 1
        out: List[Request] = []
        while t < duration_s:
            sojourn = rng.exponential(self.REGIME_MEAN_S[regime])
            end = min(t + sojourn, duration_s)
            rate = self.base_rate * self.REGIME_MULT[regime]
            # 2) within the regime, Poisson arrivals minute-by-minute with
            #    occasional spike minutes (sharp bursts, Fig. 11a)
            seg = t
            while seg < end:
                seg_end = min(seg + 60.0, end)
                r = rate * (
                    self.spike_mult if rng.random() < self.spike_prob else 1.0
                )
                n = rng.poisson(r * (seg_end - seg))
                times = rng.uniform(seg, seg_end, n)
                p, o = self._sample_lengths(rng, n)
                out.extend(
                    Request(arrival_s=float(tt), prompt_tokens=int(pi),
                            output_tokens=int(oi))
                    for tt, pi, oi in zip(times, p, o)
                )
                seg = seg_end
            # 3) regime transition
            probs = self.TRANSITION[regime]
            regime = int(rng.choice(3, p=probs))
            t = end
        out.sort(key=lambda r: r.arrival_s)
        return self._assign_regions(out)


class MAFWorkload(Workload):
    """Azure-Functions-like diurnal workload with invocation spikes."""

    name = "maf"

    def __init__(self, base_rate_per_s: float = 0.25, seed: int = 0,
                 diurnal_depth: float = 0.8,
                 spike_prob_per_min: float = 0.004,
                 spike_mult: float = 20.0,
                 client_regions: Optional[ClientRegions] = None) -> None:
        super().__init__(seed, client_regions=client_regions)
        self.base_rate = float(base_rate_per_s)
        self.depth = float(diurnal_depth)
        self.spike_prob = float(spike_prob_per_min)
        self.spike_mult = float(spike_mult)

    def _rate(self, t: float) -> float:
        phase = 2.0 * math.pi * (t % 86400.0) / 86400.0
        return self.base_rate * (
            1.0 - self.depth * 0.5 * (1.0 + math.cos(phase))
            + self.depth
        )

    def generate(self, duration_s: float) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        out: List[Request] = []
        t = 0.0
        while t < duration_s:
            end = min(t + 60.0, duration_s)
            r = self._rate(t)
            if rng.random() < self.spike_prob:
                r *= self.spike_mult
            n = rng.poisson(r * (end - t))
            times = rng.uniform(t, end, n)
            # serverless-style shorter outputs
            p, o = self._sample_lengths(rng, n, out_mu=4.2)
            out.extend(
                Request(arrival_s=float(tt), prompt_tokens=int(pi),
                        output_tokens=int(oi))
                for tt, pi, oi in zip(times, p, o)
            )
            t = end
        out.sort(key=lambda r: r.arrival_s)
        return self._assign_regions(out)


_WORKLOADS = {
    "poisson": PoissonWorkload,
    "arena": ArenaWorkload,
    "maf": MAFWorkload,
}


def make_workload(name: str, **kwargs) -> Workload:
    if name not in _WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(_WORKLOADS)}")
    return _WORKLOADS[name](**kwargs)


def interarrival_stats(requests: List[Request]) -> dict:
    """Summary stats used by the Fig. 11 benchmark."""
    if len(requests) < 2:
        return {"n": len(requests)}
    times = np.array([r.arrival_s for r in requests])
    gaps = np.diff(times)
    return {
        "n": len(requests),
        "mean_gap_s": float(gaps.mean()),
        "p50_gap_s": float(np.percentile(gaps, 50)),
        "p99_gap_s": float(np.percentile(gaps, 99)),
        "cv": float(gaps.std() / max(gaps.mean(), 1e-9)),
        "peak_to_mean": float(
            np.histogram(times, bins=max(int(times[-1] // 60), 1))[0].max()
            / max(len(requests) / max(times[-1] / 60.0, 1e-9), 1e-9)
        ),
    }

"""Request workloads (§5.2): Poisson, Arena-like bursty, MAF-like diurnal."""

from repro.workloads.arrivals import (
    ArenaWorkload,
    MAFWorkload,
    PoissonWorkload,
    Request,
    Workload,
    make_workload,
)

__all__ = [
    "ArenaWorkload",
    "MAFWorkload",
    "PoissonWorkload",
    "Request",
    "Workload",
    "make_workload",
]

"""SpotHedge — the paper's policy (§3).

Three mechanisms, composed:

1. **Dynamic Placement (Alg. 1).**  Maintain ``Z_A`` (available zones) and
   ``Z_P`` (highly-preempting zones).  A preemption or failed launch in ``z``
   moves ``z → Z_P``; a successful ready launch moves ``z → Z_A``.  New spot
   replicas are drawn from ``Z_A``, excluding zones that already host spot
   replicas (the set ``C``) when possible, breaking ties by spot price.
   When ``|Z_A| < 2`` the lists are rebalanced (``Z_A ← Z_A + Z_P``), which
   prevents collapsing all placements onto one zone.

2. **Overprovisioning (§3.2).**  Target ``N_Tar(t) + N_Extra`` *spot*
   replicas.  The extra spot replicas are the cheap buffer that absorbs
   preemptions while replacements (spot or on-demand) cold-start.

3. **Dynamic Fallback (§3.2).**  Maintain
   ``O(t) = min(N_Tar, N_Tar + N_Extra − S_r(t))`` launched on-demand
   replicas.  On-demand replicas are scaled down as soon as enough spot
   replicas are *ready* — on-demand is the fallback, never the steady state.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.policy import (
    Action,
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Policy,
    Terminate,
    register_policy,
)


@register_policy
class SpotHedgePolicy(Policy):
    """The full SpotHedge policy."""

    name = "spothedge"

    def __init__(
        self,
        num_overprovision: int = 2,
        dynamic_ondemand_fallback: bool = True,
        # optional static floor of on-demand capacity (custom policy, §4)
        min_ondemand: int = 0,
        # launch at most this many spot replicas per zone per tick, so a
        # single tick cannot pile every replacement onto one zone
        max_launch_per_zone_per_tick: int = 2,
        # best-effort preemption warnings (§4 "Preemption handling"): treat
        # ready replicas in recently-warned zones as at-risk when sizing the
        # on-demand fallback.  0 disables.
        warning_ttl_s: float = 240.0,
    ) -> None:
        super().__init__()
        self.n_extra = int(num_overprovision)
        self.dynamic_fallback = bool(dynamic_ondemand_fallback)
        self.min_ondemand = int(min_ondemand)
        self.max_launch_per_zone_per_tick = int(max_launch_per_zone_per_tick)
        self.warning_ttl_s = float(warning_ttl_s)
        self._za: List[str] = []
        self._zp: List[str] = []
        self._warned: Dict[str, float] = {}   # zone -> warning time

    # ------------------------------------------------------------------
    def reset(self, zones, catalog, itype) -> None:
        super().reset(zones, catalog, itype)
        self._za = [z.name for z in zones]    # line 1: Z_A <- Z
        self._zp = []
        self._warned = {}

    # -- Alg. 1 event handlers -------------------------------------------
    def _move_to_zp(self, zone: str) -> None:
        if zone in self._za:
            self._za.remove(zone)
            self._zp.append(zone)
        # line 7-9: rebalance when Z_A thins out
        if len(self._za) < 2:
            self._za = self._za + self._zp
            self._zp = []

    def on_preemption(self, zone: str, now: float) -> None:
        # HANDLE-PREEMPTION(z)
        self._move_to_zp(zone)

    def on_launch_failure(self, zone: str, now: float) -> None:
        # A failed launch is evidence the zone is out of capacity — the
        # paper's Fig. 7 narrative moves zone 2 to Z_P on launch failure.
        super().on_launch_failure(zone, now)
        self._move_to_zp(zone)

    def on_ready(self, zone: str, now: float) -> None:
        # HANDLE-LAUNCH(z)
        if zone in self._zp:
            self._zp.remove(zone)
            self._za.append(zone)

    def on_warning(self, zone: str, now: float) -> None:
        if self.warning_ttl_s > 0:
            self._warned[zone] = now

    # -- SELECT-NEXT-ZONE (Alg. 1, line 17-23) -----------------------------
    def _zone_rank_key(self, zone: str, now: float) -> tuple:
        """Tie-break order among equally-loaded candidate zones.  Vanilla
        SpotHedge ranks by spot price; RiskAwareSpotHedgePolicy overrides
        this to rank by forecast preemption risk first."""
        return (self._spot_price(zone), zone)

    def _select_next_zone(
        self, current_counts: Dict[str, int], now: float
    ) -> str:
        enabled = set(self._zone_names())
        active = [z for z in self._za if z in enabled]
        if not active:
            # All enabled zones in Z_P — rebalance defensively.
            self._za = list(self._zone_names())
            self._zp = []
            active = list(self._za)
        # honor launch-failure cooldowns unless that empties the pool
        cooled = [z for z in active if self._cooled(z, now)]
        if cooled:
            active = cooled
        occupied = {z for z, c in current_counts.items() if c > 0}
        unoccupied = [z for z in active if z not in occupied]  # Z'_A = Z_A \ C
        pool = unoccupied if unoccupied else active
        # prioritize zones with fewer current spot placements, then price
        return min(
            pool,
            key=lambda z: (
                current_counts.get(z, 0),
                *self._zone_rank_key(z, now),
            ),
        )

    # -- the decision ----------------------------------------------------
    def _spot_goal(self, obs: Observation) -> int:
        """Launched-spot target S(t) + buffer.  Vanilla SpotHedge keeps a
        constant ``N_Tar + N_Extra``; RiskAwareSpotHedgePolicy modulates
        the buffer with the forecast (lean when calm, full when risky)."""
        return obs.n_target + self.n_extra

    def decide(self, obs: Observation) -> List[Action]:
        actions: List[Action] = []
        n_tar = obs.n_target
        spot_goal = self._spot_goal(obs)

        # 1) keep trying to reach N_Tar + N_Extra *launched* spot replicas
        counts = obs.spot_count_by_zone()
        to_launch = spot_goal - obs.s_launched
        # when every enabled zone recently failed, drop to a single probe
        # launch per tick ("the policy can additionally probe different
        # zones to maintain Z_P and Z_A" — §3.1)
        if to_launch > 1 and not any(
            self._cooled(z, obs.now) for z in self._zone_names()
        ):
            to_launch = 1
        launched_this_tick: Dict[str, int] = {}
        for _ in range(max(0, to_launch)):
            zone = self._select_next_zone(counts, obs.now)
            if (
                launched_this_tick.get(zone, 0)
                >= self.max_launch_per_zone_per_tick
                and len(self._za) > 1
            ):
                # spread replacements across remaining zones within a tick
                alt = dict(counts)
                alt[zone] = alt.get(zone, 0) + 10_000  # de-prioritize
                zone = self._select_next_zone(alt, obs.now)
            self._note(
                why="fill_spot_buffer",
                spot_goal=spot_goal,
                s_launched=obs.s_launched,
                zone_spot_count=counts.get(zone, 0),
                zone_rank=self._zone_rank_key(zone, obs.now),
            )
            actions.append(LaunchSpot(zone))
            counts[zone] = counts.get(zone, 0) + 1
            launched_this_tick[zone] = launched_this_tick.get(zone, 0) + 1

        # 2) scale down surplus spot (target shrank): newest-first,
        #    provisioning-first
        if to_launch < 0:
            surplus = -to_launch
            pool = sorted(
                obs.spot_provisioning, key=lambda i: -i.launched_at
            ) + sorted(obs.spot_ready, key=lambda i: -i.launched_at)
            for inst in pool[:surplus]:
                self._note(
                    why="shrink_spot_buffer",
                    spot_goal=spot_goal,
                    s_launched=obs.s_launched,
                    surplus=surplus,
                )
                actions.append(Terminate(inst.id))

        # 3) Dynamic Fallback: O(t) = min(N_Tar, N_Tar + N_Extra - S_r)
        #    Ready replicas in recently-warned zones are discounted from S_r
        #    (the §4 warning extension) so the fallback launches *before*
        #    the preemption lands, shaving one cold start from the outage.
        s_r_eff = obs.s_r - self._at_risk_ready(obs)
        if self.dynamic_fallback:
            # spot_goal == n_tar + n_extra for vanilla SpotHedge.  The
            # risk-aware subclass may have trimmed the buffer — the
            # fallback must chase the trimmed goal or it would backfill
            # every trimmed spot replica with on-demand — but a *surged*
            # goal is spot-only insurance and must not leak into O(t),
            # hence the cap at the vanilla goal.
            od_goal = min(spot_goal, n_tar + self.n_extra)
            od_needed = min(n_tar, od_goal - s_r_eff)
            od_needed = max(od_needed, self.min_ondemand, 0)
        else:
            od_needed = self.min_ondemand
        gap = od_needed - obs.o_launched
        if gap > 0:
            zone = self._cheapest_od_zone()
            for _ in range(gap):
                self._note(
                    why="od_fallback",
                    od_needed=od_needed,
                    s_r=obs.s_r,
                    at_risk_ready=obs.s_r - s_r_eff,
                    n_target=n_tar,
                )
                actions.append(LaunchOnDemand(zone))
        elif gap < 0:
            od_terms = self._scale_down_od(obs, od_needed)
            for _ in od_terms:
                self._note(
                    why="shrink_od_fallback",
                    od_needed=od_needed,
                    o_launched=obs.o_launched,
                    s_r=obs.s_r,
                )
            actions.extend(od_terms)
        return actions

    # -- at-risk accounting (overridden by the risk-aware subclass) --------
    def _at_risk_ready(self, obs: Observation) -> int:
        """Ready spot replicas to discount from S_r when sizing the
        on-demand fallback.  Vanilla SpotHedge counts replicas in
        recently-warned zones; RiskAwareSpotHedgePolicy adds replicas in
        zones whose *forecast* preemption risk crosses its threshold."""
        self._warned = {
            z: t0
            for z, t0 in self._warned.items()
            if obs.now - t0 <= self.warning_ttl_s
        }
        return sum(
            1 for inst in obs.spot_ready if inst.zone in self._warned
        )

    # -- introspection (used by tests + dashboards) ------------------------
    @property
    def available_zones(self) -> List[str]:
        return list(self._za)

    @property
    def preempting_zones(self) -> List[str]:
        return list(self._zp)

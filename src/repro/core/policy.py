"""Policy interface shared by the simulator and the live service controller.

The controller exposes the *observable* cluster state to the policy once per
control interval; the policy returns a list of actions (launch spot in zone z,
launch on-demand, terminate instance i).  Event hooks deliver preemption /
ready / launch-failure transitions between ticks, which is what Alg. 1 keys
off.  A policy never sees the future of the trace — only the Omniscient
oracle (offline ILP) does.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING, Union

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.catalog import Catalog, Zone
    from repro.cluster.instance import Instance


# ---------------------------------------------------------------------------
# Actions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LaunchSpot:
    zone: str


@dataclasses.dataclass(frozen=True)
class LaunchOnDemand:
    zone: str


@dataclasses.dataclass(frozen=True)
class Terminate:
    instance_id: int


#: The controller contract: a policy's ``decide`` returns a list of these.
Action = Union[LaunchSpot, LaunchOnDemand, Terminate]


# ---------------------------------------------------------------------------
# Controller events
# ---------------------------------------------------------------------------


class EventKind(enum.Enum):
    """Cluster transitions delivered to the policy between control ticks."""

    PREEMPTION = "preemption"
    LAUNCH_FAILURE = "launch_failure"
    READY = "ready"
    WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class ControllerEvent:
    """A structured cluster transition (preempt / launch-fail / ready /
    preemption-warning) as the controller observed it.

    ``instance_id`` is set when the event concerns a specific instance
    (preemption, ready); zone-level events (launch failure, warning) leave
    it ``None``.
    """

    kind: EventKind
    zone: str
    now: float
    instance_id: Optional[int] = None


# ---------------------------------------------------------------------------
# Observation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Observation:
    """What the controller can see at time ``now`` (no future knowledge)."""

    now: float
    n_target: int                     # N_Tar(t) — from the autoscaler
    spot_ready: List["Instance"]
    spot_provisioning: List["Instance"]
    od_ready: List["Instance"]
    od_provisioning: List["Instance"]

    # -- derived -----------------------------------------------------------
    @property
    def s_r(self) -> int:
        """S_r(t): number of ready spot replicas."""
        return len(self.spot_ready)

    @property
    def s_launched(self) -> int:
        """S(t): launched (ready + provisioning) spot replicas."""
        return len(self.spot_ready) + len(self.spot_provisioning)

    @property
    def o_r(self) -> int:
        return len(self.od_ready)

    @property
    def o_launched(self) -> int:
        return len(self.od_ready) + len(self.od_provisioning)

    @property
    def ready_total(self) -> int:
        return self.s_r + self.o_r

    def spot_count_by_zone(self) -> Dict[str, int]:
        """Active (ready+provisioning) spot replicas per zone — the set C
        that SELECT-NEXT-ZONE avoids re-using (Alg. 1 line 18)."""
        counts: Dict[str, int] = {}
        for inst in self.spot_ready + self.spot_provisioning:
            counts[inst.zone] = counts.get(inst.zone, 0) + 1
        return counts


# ---------------------------------------------------------------------------
# Policy base class
# ---------------------------------------------------------------------------


class Policy:
    """Base class.  Subclasses implement ``decide`` and the event hooks."""

    name: str = "policy"

    #: after a failed spot launch, avoid retrying the same zone for this long
    #: (real controllers back off; probing still happens — see SpotHedge).
    launch_cooldown_s: float = 90.0

    def __init__(self) -> None:
        self._zones: List["Zone"] = []
        self._catalog: Optional["Catalog"] = None
        self._itype: str = ""
        self._fail_at: Dict[str, float] = {}
        # machine-readable decision reasons, one per action appended in
        # the current decide() call (repro.obs pairs them by index)
        self._reasons: List[Optional[Dict[str, object]]] = []

    # -- lifecycle -----------------------------------------------------
    def reset(
        self, zones: Sequence["Zone"], catalog: "Catalog", itype: str
    ) -> None:
        """Called once before the run with the *enabled* zone set (the user's
        ``any_of`` filter from Listing 1 already applied)."""
        self._zones = list(zones)
        self._catalog = catalog
        self._itype = itype
        self._fail_at = {}

    # -- event hooks (between control ticks) ----------------------------
    def on_event(self, event: ControllerEvent) -> None:
        """Structured event entry point: the controller delivers every
        cluster transition through here.  Dispatches to the per-kind hooks,
        which remain the subclass override points."""
        if event.kind is EventKind.PREEMPTION:
            self.on_preemption(event.zone, event.now)
        elif event.kind is EventKind.LAUNCH_FAILURE:
            self.on_launch_failure(event.zone, event.now)
        elif event.kind is EventKind.READY:
            self.on_ready(event.zone, event.now)
        elif event.kind is EventKind.WARNING:
            self.on_warning(event.zone, event.now)
        else:  # pragma: no cover - exhaustive over EventKind
            raise TypeError(f"unknown controller event {event!r}")

    def on_preemption(self, zone: str, now: float) -> None:
        """A spot replica in ``zone`` was preempted."""

    def on_launch_failure(self, zone: str, now: float) -> None:
        """A spot launch in ``zone`` failed (no capacity)."""
        self._fail_at[zone] = now

    def _cooled(self, zone: str, now: float) -> bool:
        """True if the zone is past its launch-failure cooldown."""
        return now - self._fail_at.get(zone, -1e18) >= self.launch_cooldown_s

    def on_ready(self, zone: str, now: float) -> None:
        """A spot replica in ``zone`` finished cold start and is ready."""

    def on_warning(self, zone: str, now: float) -> None:
        """Best-effort preemption warning received for an instance in zone."""

    # -- the decision --------------------------------------------------
    def decide(self, obs: Observation) -> List[Action]:
        raise NotImplementedError

    # -- decision reasons (observability) ------------------------------
    def _note(self, **reason: object) -> None:
        """Record the machine-readable *reason* for the action the policy
        is about to (or just did) append in ``decide``.

        Reasons pair with actions by position: call ``_note`` exactly
        once per appended action, in the same order.  Noting is pure
        bookkeeping — it must never draw RNG or change decisions, so
        golden metrics are identical whether or not anyone reads the
        reasons.
        """
        reasons = getattr(self, "_reasons", None)
        if reasons is None:  # subclass skipped Policy.__init__
            reasons = self._reasons = []
        reasons.append(dict(reason))

    def take_reasons(self) -> List[Optional[Dict[str, object]]]:
        """Drain the reasons noted during the last ``decide`` call.

        The controller calls this after every ``decide``; policies that
        never ``_note`` yield an empty list (reasons default to None).
        """
        reasons = getattr(self, "_reasons", None)
        if not reasons:
            return []
        out = list(reasons)
        reasons.clear()
        return out

    # -- shared helpers ---------------------------------------------------
    def _zone_names(self) -> List[str]:
        return [z.name for z in self._zones]

    def _spot_price(self, zone: str) -> float:
        assert self._catalog is not None
        return self._catalog.spot_price(self._itype, zone)

    def _od_price(self, zone: str) -> float:
        assert self._catalog is not None
        return self._catalog.od_price(self._itype, zone)

    def _cheapest_od_zone(self) -> str:
        """On-demand fallback zone: cheapest enabled zone (OD is assumed
        obtainable across regions — §5.1 Discussion)."""
        return min(self._zone_names(), key=lambda z: (self._od_price(z), z))

    @staticmethod
    def _scale_down_od(
        obs: Observation, od_needed: int
    ) -> List[Action]:
        """Terminate surplus on-demand replicas, provisioning-first (they
        have served no traffic yet), then newest-ready-first."""
        actions: List[Action] = []
        surplus = obs.o_launched - od_needed
        if surplus <= 0:
            return actions
        pool = sorted(
            obs.od_provisioning, key=lambda i: -i.launched_at
        ) + sorted(obs.od_ready, key=lambda i: -i.launched_at)
        for inst in pool[:surplus]:
            actions.append(Terminate(inst.id))
        return actions


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, type] = {}


def register_policy(cls: type) -> type:
    _REGISTRY[cls.name] = cls
    return cls


def _load_builtin() -> None:
    # Import for registration side effects.
    from repro.core import baselines as _b  # noqa: F401
    from repro.core import omniscient as _o  # noqa: F401
    from repro.core import risk_aware as _r  # noqa: F401
    from repro.core import spothedge as _s  # noqa: F401


def make_policy(name: str, **kwargs) -> Policy:
    """Instantiate a policy by its registered name (CLI / config entry)."""
    _load_builtin()
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def policy_class(name: str) -> type:
    """The registered class for ``name`` (builders peek at class flags
    like ``uses_forecast`` before instantiating)."""
    _load_builtin()
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_policies() -> List[str]:
    _load_builtin()
    return sorted(_REGISTRY)

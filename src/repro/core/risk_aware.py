"""Risk-aware SpotHedge: forecast-ranked placement + pre-emptive hedging.

Vanilla SpotHedge (§3.1) is reactive — a zone only enters ``Z_P`` after a
preemption or failed launch has already cost a replica and a cold start.
:class:`RiskAwareSpotHedgePolicy` keeps the full SpotHedge machinery
(``Z_A``/``Z_P``, overprovisioning, dynamic fallback) but consults a
:class:`repro.forecast.Forecaster` built from the same observation stream:

* **placement** — ``SELECT-NEXT-ZONE`` ranks candidate zones by forecast
  preemption risk (bucketed, so spot price still breaks near-ties)
  instead of price alone.  A zone whose siblings just went dark is
  avoided *before* it fails, not after.
* **pre-emptive fallback** — ready spot replicas in zones whose forecast
  preemption risk crosses ``risk_threshold`` are discounted from ``S_r``
  when sizing the on-demand fallback ``O(t)``, exactly like the §4
  warning extension but driven by the predictor, so the hedge launches a
  cold start *ahead* of a predicted availability collapse.

The forecaster sees what the policy sees: preemption / launch-failure /
ready events, plus a periodic "these zones host live ready replicas" row
sampled at the forecaster's observation cadence.  No trace future is ever
consulted — the policy stays causally fair against every baseline.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional

from repro.core.policy import (
    Action,
    ControllerEvent,
    Observation,
    register_policy,
)
from repro.core.spothedge import SpotHedgePolicy
from repro.forecast.base import Forecaster, ZoneForecast, make_forecaster

__all__ = ["RiskAwareSpotHedgePolicy"]


@register_policy
class RiskAwareSpotHedgePolicy(SpotHedgePolicy):
    """SpotHedge with a forecaster in the placement and hedging loop."""

    name = "risk_spothedge"
    #: the builder routes a spec's ``forecast:`` section into policies
    #: that declare this flag (others ignore the section)
    uses_forecast = True

    def __init__(
        self,
        forecaster: "str | Forecaster" = "markov",
        horizon_s: float = 450.0,
        risk_threshold: float = 0.6,
        # below this forecast risk in every occupied zone, the spot
        # overprovision buffer is trimmed (the cost the hedge spends
        # during predicted crunches is recouped during predicted calm)
        calm_threshold: float = 0.06,
        min_overprovision: Optional[int] = None,
        # extra *spot* replicas (cheap insurance, placed in forecast-safe
        # zones by the rank hook) added on top of N_Extra while any
        # occupied zone's risk crosses risk_threshold
        surge_overprovision: int = 1,
        forecaster_args: Optional[Mapping[str, object]] = None,
        # observation cadence fed to the forecaster: estimators express
        # their transition statistics per observation step, so throttling
        # keeps their per-step hazards calibrated even though the policy
        # ticks every few seconds
        obs_interval_s: float = 60.0,
        **spothedge_kwargs,
    ) -> None:
        super().__init__(**spothedge_kwargs)
        if horizon_s <= 0:
            raise ValueError(f"horizon_s must be positive, got {horizon_s}")
        for nm, v in (("risk_threshold", risk_threshold),
                      ("calm_threshold", calm_threshold)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be a probability, got {v}")
        if obs_interval_s <= 0:
            raise ValueError(
                f"obs_interval_s must be positive, got {obs_interval_s}"
            )
        if min_overprovision is None:
            # trim floor defaults to 1 but must never exceed the buffer
            # itself (overprovision: 0 is a legal vanilla knob)
            min_overprovision = min(1, self.n_extra)
        if not 0 <= min_overprovision <= self.n_extra:
            raise ValueError(
                f"min_overprovision must lie in [0, num_overprovision="
                f"{self.n_extra}], got {min_overprovision}"
            )
        if surge_overprovision < 0:
            raise ValueError(
                f"surge_overprovision must be >= 0, "
                f"got {surge_overprovision}"
            )
        if isinstance(forecaster, str):
            forecaster = make_forecaster(
                forecaster, **dict(forecaster_args or {})
            )
        elif forecaster_args:
            raise ValueError(
                "forecaster_args only applies when forecaster is a name"
            )
        self.forecaster = forecaster
        self.horizon_s = float(horizon_s)
        self.risk_threshold = float(risk_threshold)
        self.calm_threshold = float(calm_threshold)
        self.min_overprovision = int(min_overprovision)
        self.surge_overprovision = int(surge_overprovision)
        self.obs_interval_s = float(obs_interval_s)
        self._forecast: Dict[str, ZoneForecast] = {}
        self._last_obs_at = -1e18

    # -- lifecycle -------------------------------------------------------
    def reset(self, zones, catalog, itype) -> None:
        super().reset(zones, catalog, itype)
        self.forecaster.reset(
            [z.name for z in zones],
            {z.name: z.region for z in zones},
            dt=self.obs_interval_s,
        )
        self._forecast = {}
        self._last_obs_at = -1e18

    # -- observation plumbing --------------------------------------------
    def on_event(self, event: ControllerEvent) -> None:
        super().on_event(event)
        self.forecaster.observe_event(event)

    def _feed_forecaster(self, obs: Observation) -> None:
        """Periodic up-evidence: zones hosting ready spot replicas are
        demonstrably obtainable right now.  Zones with no presence stay
        unobserved — the estimators decay them toward their base rates."""
        if obs.now - self._last_obs_at < self.obs_interval_s:
            return
        up = {inst.zone for inst in obs.spot_ready}
        if up:
            self.forecaster.observe(obs.now, {z: True for z in up})
        self._last_obs_at = obs.now

    # -- SpotHedge hooks --------------------------------------------------
    def _select_next_zone(self, current_counts, now: float) -> str:
        # SELECT-NEXT-ZONE orders by current placement count before the
        # rank key, so risk alone cannot keep a launch out of a zone the
        # forecast says is about to collapse.  When a safe alternative
        # exists, push predicted-collapse zones to the back of the pool
        # (the same count-inflation trick the per-tick spread cap uses).
        if self._forecast:
            names = self._zone_names()
            risky = {
                z
                for z in names
                if (f := self._forecast.get(z)) is not None
                and f.p_preempt >= self.risk_threshold
            }
            if risky and any(z not in risky for z in names):
                alt = dict(current_counts)
                for z in risky:
                    alt[z] = alt.get(z, 0) + 10_000
                return super()._select_next_zone(alt, now)
        return super()._select_next_zone(current_counts, now)

    def _zone_rank_key(self, zone: str, now: float) -> tuple:
        f = self._forecast.get(zone)
        if f is None:
            return super()._zone_rank_key(zone, now)
        # bucket the risk so near-equal zones still compete on price
        return (
            round(f.p_preempt, 1),
            self._spot_price(zone),
            zone,
        )

    def _spot_goal(self, obs: Observation) -> int:
        """Forecast-modulated spot buffer.

        The buffer exists to absorb preemptions while replacements cold
        start.  Three regimes, judged by the forecast risk of the zones
        the fleet actually occupies:

        * **calm**  (every occupied zone below ``calm_threshold``) —
          most of that insurance is dead weight; trim the buffer to
          ``min_overprovision`` and bank the spot cost.
        * **risky** (any occupied zone at or above ``risk_threshold``) —
          add ``surge_overprovision`` *spot* replicas on top of
          ``N_Extra``.  The rank hook steers them into forecast-safe
          zones (typically another region), so the predicted crunch is
          absorbed by cheap spot launched *before* it lands, not by
          on-demand after.
        * otherwise — the vanilla ``N_Tar + N_Extra``.
        """
        base = obs.n_target + self.n_extra
        if not self._forecast:
            return base
        # risk of the fleet as placed: the zones hosting live replicas
        risks = [
            self._forecast[inst.zone].p_preempt
            for inst in obs.spot_ready + obs.spot_provisioning
            if inst.zone in self._forecast
        ]
        if not risks:
            return base
        if max(risks) >= self.risk_threshold:
            return base + self.surge_overprovision
        if (
            max(risks) < self.calm_threshold
            and self.n_extra > self.min_overprovision
        ):
            return obs.n_target + self.min_overprovision
        return base

    def _at_risk_ready(self, obs: Observation) -> int:
        warned = super()._at_risk_ready(obs)
        forecast_risk = sum(
            1
            for inst in obs.spot_ready
            if (f := self._forecast.get(inst.zone)) is not None
            and f.p_preempt >= self.risk_threshold
        )
        # only hedge when the predicted survivors cannot hold N_Tar —
        # losses the spot buffer can absorb are its job to absorb, and
        # hedging them anyway burns on-demand on false positives.  A
        # region-wide crunch (first preemption flips siblings into the
        # crunch bucket, their risk jumps) blows through the buffer and
        # opens the gate *before* the follow-on preemptions land.
        if obs.s_r - forecast_risk >= obs.n_target:
            forecast_risk = 0
        return max(warned, forecast_risk)

    # -- the decision ------------------------------------------------------
    def decide(self, obs: Observation) -> List[Action]:
        self._feed_forecaster(obs)
        self._forecast = self.forecaster.predict(obs.now, self.horizon_s)
        return super().decide(obs)

    # -- introspection -----------------------------------------------------
    @property
    def current_forecast(self) -> Dict[str, ZoneForecast]:
        """Latest per-zone forecast (empty before the first decide)."""
        return dict(self._forecast)

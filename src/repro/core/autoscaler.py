"""Load-based autoscaler with hysteresis (§4 "Autoscaler").

``N_Can = ceil(R_t / Q_Tar)`` where ``R_t`` is the average request rate over
a trailing window (default 60 s).  ``N_Tar`` only moves to ``N_Can`` after
the candidate has been consistently above (below) the current target for
``upscale_delay_s`` (``downscale_delay_s``) — the paper quotes ~10 minutes
of consistency before changing the target.
"""

from __future__ import annotations

import collections
import math
from typing import Deque, Optional, Tuple


class Autoscaler:
    """Interface: ``observe`` request arrivals, ``target`` returns N_Tar."""

    def observe(self, now: float, num_requests: int) -> None:
        raise NotImplementedError

    def observe_batch(self, events: "list[Tuple[float, int]]") -> None:
        """Record several ``(now, num_requests)`` observations at once.

        Equivalent to calling :meth:`observe` per event in order (events
        must be time-ordered); exists so hot loops can amortize the call
        overhead between target() reads.
        """
        for now, n in events:
            self.observe(now, n)

    def target(self, now: float) -> int:
        raise NotImplementedError


class ConstantTarget(Autoscaler):
    """Fixed N_Tar (used by the §5.2 policy benchmarks)."""

    def __init__(self, n_target: int) -> None:
        self.n_target = int(n_target)

    def observe(self, now: float, num_requests: int) -> None:
        pass

    def observe_batch(self, events: "list[Tuple[float, int]]") -> None:
        pass

    def target(self, now: float) -> int:
        return self.n_target


class LoadAutoscaler(Autoscaler):
    """The paper's QPS autoscaler with hysteresis."""

    def __init__(
        self,
        target_qps_per_replica: float,
        *,
        window_s: float = 60.0,
        upscale_delay_s: float = 300.0,
        downscale_delay_s: float = 1200.0,
        min_replicas: int = 1,
        max_replicas: int = 1_000,
        initial_target: Optional[int] = None,
    ) -> None:
        if target_qps_per_replica <= 0:
            raise ValueError("target_qps_per_replica must be positive")
        self.q_tar = float(target_qps_per_replica)
        self.window_s = float(window_s)
        self.upscale_delay_s = float(upscale_delay_s)
        self.downscale_delay_s = float(downscale_delay_s)
        self.min_replicas = int(min_replicas)
        self.max_replicas = int(max_replicas)
        self._events: Deque[Tuple[float, int]] = collections.deque()
        self._n_tar = int(initial_target or min_replicas)
        # time at which the candidate first diverged in the current direction
        self._diverged_since: Optional[float] = None
        self._diverge_dir = 0

    # ------------------------------------------------------------------
    def observe(self, now: float, num_requests: int) -> None:
        if num_requests > 0:
            self._events.append((now, num_requests))
        self._evict(now)

    def observe_batch(self, events: "list[Tuple[float, int]]") -> None:
        # eviction is idempotent and driven by `now`, so appending the
        # whole (time-ordered) batch and evicting once at the latest time
        # leaves the window in exactly the per-call state
        if events:
            self._events.extend(e for e in events if e[1] > 0)
            self._evict(events[-1][0])

    def _evict(self, now: float) -> None:
        # half-open window (now - window_s, now]
        while self._events and self._events[0][0] <= now - self.window_s:
            self._events.popleft()

    def _rate(self, now: float) -> float:
        self._evict(now)
        total = sum(n for _, n in self._events)
        return total / self.window_s

    def candidate(self, now: float) -> int:
        n_can = math.ceil(self._rate(now) / self.q_tar)
        return max(self.min_replicas, min(self.max_replicas, n_can))

    # ------------------------------------------------------------------
    def target(self, now: float) -> int:
        n_can = self.candidate(now)
        if n_can == self._n_tar:
            self._diverged_since, self._diverge_dir = None, 0
            return self._n_tar
        direction = 1 if n_can > self._n_tar else -1
        if direction != self._diverge_dir:
            self._diverged_since, self._diverge_dir = now, direction
            return self._n_tar
        assert self._diverged_since is not None
        held = now - self._diverged_since
        delay = (
            self.upscale_delay_s if direction > 0 else self.downscale_delay_s
        )
        if held >= delay:
            self._n_tar = n_can
            self._diverged_since, self._diverge_dir = None, 0
        return self._n_tar

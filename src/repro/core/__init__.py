"""SpotHedge — the paper's primary contribution — plus baselines and oracle.

Contents
--------
``policy``      Observation / Action / Policy interfaces shared by the
                simulator and the live serving controller.
``spothedge``   SpotHedge = Dynamic Placement (Alg. 1) + overprovisioning +
                Dynamic Fallback (§3.2).
``baselines``   EvenSpread, RoundRobin, StaticMixture (ASG), AWSSpot,
                MArk-like, OnDemandOnly, SpotOnly.
``autoscaler``  The load-based autoscaler with hysteresis (§4).
``omniscient``  The Omniscient ILP oracle (§3.3, Eq. 1-5) via HiGHS.
"""

from repro.core.autoscaler import Autoscaler, ConstantTarget, LoadAutoscaler
from repro.core.baselines import (
    AWSSpotPolicy,
    EvenSpreadPolicy,
    MArkLikePolicy,
    OnDemandOnlyPolicy,
    RoundRobinPolicy,
    SpotOnlyPolicy,
    StaticMixturePolicy,
)
from repro.core.omniscient import OmniscientPolicy, solve_omniscient
from repro.core.policy import (
    Action,
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Policy,
    Terminate,
    make_policy,
)
from repro.core.spothedge import SpotHedgePolicy

__all__ = [
    "Action",
    "LaunchOnDemand",
    "LaunchSpot",
    "Observation",
    "Policy",
    "Terminate",
    "make_policy",
    "SpotHedgePolicy",
    "EvenSpreadPolicy",
    "RoundRobinPolicy",
    "StaticMixturePolicy",
    "AWSSpotPolicy",
    "MArkLikePolicy",
    "OnDemandOnlyPolicy",
    "SpotOnlyPolicy",
    "Autoscaler",
    "ConstantTarget",
    "LoadAutoscaler",
    "OmniscientPolicy",
    "solve_omniscient",
]

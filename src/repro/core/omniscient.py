"""Omniscient oracle policy (§3.3, Eq. 1-5) — offline MILP over the trace.

Requires the complete spot obtainability trace (infeasible online; the paper
uses it as a cost lower bound).  We bucket time to keep the MILP tractable
and solve with scipy's HiGHS backend.

Decision variables per time bucket ``t``:

    S[z,t]  launched spot replicas in zone z          (int >= 0, <= C(z,t))
    R[z,t]  ready spot replicas in zone z             (int >= 0)
    O[t]    launched on-demand replicas               (int >= 0)
    Or[t]   ready on-demand replicas                  (int >= 0)
    M[t]    availability indicator                    (binary)

    minimize   sum_t [ sum_z S[z,t] + k * O[t] ]                    (Eq. 1)
    s.t.       sum_t M[t] >= T * Avail_Tar                          (Eq. 2)
               S[z,t] <= C(z,t)                                     (Eq. 3)
               R[z,t] <= S[z,t']  for t' in (t-d, t]   (cold start) (Eq. 4)
               Or[t]  <= O[t']   for t' in (t-d, t]                 (Eq. 4)
               M[t]*Nmax  >= sum_z R[z,t] + Or[t] - N_Tar(t)        (Eq. 5)
               (1-M[t])*Nmax >= N_Tar(t) - sum_z R[z,t] - Or[t]     (Eq. 5)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np
from scipy import optimize, sparse

from repro.cluster.traces import SpotTrace
from repro.core.policy import (
    Action,
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Policy,
    Terminate,
    register_policy,
)


@dataclasses.dataclass
class OmniscientSchedule:
    """The solved plan, replayable against the simulator."""

    zones: List[str]
    bucket_s: float
    spot_plan: np.ndarray        # int [T, Z] — S[z,t]
    od_plan: np.ndarray          # int [T]    — O[t]
    availability_ind: np.ndarray  # int [T]   — M[t]
    objective: float             # normalized cost units (spot-replica-buckets)
    status: str

    def spot_at(self, t: float) -> Dict[str, int]:
        i = min(int(t / self.bucket_s), len(self.od_plan) - 1)
        return {z: int(c) for z, c in zip(self.zones, self.spot_plan[i])}

    def od_at(self, t: float) -> int:
        i = min(int(t / self.bucket_s), len(self.od_plan) - 1)
        return int(self.od_plan[i])


def solve_omniscient(
    trace: SpotTrace,
    *,
    n_target: int,
    cold_start_s: float,
    k_ratio: float,
    avail_target: float = 0.99,
    bucket_s: Optional[float] = None,
    max_buckets: int = 400,
    time_limit_s: float = 120.0,
) -> OmniscientSchedule:
    """Solve Eq. 1-5 over ``trace`` and return the optimal schedule."""
    if bucket_s is None:
        # choose the coarsest bucket that still resolves the cold start and
        # keeps the MILP under ``max_buckets`` buckets.
        bucket_s = max(trace.dt, cold_start_s,
                       trace.duration_s / max_buckets)
    stride = max(1, int(round(bucket_s / trace.dt)))
    # bucket capacity = min over the bucket (conservative: a launch must
    # survive the whole bucket)
    T_raw = trace.cap.shape[0]
    T = T_raw // stride
    if T < 2:
        raise ValueError("trace too short for the requested bucketing")
    capb = trace.cap[: T * stride].reshape(T, stride, -1).min(axis=1)
    Z = capb.shape[1]
    db = max(1, int(math.ceil(cold_start_s / bucket_s)))
    # nothing can be ready during the first db buckets (cold start), so the
    # availability target is capped at the achievable maximum
    avail_target = min(avail_target, (T - db) / T)
    n_max = int(max(n_target * 2, int(capb.max()) + n_target, 4))

    # variable layout: [S (T*Z) | R (T*Z) | O (T) | Or (T) | M (T)]
    nS = T * Z
    iS = lambda t, z: t * Z + z                  # noqa: E731
    iR = lambda t, z: nS + t * Z + z             # noqa: E731
    iO = lambda t: 2 * nS + t                    # noqa: E731
    iOr = lambda t: 2 * nS + T + t               # noqa: E731
    iM = lambda t: 2 * nS + 2 * T + t            # noqa: E731
    nvar = 2 * nS + 3 * T

    c = np.zeros(nvar)
    for t in range(T):
        for z in range(Z):
            c[iS(t, z)] = 1.0
        c[iO(t)] = k_ratio

    rows: List[int] = []
    cols: List[int] = []
    vals: List[float] = []
    lbs: List[float] = []
    ubs: List[float] = []
    r = 0

    def add(coefs: List, lo: float, hi: float) -> None:
        nonlocal r
        for col, v in coefs:
            rows.append(r)
            cols.append(col)
            vals.append(v)
        lbs.append(lo)
        ubs.append(hi)
        r += 1

    inf = np.inf
    # Eq. 2: sum_t M[t] >= T * avail_target
    add([(iM(t), 1.0) for t in range(T)], math.ceil(T * avail_target), inf)

    for t in range(T):
        # Eq. 4 spot: R[z,t] <= S[z,t'] for the trailing cold-start window
        for z in range(Z):
            if t < db:
                add([(iR(t, z), 1.0)], 0.0, 0.0)   # nothing ready yet
            else:
                for tp in range(t - db, t + 1):
                    add([(iR(t, z), 1.0), (iS(tp, z), -1.0)], -inf, 0.0)
        # Eq. 4 on-demand
        if t < db:
            add([(iOr(t), 1.0)], 0.0, 0.0)
        else:
            for tp in range(t - db, t + 1):
                add([(iOr(t), 1.0), (iO(tp), -1.0)], -inf, 0.0)
        # Eq. 5a: M*Nmax - sum_z R - Or >= -N_Tar  (forces M=1 if ready>=NTar)
        add(
            [(iM(t), float(n_max))]
            + [(iR(t, z), -1.0) for z in range(Z)]
            + [(iOr(t), -1.0)],
            -float(n_target),
            inf,
        )
        # Eq. 5b: (1-M)*Nmax >= N_Tar - sum R - Or
        #   ->  -M*Nmax + sum R + Or >= N_Tar - Nmax
        add(
            [(iM(t), -float(n_max))]
            + [(iR(t, z), 1.0) for z in range(Z)]
            + [(iOr(t), 1.0)],
            float(n_target) - float(n_max),
            inf,
        )

    A = sparse.csr_matrix(
        (vals, (rows, cols)), shape=(r, nvar)
    )
    constraints = optimize.LinearConstraint(A, np.array(lbs), np.array(ubs))

    lb = np.zeros(nvar)
    ub = np.full(nvar, float(n_max))
    for t in range(T):
        for z in range(Z):
            ub[iS(t, z)] = float(capb[t, z])          # Eq. 3
            ub[iR(t, z)] = float(capb[t, z])
        ub[iO(t)] = float(n_target)
        ub[iOr(t)] = float(n_target)
        ub[iM(t)] = 1.0
    bounds = optimize.Bounds(lb, ub)
    integrality = np.ones(nvar)  # all integer (M binary via bounds)

    res = optimize.milp(
        c,
        constraints=constraints,
        bounds=bounds,
        integrality=integrality,
        options={"time_limit": time_limit_s, "presolve": True},
    )
    if res.x is None:
        # Availability target infeasible under the trace — retry with the
        # best achievable availability (all-OD satisfies any target, so this
        # only triggers for avail_target pathologies, e.g. > 1).
        raise RuntimeError(f"omniscient MILP failed: {res.message}")

    x = np.round(res.x).astype(int)
    spot_plan = np.array(
        [[x[iS(t, z)] for z in range(Z)] for t in range(T)], dtype=int
    )
    od_plan = np.array([x[iO(t)] for t in range(T)], dtype=int)
    m = np.array([x[iM(t)] for t in range(T)], dtype=int)
    return OmniscientSchedule(
        zones=list(trace.zones),
        bucket_s=float(stride * trace.dt),
        spot_plan=spot_plan,
        od_plan=od_plan,
        availability_ind=m,
        objective=float(res.fun),
        status=str(res.message),
    )


@register_policy
class OmniscientPolicy(Policy):
    """Replays a pre-solved :class:`OmniscientSchedule` in the simulator."""

    name = "omniscient"

    def __init__(self, schedule: Optional[OmniscientSchedule] = None) -> None:
        super().__init__()
        self.schedule = schedule

    def attach_schedule(self, schedule: OmniscientSchedule) -> None:
        self.schedule = schedule

    def decide(self, obs: Observation) -> List[Action]:
        if self.schedule is None:
            raise RuntimeError(
                "OmniscientPolicy needs a schedule "
                "(call attach_schedule or use solve_omniscient)"
            )
        plan = self.schedule.spot_at(obs.now)
        od_plan = self.schedule.od_at(obs.now)
        actions: List[Action] = []

        counts = obs.spot_count_by_zone()
        # launch up to plan per zone; terminate down to plan per zone
        for zone in self.schedule.zones:
            want = plan.get(zone, 0)
            have = counts.get(zone, 0)
            if want > have:
                actions.extend(LaunchSpot(zone) for _ in range(want - have))
            elif want < have:
                pool = [
                    i
                    for i in obs.spot_provisioning + obs.spot_ready
                    if i.zone == zone
                ]
                pool.sort(key=lambda i: -i.launched_at)
                actions.extend(
                    Terminate(i.id) for i in pool[: have - want]
                )

        gap = od_plan - obs.o_launched
        if gap > 0:
            zone = self._cheapest_od_zone()
            actions.extend(LaunchOnDemand(zone) for _ in range(gap))
        elif gap < 0:
            actions.extend(self._scale_down_od(obs, od_plan))
        return actions

"""Baseline policies the paper compares against (§2.4, §5).

* ``EvenSpreadPolicy``   — static even spread over zones (AWS ASG / MArk's
                           placement; §3.1 "Static Spread").
* ``RoundRobinPolicy``   — relaunch in the next zone, round-robin (Ray Serve,
                           GKE; §3.1).
* ``StaticMixturePolicy``— ASG-style fixed node pools: a fixed fraction of
                           on-demand replicas plus a fixed spot pool (§2.4).
* ``AWSSpotPolicy``      — pure spot node pool with even spread in a single
                           region (the paper's "AWSSpot" baseline).
* ``MArkLikePolicy``     — greedy spot-first with over-requesting behaviour
                           under unavailability (§5.1: MArk/AWSSpot keep
                           re-requesting; we cap retries per tick the way the
                           paper observed up to 14 in-flight requests).
* ``OnDemandOnlyPolicy`` — the cost reference (availability ~1, cost 1.0).
* ``SpotOnlyPolicy``     — pure spot with SpotHedge placement but *no*
                           on-demand fallback (ablation).
"""

from __future__ import annotations

import itertools
from typing import Dict, List

from repro.core.policy import (
    Action,
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Policy,
    Terminate,
    register_policy,
)
from repro.core.spothedge import SpotHedgePolicy


def _terminate_surplus_spot(obs: Observation, goal: int) -> List[Action]:
    surplus = obs.s_launched - goal
    if surplus <= 0:
        return []
    pool = sorted(obs.spot_provisioning, key=lambda i: -i.launched_at) + sorted(
        obs.spot_ready, key=lambda i: -i.launched_at
    )
    return [Terminate(i.id) for i in pool[:surplus]]


@register_policy
class EvenSpreadPolicy(Policy):
    """Keep N_Tar spot replicas spread evenly over all enabled zones."""

    name = "even_spread"

    def decide(self, obs: Observation) -> List[Action]:
        zones = self._zone_names()
        counts = obs.spot_count_by_zone()
        actions: List[Action] = []
        to_launch = obs.n_target - obs.s_launched
        for _ in range(max(0, to_launch)):
            # fill the least-loaded zone, fixed zone order — static spread
            zone = min(zones, key=lambda z: (counts.get(z, 0), zones.index(z)))
            actions.append(LaunchSpot(zone))
            counts[zone] = counts.get(zone, 0) + 1
        actions.extend(_terminate_surplus_spot(obs, obs.n_target))
        return actions


@register_policy
class RoundRobinPolicy(Policy):
    """Relaunch preempted replicas in the next zone, round-robin."""

    name = "round_robin"

    def __init__(self) -> None:
        super().__init__()
        self._cursor = 0

    def decide(self, obs: Observation) -> List[Action]:
        zones = self._zone_names()
        actions: List[Action] = []
        to_launch = obs.n_target - obs.s_launched
        for _ in range(max(0, to_launch)):
            zone = zones[self._cursor % len(zones)]
            self._cursor += 1
            actions.append(LaunchSpot(zone))
        actions.extend(_terminate_surplus_spot(obs, obs.n_target))
        return actions


@register_policy
class StaticMixturePolicy(Policy):
    """ASG-style static node pools (§2.4).

    ``od_fraction`` of N_Tar is always on-demand (ASG default example: 10%);
    the rest is a *fixed-size* spot pool spread evenly in one region.  The
    pools never trade capacity: lost spot capacity is retried as spot, never
    covered by extra on-demand — the paper's core criticism.
    """

    name = "static_mixture"

    def __init__(self, od_fraction: float = 0.1) -> None:
        super().__init__()
        self.od_fraction = float(od_fraction)

    def decide(self, obs: Observation) -> List[Action]:
        import math

        n_od = max(1, math.ceil(obs.n_target * self.od_fraction)) \
            if self.od_fraction > 0 else 0
        n_spot = obs.n_target - n_od
        actions: List[Action] = []

        # on-demand pool, fixed size
        gap_od = n_od - obs.o_launched
        if gap_od > 0:
            zone = self._cheapest_od_zone()
            actions.extend(LaunchOnDemand(zone) for _ in range(gap_od))
        elif gap_od < 0:
            actions.extend(self._scale_down_od(obs, n_od))

        # spot pool, fixed size, even spread
        zones = self._zone_names()
        counts = obs.spot_count_by_zone()
        gap_spot = n_spot - obs.s_launched
        for _ in range(max(0, gap_spot)):
            zone = min(zones, key=lambda z: (counts.get(z, 0), zones.index(z)))
            actions.append(LaunchSpot(zone))
            counts[zone] = counts.get(zone, 0) + 1
        actions.extend(_terminate_surplus_spot(obs, n_spot))
        return actions


@register_policy
class AWSSpotPolicy(EvenSpreadPolicy):
    """Pure spot node pool with even spread — the paper's AWSSpot baseline.

    Same placement as EvenSpread; the distinction in our benchmarks is that
    AWSSpot is configured with the zones of a *single region* (the paper runs
    it in us-west-2), whereas EvenSpread may be given multi-region zones.
    """

    name = "aws_spot"


@register_policy
class MArkLikePolicy(Policy):
    """Greedy spot-first policy in the spirit of MArk (§5.1 baseline).

    MArk targets spot CPU instances and assumes replacements become ready
    quickly after a preemption warning.  Ported to spot GPUs it (a) keeps
    re-requesting spot in the cheapest zone, and (b) over-requests under
    unavailability because provisioning instances don't count toward its
    target.  The paper observed up to 14 in-flight provisioning requests
    (Fig. 12b); we reproduce that failure mode with ``overrequest_factor``.
    """

    name = "mark_like"

    def __init__(self, overrequest_factor: float = 2.0,
                 max_inflight: int = 14) -> None:
        super().__init__()
        self.overrequest_factor = float(overrequest_factor)
        self.max_inflight = int(max_inflight)

    def decide(self, obs: Observation) -> List[Action]:
        actions: List[Action] = []
        # counts only READY replicas toward the target (the ported bug)
        deficit = obs.n_target - obs.s_r
        if deficit > 0:
            want = min(
                int(deficit * self.overrequest_factor),
                self.max_inflight - len(obs.spot_provisioning),
            )
            # cheapest zone first — MArk is cost-greedy
            zones = sorted(
                self._zone_names(), key=lambda z: (self._spot_price(z), z)
            )
            for i in range(max(0, want)):
                actions.append(LaunchSpot(zones[i % len(zones)]))
        else:
            actions.extend(_terminate_surplus_spot(obs, obs.n_target))
        return actions


@register_policy
class OnDemandOnlyPolicy(Policy):
    """N_Tar on-demand replicas, nothing else (the cost denominator)."""

    name = "ondemand_only"

    def decide(self, obs: Observation) -> List[Action]:
        actions: List[Action] = []
        gap = obs.n_target - obs.o_launched
        if gap > 0:
            zone = self._cheapest_od_zone()
            actions.extend(LaunchOnDemand(zone) for _ in range(gap))
        elif gap < 0:
            actions.extend(self._scale_down_od(obs, obs.n_target))
        return actions


@register_policy
class SpotOnlyPolicy(SpotHedgePolicy):
    """SpotHedge placement without the on-demand fallback (ablation)."""

    name = "spot_only"

    def __init__(self, num_overprovision: int = 2) -> None:
        super().__init__(
            num_overprovision=num_overprovision,
            dynamic_ondemand_fallback=False,
        )

"""Scenario reports: per-cell metrics, aggregation, JSON artifacts.

A :class:`ScenarioReport` is the output of ``ScenarioSuite.run``: one
:class:`CellResult` per scenario (P50/P90/P99 latency, failure rate,
cost-vs-OD, availability, preemption counts, wall-clock), plus suite-level
metadata.  ``save()`` writes the JSON artifact under ``artifacts/bench/``.

Artifact schema (``schema: 1``)::

    {
      "schema": 1,
      "suite": "latency-sweep",
      "engine": "vector",
      "workers": 1,
      "wall_s": 12.3,
      "n_cells": 27,
      "cells": [
        {
          "policy": "spothedge", "trace": "aws-1",
          "workload": "poisson", "seed": 5,
          "n_requests": 25902, "n_completed": 25721, "n_failed": 181,
          "failure_rate": 0.007, "mean_s": 3.1,
          "p50_s": 2.9, "p90_s": 4.9, "p99_s": 9.4,
          "total_cost": 101.2, "cost_vs_ondemand": 0.41,
          "availability": 0.97, "n_preemptions": 11,
          "n_launch_failures": 3, "wall_s": 0.41
        }, ...
      ]
    }
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Mapping, Optional

import numpy as np

from repro.serving.sim import ServingResult

__all__ = ["CellResult", "ScenarioReport", "SCHEMA_VERSION"]

SCHEMA_VERSION = 1


def _finite(v: float) -> Optional[float]:
    return float(v) if np.isfinite(v) else None


@dataclasses.dataclass
class CellResult:
    """One scenario's labels + headline metrics."""

    labels: Dict[str, Any]           # axis -> value (policy, trace, ...)
    n_requests: int
    n_completed: int
    n_failed: int
    failure_rate: float
    mean_s: float
    p50_s: float
    p90_s: float
    p99_s: float
    total_cost: float
    cost_vs_ondemand: float
    availability: float
    n_preemptions: int
    n_launch_failures: int
    wall_s: float
    # token-level metrics — populated only for replica_model="token"
    # cells and omitted from to_dict() when None, so request-level
    # artifacts keep their historical shape
    ttft_p50_s: Optional[float] = None
    ttft_p99_s: Optional[float] = None
    tpot_p50_s: Optional[float] = None
    tpot_p99_s: Optional[float] = None
    goodput_rps: Optional[float] = None
    slo_attainment: Optional[float] = None
    # grace-period migration counters (repro.migration) — token cells
    # only; zero when the cell ran with migration disabled
    n_drained_seqs: Optional[int] = None
    n_migrated_seqs: Optional[int] = None
    migrated_kv_tokens: Optional[int] = None
    saved_prefill_tokens: Optional[int] = None
    n_retried_requests: Optional[int] = None
    lost_kv_tokens: Optional[int] = None
    # observability (repro.obs) — picklable snapshots so process-parallel
    # sweep workers carry them back to the parent; omitted when the cell
    # ran with detail "off" (or recorded nothing)
    metrics: Optional[Dict[str, Any]] = None
    obs_event_counts: Optional[Dict[str, int]] = None
    obs_windows: Optional[List[Dict[str, Any]]] = None
    # SLO burn-rate summary + sampled-span count (repro.obs.slo /
    # repro.obs.spans); present only at detail "full"
    slo_burn: Optional[Dict[str, Any]] = None
    n_spans: Optional[int] = None

    @staticmethod
    def from_result(
        labels: Mapping[str, Any], res: ServingResult, wall_s: float
    ) -> "CellResult":
        lat = res.latencies_s
        tok = res.token
        return CellResult(
            labels=dict(labels),
            n_requests=res.n_requests,
            n_completed=res.n_completed,
            n_failed=res.n_failed,
            failure_rate=res.failure_rate,
            mean_s=float(lat.mean()) if len(lat) else float("nan"),
            p50_s=res.pct(50),
            p90_s=res.pct(90),
            p99_s=res.pct(99),
            total_cost=res.total_cost,
            cost_vs_ondemand=res.cost_vs_ondemand,
            availability=res.availability,
            n_preemptions=res.n_preemptions,
            n_launch_failures=res.n_launch_failures,
            wall_s=wall_s,
            # NaN percentiles (a token cell with zero completions) become
            # None so the JSON artifact stays strictly parseable
            ttft_p50_s=_finite(tok.ttft_pct(50)) if tok else None,
            ttft_p99_s=_finite(tok.ttft_pct(99)) if tok else None,
            tpot_p50_s=_finite(tok.tpot_pct(50)) if tok else None,
            tpot_p99_s=_finite(tok.tpot_pct(99)) if tok else None,
            goodput_rps=tok.goodput_rps if tok else None,
            slo_attainment=tok.slo_attainment if tok else None,
            n_drained_seqs=tok.n_drained_seqs if tok else None,
            n_migrated_seqs=tok.n_migrated_seqs if tok else None,
            migrated_kv_tokens=tok.migrated_kv_tokens if tok else None,
            saved_prefill_tokens=tok.saved_prefill_tokens if tok else None,
            n_retried_requests=res.n_retried_requests if tok else None,
            lost_kv_tokens=res.lost_kv_tokens if tok else None,
            metrics=res.metrics,
            obs_event_counts=(
                res.obs.event_counts() if res.obs is not None else None
            ),
            obs_windows=(
                res.obs.window_records() or None
                if res.obs is not None else None
            ),
            slo_burn=(
                res.obs.slo_burn_summary()
                if res.obs is not None else None
            ),
            n_spans=(
                len(res.obs.span_records()) or None
                if res.obs is not None else None
            ),
        )

    @property
    def cell_id(self) -> str:
        return "/".join(str(v) for v in self.labels.values())

    def to_dict(self, round_to: Optional[int] = 6) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.labels)
        for f in dataclasses.fields(self):
            if f.name == "labels":
                continue
            v = getattr(self, f.name)
            if v is None:
                continue
            if round_to is not None and isinstance(v, float) \
                    and np.isfinite(v):
                v = round(v, round_to)
            out[f.name] = v
        return out


@dataclasses.dataclass
class ScenarioReport:
    """All cell results of one suite run, JSON-serializable."""

    suite: str
    engine: str
    workers: int
    cells: List[CellResult]
    wall_s: float
    # suite-level metrics: every cell's registry snapshot merged
    # (repro.obs.MetricsRegistry.merge_snapshots); None when no cell
    # recorded any
    metrics: Optional[Dict[str, Any]] = None

    # -- access ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.cells)

    def select(self, **labels: Any) -> List[CellResult]:
        """Cells whose labels match every given ``axis=value``."""
        return [
            c for c in self.cells
            if all(c.labels.get(k) == v for k, v in labels.items())
        ]

    def burn_ranking(self) -> List[CellResult]:
        """Cells with a burn summary, worst error-budget burn first.

        Ranks by time spent alerting, then by alert-window count — the
        cell a paging SLO would flag first.  Cells that ran below detail
        ``full`` (no burn windows) are omitted.
        """
        burned = [c for c in self.cells if c.slo_burn]
        return sorted(
            burned,
            key=lambda c: (
                -float(c.slo_burn.get("alert_minutes", 0.0)),
                -int(c.slo_burn.get("alert_windows", 0)),
            ),
        )

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out = {
            "schema": SCHEMA_VERSION,
            "suite": self.suite,
            "engine": self.engine,
            "workers": self.workers,
            "wall_s": round(self.wall_s, 3),
            "n_cells": len(self.cells),
            "cells": [c.to_dict() for c in self.cells],
        }
        if self.metrics is not None:
            out["metrics"] = self.metrics
        return out

    def save(self, directory: str = os.path.join("artifacts", "bench"),
             stem: Optional[str] = None) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(
            directory, f"{stem or 'scenario_' + self.suite}.json"
        )
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, default=str)
        return path

    # -- display ---------------------------------------------------------
    def summary(self) -> str:
        lines = [
            f"suite {self.suite}: {len(self.cells)} cells, "
            f"engine={self.engine}, workers={self.workers}, "
            f"wall={self.wall_s:.1f}s"
        ]
        for c in self.cells:
            lines.append(
                f"  {c.cell_id:<44s} p50={c.p50_s:7.2f}s "
                f"p99={c.p99_s:8.2f}s fail={c.failure_rate:7.2%} "
                f"cost={c.cost_vs_ondemand:6.2%} "
                f"avail={c.availability:.2%} [{c.wall_s:.2f}s]"
            )
        burned = self.burn_ranking()
        if burned:
            lines.append("  SLO burn (worst first):")
            for c in burned:
                b = c.slo_burn
                lines.append(
                    f"    {c.cell_id:<42s} "
                    f"alert={b['alert_minutes']:6.1f}min "
                    f"({b['alert_windows']}/{b['windows']} windows)"
                )
        return "\n".join(lines)

"""ScenarioSuite: expand a ServiceSpec grid and run every cell.

The suite is the one execution path for every multi-run experiment in the
repo (``benchmarks/e2e_compare.py``, ``latency.py``, ``sensitivity.py``,
``launch/serve.py --sweep``).  Two ways to build one:

* **declaratively** — a spec with a ``sweep:`` section expands to the
  ``policies × traces × workloads × seeds`` grid::

      suite = ScenarioSuite.from_spec("sweep.yaml")
      report = suite.run(workers="auto")
      print(report.summary())

* **programmatically** — hand the suite explicit :class:`Scenario`
  variants (custom axes like trace windows or cold-start sweeps)::

      suite = ScenarioSuite([Scenario(labels={...}, spec=variant), ...])

Request tapes are shared: scenarios with equal ``tape_key`` replay
identical arrivals (the grid keys tapes by workload × seed × horizon, so
every policy/trace cell of one workload sees the same request stream —
the §5.1 fair-comparison methodology).  Tapes are regenerated from the
spec inside worker processes instead of being pickled across; workload
generation is seed-deterministic, so every worker sees the same stream.

Cells are independent, so ``run(workers=N)`` fans them out over worker
processes; results are deterministic and identical for any worker count.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import time
from typing import (
    Any, Dict, Hashable, List, Mapping, Optional, Sequence, Tuple,
)

from repro.cluster.traces import SpotTrace
from repro.core.policy import policy_class
from repro.experiments.report import CellResult, ScenarioReport
from repro.service.builder import build_requests, build_service
from repro.service.loader import load_spec
from repro.service.spec import (
    ForecastSpec,
    MigrationSpec,
    ServiceSpec,
    SpecError,
    SweepSpec,
)
from repro.workloads import Request

__all__ = ["Scenario", "ScenarioSuite"]


# label axes may not shadow metric fields — CellResult.to_dict flattens
# labels and metrics into one record
_RESERVED_LABELS = frozenset(
    f.name for f in dataclasses.fields(CellResult) if f.name != "labels"
)


@dataclasses.dataclass
class Scenario:
    """One cell of a scenario matrix: labels + a single-run spec.

    ``trace`` optionally overrides the spec's named trace with a
    pre-sliced window (the e2e benchmark's available/volatile windows).
    Scenarios sharing a ``tape_key`` replay one request tape.
    """

    labels: Dict[str, Any]
    spec: ServiceSpec
    trace: Optional[SpotTrace] = None
    tape_key: Optional[Hashable] = None

    def __post_init__(self) -> None:
        if self.spec.sweep is not None:
            raise SpecError(
                "a Scenario wraps a single-run spec; expand the sweep "
                "with ScenarioSuite.from_spec first"
            )
        clash = set(self.labels) & _RESERVED_LABELS
        if clash:
            raise SpecError(
                f"scenario label axes {sorted(clash)} collide with "
                "CellResult metric fields; pick different axis names"
            )

    @property
    def cell_id(self) -> str:
        return "/".join(str(v) for v in self.labels.values())


def _canonical_args(value: Any, path: str = "workload.args") -> Hashable:
    """Canonicalize a workload-args value into a hashable, order-insensitive
    structure for the tape key.

    Strict by design: only JSON-ish primitives and containers are
    accepted.  The previous ``json.dumps(..., default=repr)`` fallback
    silently stringified arbitrary objects, and a ``repr`` that embeds a
    memory address (the default ``object.__repr__``) yields a key that
    differs across processes/runs — spawn-started workers then regenerate
    tapes and logically identical cells stop sharing one, breaking the
    §5.1 same-tape methodology.  Anything un-canonicalizable now raises
    ``SpecError`` at key-construction time instead.
    """
    if value is None or isinstance(value, str):
        return value
    if isinstance(value, bool):
        # tag bools: True == 1 under dict/tuple equality, but workload
        # args {"flag": True} and {"flag": 1} must not share a tape key
        return ("__bool__", value)
    if isinstance(value, (int, float)):
        return value
    if isinstance(value, (list, tuple)):
        return tuple(
            _canonical_args(v, f"{path}[{k}]") for k, v in enumerate(value)
        )
    if isinstance(value, Mapping):
        items = []
        for k in sorted(value, key=str):
            if not isinstance(k, str):
                raise SpecError(
                    f"{path}: mapping key {k!r} is not a string; tape "
                    "keys require string-keyed mappings"
                )
            items.append((k, _canonical_args(value[k], f"{path}.{k}")))
        return tuple(items)
    raise SpecError(
        f"{path}: cannot canonicalize {type(value).__name__} value "
        f"{value!r} for the shared-tape key; workload args must be "
        "JSON-like (None/bool/int/float/str and lists/dicts thereof) so "
        "the key is stable across processes"
    )


def _workload_tape_key(spec: ServiceSpec) -> Tuple:
    """Tapes are equal iff workload spec and arrival horizon are equal."""
    w = spec.workload
    # args may hold unhashable values (e.g. a client_regions mapping) —
    # the canonical tuple form keeps the key hashable, order-insensitive
    # and — unlike repr-based fallbacks — stable across processes
    args_key = _canonical_args(dict(w.args))
    return (
        w.kind, w.rate_per_s, w.seed,
        args_key,
        spec.sim.duration_s - spec.sim.drain_s,
    )


def _effective_tape_key(scenario: Scenario) -> Optional[Tuple]:
    """Cache key for a scenario's shared tape.

    The user's ``tape_key`` groups cells; composing it with the workload
    fingerprint guarantees two suites that happen to reuse a key with
    *different* workloads can never share a stale tape (the worker-side
    cache outlives a single ``run()``).
    """
    if scenario.tape_key is None:
        return None
    return (scenario.tape_key, _workload_tape_key(scenario.spec))


def _run_scenario(
    scenario: Scenario,
    tape_cache: Dict[Hashable, List[Request]],
    engine: Optional[str],
) -> CellResult:
    """Build and run one cell; tapes are cached per process."""
    spec = scenario.spec
    if engine is not None and spec.sim.engine != engine:
        spec = dataclasses.replace(
            spec, sim=dataclasses.replace(spec.sim, engine=engine)
        )
    requests: Optional[List[Request]] = None
    key = _effective_tape_key(scenario)
    if key is not None:
        requests = tape_cache.get(key)
        if requests is None:
            requests = tape_cache[key] = build_requests(spec)
    t0 = time.perf_counter()
    resolved = build_service(
        spec, trace=scenario.trace, requests=requests
    )
    result = resolved.simulator.run(spec.sim.duration_s)
    wall = time.perf_counter() - t0
    return CellResult.from_result(scenario.labels, result, wall)


def _disambiguate(
    names: List[str], knobs: List[List[Tuple[str, Any]]]
) -> List[str]:
    """Axis labels: the bare name when unique, name[knob=...] or name#k
    when several grid entries share it (e.g. two spothedge variants)."""
    counts: Dict[str, int] = {}
    for n in names:
        counts[n] = counts.get(n, 0) + 1
    seen: Dict[str, int] = {}
    out: List[str] = []
    for n, kv in zip(names, knobs):
        if counts[n] == 1:
            out.append(n)
            continue
        k = seen[n] = seen.get(n, 0) + 1
        detail = ",".join(f"{key}={v}" for key, v in kv)
        out.append(f"{n}[{detail}]" if detail else f"{n}#{k}")
    # identical knob sets would still collide — fall back to indexing
    if len(set(out)) != len(out):
        out = [
            lab if out.count(lab) == 1 else f"{lab}#{i}"
            for i, lab in enumerate(out)
        ]
    return out


# module-level worker state so ProcessPoolExecutor workers reuse tapes
_worker_tapes: Dict[Hashable, List[Request]] = {}


def _run_scenario_worker(
    payload: Tuple[Scenario, Optional[str]]
) -> CellResult:
    scenario, engine = payload
    return _run_scenario(scenario, _worker_tapes, engine)


class ScenarioSuite:
    """A batch of scenarios sharing one execution path."""

    def __init__(self, scenarios: Sequence[Scenario],
                 name: str = "suite") -> None:
        self.scenarios: List[Scenario] = list(scenarios)
        self.name = name
        if not self.scenarios:
            raise SpecError("ScenarioSuite needs at least one scenario")

    def __len__(self) -> int:
        return len(self.scenarios)

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(
        cls,
        spec: "ServiceSpec | Mapping[str, Any] | str",
        name: Optional[str] = None,
    ) -> "ScenarioSuite":
        """Expand a spec's ``sweep`` grid (missing axes fall back to the
        base spec's single value)."""
        base = load_spec(spec)
        sweep = base.sweep or SweepSpec()
        policies = sweep.policies or (base.replica_policy,)
        traces = sweep.traces or (base.trace,)
        workloads = sweep.workloads or (base.workload,)
        # no seeds axis: every workload keeps its own declared seed
        seeds: Tuple[Optional[int], ...] = sweep.seeds or (None,)
        # no forecasters axis: the base forecast section (if any) applies
        # to every cell and no "forecaster" label column is emitted
        forecasters: Tuple[Optional[str], ...] = sweep.forecasters or (None,)
        # no replica_models axis: every cell keeps sim.replica_model and
        # no "replica_model" label column is emitted
        replica_models: Tuple[Optional[str], ...] = (
            sweep.replica_models or (None,)
        )
        # no migration axis: the base migration section (if any) applies
        # to every cell and no "migration" label column is emitted
        migrations: "Tuple[bool | MigrationSpec | None, ...]" = (
            sweep.migration or (None,)
        )

        policy_labels = _disambiguate(
            [p.name for p in policies],
            [sorted(p.policy_kwargs().items()) for p in policies],
        )
        workload_labels = _disambiguate(
            [w.kind for w in workloads],
            [
                [("rate_per_s", w.rate_per_s), ("seed", w.seed),
                 *sorted(w.args.items())]
                for w in workloads
            ],
        )

        scenarios: List[Scenario] = []
        for (pol, plabel), tr, (wl, wlabel), seed, fc, rm, mg in (
            itertools.product(
                zip(policies, policy_labels),
                traces,
                zip(workloads, workload_labels),
                seeds,
                forecasters,
                replica_models,
                migrations,
            )
        ):
            if fc is not None and not getattr(
                policy_class(pol.name), "uses_forecast", False
            ):
                # a forecaster axis is meaningless for policies that
                # ignore the forecast section — expanding it would re-run
                # byte-identical cells once per predictor.  Keep exactly
                # one (unlabeled-forecaster) cell for such policies.
                if fc != forecasters[0]:
                    continue
                fc = None
            cell_rm = rm if rm is not None else base.sim.replica_model
            if mg is not None and cell_rm != "token":
                # migration only exists at token granularity; keep one
                # (unlabeled-migration) cell for request-model variants
                if mg != migrations[0]:
                    continue
                mg = None
            wl_seeded = (
                wl if seed is None else dataclasses.replace(wl, seed=seed)
            )
            forecast = base.forecast
            if fc is not None:
                forecast = dataclasses.replace(
                    base.forecast or ForecastSpec(), name=fc
                )
            sim = base.sim
            if rm is not None and sim.replica_model != rm:
                sim = dataclasses.replace(sim, replica_model=rm)
            migration = base.migration
            mig_label: Optional[str] = None
            if mg is not None:
                if isinstance(mg, bool):
                    migration = dataclasses.replace(
                        base.migration or MigrationSpec(), enabled=mg
                    )
                else:
                    migration = mg
                mig_label = "on" if migration.enabled else "off"
            if (
                migration is not None
                and migration.enabled
                and cell_rm != "token"
            ):
                # an enabled base section on a request-model cell of a
                # mixed replica_models sweep: the cell has no KV state,
                # drop the section (the token cells keep it)
                migration = None
            cell_spec = dataclasses.replace(
                base,
                name=(f"{base.name}-{plabel}-{tr}-{wlabel}"
                      f"-s{wl_seeded.seed}"
                      + (f"-{fc}" if fc is not None else "")
                      + (f"-{rm}" if rm is not None else "")
                      + (f"-mig_{mig_label}" if mig_label is not None
                         else "")),
                replica_policy=pol,
                trace=tr,
                workload=wl_seeded,
                forecast=forecast,
                migration=migration,
                sim=sim,
                sweep=None,
            )
            labels = {
                "policy": plabel,
                "trace": tr,
                "workload": wlabel,
                "seed": wl_seeded.seed,
            }
            if fc is not None:
                labels["forecaster"] = fc
            if rm is not None:
                labels["replica_model"] = rm
            if mig_label is not None:
                labels["migration"] = mig_label
            scenarios.append(
                Scenario(
                    labels=labels,
                    spec=cell_spec,
                    tape_key=_workload_tape_key(cell_spec),
                )
            )
        return cls(scenarios, name=name or base.name)

    # ------------------------------------------------------------------
    def run(
        self,
        *,
        engine: Optional[str] = None,
        workers: "int | str | None" = None,
        save_to: Optional[str] = None,
        progress: bool = False,
    ) -> ScenarioReport:
        """Run every scenario; returns the aggregated report.

        ``engine`` overrides ``spec.sim.engine`` for every cell
        ("vector" / "legacy" / "jax").  ``workers`` fans independent
        cells out over processes ("auto" = one per CPU); results are
        identical for any worker count.  ``save_to`` writes the JSON
        artifact into the given directory (e.g. ``artifacts/bench``).

        ``engine="jax"`` takes the matrix-batched path: the control
        plane of every cell is replayed in-process (phase A), then all
        request-model data planes run as one vmapped XLA program per
        shape group (phase B) — ``workers`` is ignored, the batching
        *is* the parallelism.  Results are identical to the per-cell
        engines (tests/test_jax_engine.py).
        """
        n_workers = self._resolve_workers(workers)
        t0 = time.perf_counter()
        # serial and parallel share the process-level tape cache, so
        # repeated runs of one suite (e.g. benchmark trials) pay tape
        # generation once regardless of worker count
        self._prime_tape_cache()
        use_jax = engine == "jax" or (
            engine is None
            and bool(self.scenarios)
            and all(sc.spec.sim.engine == "jax" for sc in self.scenarios)
        )
        if use_jax:
            n_workers = 1
            cells = self._run_jax_matrix(progress)
        elif n_workers <= 1 or len(self.scenarios) <= 1:
            n_workers = 1
            cells = []
            for sc in self.scenarios:
                cells.append(_run_scenario(sc, _worker_tapes, engine))
                if progress:
                    print(f"[suite {self.name}] {cells[-1].cell_id} done "
                          f"({len(cells)}/{len(self.scenarios)})",
                          flush=True)
        else:
            cells = self._run_parallel(n_workers, engine, progress)
        wall = time.perf_counter() - t0
        # merge every cell's registry snapshot (cells from parallel
        # workers carry theirs back through the picklable CellResult)
        from repro.obs.registry import MetricsRegistry

        snaps = [c.metrics for c in cells if c.metrics]
        report = ScenarioReport(
            suite=self.name,
            engine=engine or self._engine_label(),
            workers=n_workers,
            cells=cells,
            wall_s=wall,
            metrics=MetricsRegistry.merge_snapshots(snaps) or None
            if snaps else None,
        )
        if progress:
            # surface paging-worthy cells (detail "full" only) as they
            # would reach an operator: worst error-budget burn first
            for c in report.burn_ranking():
                b = c.slo_burn
                if b["alert_windows"]:
                    print(f"[suite {self.name}] SLO burn alert: "
                          f"{c.cell_id} {b['alert_minutes']:.1f}min "
                          f"over {b['alert_windows']} windows", flush=True)
        if save_to is not None:
            report.save(save_to)
        return report

    # ------------------------------------------------------------------
    def _run_jax_matrix(self, progress: bool) -> List[CellResult]:
        """The jit/vmap path: build every cell, replay control planes,
        then run all request-model data planes as one batched program.

        Token-model cells and queue-overflow lanes fall back to the
        NumPy oracle inside :func:`repro.serving.jaxengine.run_cells`,
        so a mixed matrix still returns a complete, exact report.
        """
        from repro.serving.jaxengine import run_cells

        builds = []
        for sc in self.scenarios:
            spec = sc.spec
            if spec.sim.engine != "jax":
                spec = dataclasses.replace(
                    spec, sim=dataclasses.replace(spec.sim, engine="jax")
                )
            requests: Optional[List[Request]] = None
            key = _effective_tape_key(sc)
            if key is not None:
                requests = _worker_tapes.get(key)
                if requests is None:
                    requests = _worker_tapes[key] = build_requests(spec)
            t0 = time.perf_counter()
            resolved = build_service(
                spec, trace=sc.trace, requests=requests
            )
            builds.append((sc, spec, resolved,
                           time.perf_counter() - t0))
        t0 = time.perf_counter()
        results = run_cells(
            [b[2].simulator for b in builds],
            [b[1].sim.duration_s for b in builds],
        )
        # the batch is one program: attribute its wall clock evenly
        share = (time.perf_counter() - t0) / max(len(builds), 1)
        cells: List[CellResult] = []
        for (sc, _spec, _res, build_s), result in zip(builds, results):
            cells.append(
                CellResult.from_result(sc.labels, result,
                                       build_s + share)
            )
            if progress:
                print(f"[suite {self.name}] {cells[-1].cell_id} done "
                      f"({len(cells)}/{len(builds)})", flush=True)
        return cells

    def _engine_label(self) -> str:
        engines = {sc.spec.sim.engine for sc in self.scenarios}
        return engines.pop() if len(engines) == 1 else "mixed"

    @staticmethod
    def _resolve_workers(workers: "int | str | None") -> int:
        if workers is None:
            return 1
        if workers == "auto":
            return os.cpu_count() or 1
        try:
            n = int(workers)
        except (TypeError, ValueError):
            raise SpecError(
                f"workers must be an int >= 1 or 'auto', got {workers!r}"
            ) from None
        if n < 1:
            raise SpecError(
                f"workers must be an int >= 1 or 'auto', got {n}"
            )
        return n

    def _prime_tape_cache(self) -> None:
        """Generate this suite's shared tapes into the process cache.

        Runs in the parent BEFORE any pool forks, so fork-started workers
        inherit the tapes copy-on-write (spawn-started workers fall back
        to deterministic regeneration).  Keys other suites left behind
        are evicted so the process-global cache stays bounded by the
        current suite.
        """
        needed = {
            _effective_tape_key(sc): sc for sc in self.scenarios
            if sc.tape_key is not None
        }
        for stale in sorted(set(_worker_tapes) - set(needed)):
            del _worker_tapes[stale]
        for key, sc in needed.items():
            if key not in _worker_tapes:
                _worker_tapes[key] = build_requests(sc.spec)

    def _run_parallel(
        self, n_workers: int, engine: Optional[str], progress: bool
    ) -> List[CellResult]:
        import concurrent.futures as cf

        payloads = [(sc, engine) for sc in self.scenarios]
        cells: List[Optional[CellResult]] = [None] * len(payloads)
        with cf.ProcessPoolExecutor(max_workers=n_workers) as pool:
            futures = {
                pool.submit(_run_scenario_worker, p): i
                for i, p in enumerate(payloads)
            }
            n_done = 0
            for fut in cf.as_completed(futures):
                i = futures[fut]
                cells[i] = fut.result()
                n_done += 1
                if progress:
                    print(f"[suite {self.name}] {cells[i].cell_id} done "
                          f"({n_done}/{len(payloads)})", flush=True)
        # completeness: a lost future must be a loud failure, not a
        # silently shorter report (the old `[c for c in cells if c]`
        # filter dropped unfilled cells without a trace)
        missing = [
            self.scenarios[i].cell_id
            for i, c in enumerate(cells) if c is None
        ]
        if missing:
            raise RuntimeError(
                f"scenario suite {self.name!r}: {len(missing)} of "
                f"{len(cells)} cells never returned a result "
                f"(lost futures): {missing}"
            )
        return [c for c in cells if c is not None]

"""Scenario-matrix experiments: declare a grid, run every cell, report.

The §5.1 methodology sweeps policies × traces × workloads × seeds; this
package is that sweep as a subsystem:

* :class:`Scenario` — one cell (labels + a single-run ServiceSpec);
* :class:`ScenarioSuite` — grid expansion from a spec's ``sweep:`` section
  (or an explicit scenario list), shared request tapes, optional
  process-parallel execution;
* :class:`ScenarioReport` / :class:`CellResult` — per-cell P50/P90/P99,
  failure rate, cost-vs-OD, availability, preemptions, wall-clock; JSON
  artifacts under ``artifacts/bench/``.

Every benchmark driver (e2e_compare, latency, sensitivity) and
``launch/serve.py --sweep`` runs through this path.
"""

from repro.experiments.report import CellResult, ScenarioReport
from repro.experiments.suite import Scenario, ScenarioSuite

__all__ = [
    "CellResult",
    "Scenario",
    "ScenarioReport",
    "ScenarioSuite",
]

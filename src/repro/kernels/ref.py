"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, Kv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum(
        "bkgqd,bkmd->bkgqm", qg, k.astype(jnp.float32)
    ) / math.sqrt(D)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        c = q_pos[:, None] >= k_pos[None, :]
        if prefix_len:
            c = c | (k_pos[None, :] < prefix_len)
        mask &= c
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqm,bkmd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def flash_decode_ref(
    q: jax.Array,                 # (B, H, D)
    k: jax.Array,                 # (B, Kv, S, D)
    v: jax.Array,
    valid: jax.Array,             # (B, S)
) -> jax.Array:
    B, H, D = q.shape
    Kv = k.shape[1]
    G = H // Kv
    qg = q.reshape(B, Kv, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bkmd->bkgm", qg, k.astype(jnp.float32)) \
        / math.sqrt(D)
    s = jnp.where(valid[:, None, None, :].astype(bool), s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgm,bkmd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def selective_scan_ref(
    a: jax.Array,                 # (B, Q, C, N)
    b: jax.Array,
    h0: jax.Array,                # (B, C, N)
) -> jax.Array:
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a_t = a.transpose(1, 0, 2, 3).astype(jnp.float32)
    b_t = b.transpose(1, 0, 2, 3).astype(jnp.float32)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return hs.transpose(1, 0, 2, 3)


def moe_gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)

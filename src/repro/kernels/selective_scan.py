"""Selective-scan Pallas TPU kernel (Mamba-1 within-chunk recurrence).

Computes h_t = a_t * h_{t-1} + b_t for a chunk, returning every h_t.

Layout: a/b (B, Q, C, N), h0 (B, C, N), out (B, Q, C, N) where C is a
``d_inner`` block and N the SSM state size (16 for falcon-mamba — padded to
a lane-friendly 128 multiple by ops.py when worthwhile; the (C, N) plane is
the VREG tile).

Grid: (B, n_channel_blocks).  Each kernel instance keeps the running state
``h`` in VMEM scratch and walks the chunk with ``fori_loop`` — the
recurrence is sequential in time but the (C, N) plane is vector-parallel,
which is the TPU-native shape of this computation (the GPU version's
warp-parallel scan over time does not transfer; DESIGN.md §Hardware
adaptation)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, chunk: int):
    h_scr[...] = h0_ref[0].astype(jnp.float32)

    def step(t, _):
        a_t = a_ref[0, t].astype(jnp.float32)      # (C, N)
        b_t = b_ref[0, t].astype(jnp.float32)
        h = a_t * h_scr[...] + b_t
        h_scr[...] = h
        o_ref[0, t] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def selective_scan_bqcn(
    a: jax.Array,                 # (B, Q, C, N)
    b: jax.Array,                 # (B, Q, C, N)
    h0: jax.Array,                # (B, C, N)
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Q, C, N = a.shape
    block_c = min(block_c, C)
    assert C % block_c == 0, (C, block_c)
    nc = C // block_c

    kernel = functools.partial(_kernel, chunk=Q)
    out = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, block_c, N), lambda b_, c: (b_, 0, c, 0)),
            pl.BlockSpec((1, Q, block_c, N), lambda b_, c: (b_, 0, c, 0)),
            pl.BlockSpec((1, block_c, N), lambda b_, c: (b_, c, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, Q, block_c, N), lambda b_, c: (b_, 0, c, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Q, C, N), jnp.float32),
        scratch_shapes=[compat.VMEM((block_c, N), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(a, b, h0)
    return out

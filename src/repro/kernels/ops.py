"""jit'd wrappers over the Pallas kernels, in model layouts.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
on CPU for correctness); on TPU backends the compiled kernels run.  Model
code calls these through ``impl="pallas"``.

Backend detection happens HERE, in the plain-Python wrappers, before the
jitted inner functions are entered.  ``interpret`` is a static argument,
so resolving it inside the traced body would bake ``jax.default_backend()``
at first-trace time into the cache entry for ``interpret=None`` — a later
call under a different backend (e.g. a CPU fallback after TPU init, or a
``jax.default_device`` context) would silently reuse the stale choice.
Resolved pre-jit, every distinct backend decision gets its own cache
entry keyed on the concrete boolean.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.flash_decode import flash_decode_bhd
from repro.kernels.moe_gmm import moe_gmm_ecf
from repro.kernels.selective_scan import selective_scan_bqcn


def _default_interpret() -> bool:
    """Interpret off-TPU.  Must only be called from un-jitted code."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "prefix_len", "block_q",
                     "block_kv", "interpret"),
)
def _flash_attention_jit(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    block_q: int,
    block_kv: int,
    interpret: bool,
) -> jax.Array:
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        prefix_len=prefix_len,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def flash_attention(
    q: jax.Array,                 # model layout (B, S, H, D)
    k: jax.Array,                 # (B, S, Kv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    return _flash_attention_jit(
        q, k, v,
        causal=causal,
        window=window,
        prefix_len=prefix_len,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_kv", "interpret")
)
def _flash_decode_jit(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_valid: jax.Array,
    *,
    block_kv: int,
    interpret: bool,
) -> jax.Array:
    out = flash_decode_bhd(
        q[:, 0],
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        kv_valid,
        block_kv=block_kv,
        interpret=interpret,
    )
    return out[:, None]


def flash_decode(
    q: jax.Array,                 # (B, 1, H, D) model layout
    k_cache: jax.Array,           # (B, S, Kv, D)
    v_cache: jax.Array,
    *,
    kv_valid: jax.Array,          # (B, S)
    block_kv: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    return _flash_decode_jit(
        q, k_cache, v_cache, kv_valid,
        block_kv=block_kv, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_c", "interpret")
)
def _selective_scan_jit(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    block_c: int,
    interpret: bool,
) -> jax.Array:
    return selective_scan_bqcn(
        a, b, h0, block_c=block_c, interpret=interpret
    )


def selective_scan(
    a: jax.Array,                 # (B, Q, C, N)
    b: jax.Array,
    h0: jax.Array,                # (B, C, N)
    *,
    block_c: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    C = a.shape[2]
    bc = block_c
    while C % bc:
        bc //= 2
    return _selective_scan_jit(
        a, b, h0, block_c=max(bc, 1), interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def _moe_gmm_jit(
    x: jax.Array,
    w: jax.Array,
    *,
    interpret: bool,
) -> jax.Array:
    return moe_gmm_ecf(x, w, interpret=interpret)


def moe_gmm(
    x: jax.Array,                 # (E, C, D)
    w: jax.Array,                 # (E, D, F)
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    return _moe_gmm_jit(x, w, interpret=interpret)


def moe_ffn(
    xe: jax.Array,                # (E, C, D)
    wi: jax.Array,                # (E, D, F)
    wg: Optional[jax.Array],
    wo: jax.Array,                # (E, F, D)
    *,
    act: str = "silu",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Full expert FFN via the grouped-matmul kernel."""
    if interpret is None:
        interpret = _default_interpret()
    h = moe_gmm(xe, wi, interpret=interpret)
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    if wg is not None:
        h = a(moe_gmm(xe, wg, interpret=interpret)) * h
    else:
        h = a(h)
    return moe_gmm(h, wo, interpret=interpret)

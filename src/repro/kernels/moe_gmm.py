"""MoE grouped matmul Pallas TPU kernel.

Computes y[e] = x[e] @ w[e] for every expert e: x (E, C, D), w (E, D, F),
y (E, C, F).  Grid (E, C/bc, F/bf, D/bd) with an fp32 VMEM accumulator over
the contraction blocks — per-expert tiles stream through the MXU without
materializing any (C, D) × (D, F) intermediate in HBM.

Block shapes are MXU-aligned (multiples of 128 on the minor dims); the
capacity dim C comes from the router (ops.py pads it to the sublane
multiple)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d: int):
    dj = pl.program_id(3)

    @pl.when(dj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # (bc, bd)
    w = w_ref[0].astype(jnp.float32)          # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(dj == n_d - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm_ecf(
    x: jax.Array,                 # (E, C, D)
    w: jax.Array,                 # (E, D, F)
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = x.shape
    F = w.shape[2]
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)

    pad_c = (-C) % block_c
    pad_d = (-D) % block_d
    pad_f = (-F) % block_f
    if pad_c or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, pad_d)))
    if pad_d or pad_f:
        w = jnp.pad(w, ((0, 0), (0, pad_d), (0, pad_f)))
    nc = (C + pad_c) // block_c
    nd = (D + pad_d) // block_d
    nf = (F + pad_f) // block_f

    kernel = functools.partial(_kernel, n_d=nd)
    out = pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec(
                (1, block_c, block_d), lambda e, c, f, d: (e, c, d)
            ),
            pl.BlockSpec(
                (1, block_d, block_f), lambda e, c, f, d: (e, d, f)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, c, f, d: (e, c, f)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (E, C + pad_c, F + pad_f), x.dtype
        ),
        scratch_shapes=[compat.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]

"""Flash attention (prefill) Pallas TPU kernel.

Layout: q (B, H, Sq, D), k/v (B, Kv, Skv, D), out (B, H, Sq, D).

Grid: (B, H, nQ, nKV) with dimension semantics (parallel, parallel,
parallel, arbitrary) — the trailing KV axis is the sequential reduction:
running max ``m``, denominator ``l`` and the fp32 accumulator live in VMEM
scratch across KV iterations; the output block is written on the last one.

Causal / sliding-window block skipping happens at *block* granularity via
``pl.when`` — fully-masked (q_blk, kv_blk) pairs issue no MXU work, which
is what cuts the 2× causal waste of the jnp blockwise path on TPU.

GQA is folded into the index_map: kv block index = h // (H // Kv).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,             # VMEM blocks
    o_ref,                            # output block
    m_scr, l_scr, acc_scr,            # scratch (VMEM)
    *,
    scale: float,
    block_q: int,
    block_kv: int,
    n_kv: int,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    seq_q: int,
    seq_kv: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv

    # block-level skip decision (static per (qi,kj) pair at trace time is
    # not possible — grid indices are dynamic — so use pl.when)
    live = jnp.asarray(True)
    if causal:
        # fully masked above the diagonal: first q pos < first kv pos
        live = jnp.logical_and(
            live, q_start + block_q - 1 >= k_start
        )
    if window is not None:
        # fully outside the window: last q pos - first kv pos >= window
        live = jnp.logical_and(
            live, q_start - (k_start + block_kv - 1) < window
        )
    if prefix_len > 0:
        # prefix zone is always live
        live = jnp.logical_or(live, k_start < prefix_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bkv)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        mask = k_pos < seq_kv                          # kv padding
        mask = jnp.logical_and(mask, q_pos < seq_q)
        if causal:
            c = q_pos >= k_pos
            if prefix_len > 0:
                c = jnp.logical_or(c, k_pos < prefix_len)
            mask = jnp.logical_and(mask, c)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq,)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,                     # (B, H, Sq, D)
    k: jax.Array,                     # (B, Kv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nkv = (Skv + pad_kv) // block_kv

    kernel = functools.partial(
        _kernel,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=nkv,
        causal=causal,
        window=window,
        prefix_len=prefix_len,
        seq_q=Sq,
        seq_kv=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, i, j, G=G: (b, h // G, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, i, j, G=G: (b, h // G, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            compat.VMEM((block_q,), jnp.float32),
            compat.VMEM((block_q,), jnp.float32),
            compat.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]

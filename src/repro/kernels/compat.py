"""Pallas TPU API compatibility shim.

The Pallas TPU surface has drifted across JAX releases: the compiler-
options dataclass was published as ``TPUCompilerParams`` (jax <= 0.4.x /
0.5.x) and renamed to ``CompilerParams`` later; the VMEM scratch-space
handle has likewise moved between spellings.  The seed kernels were
written against the newer spelling, which left the whole data plane dead
under older-but-supported JAX versions (``AttributeError: module
'jax.experimental.pallas.tpu' has no attribute 'CompilerParams'``).

All four kernels resolve the drifted symbols through this module, so a
JAX upgrade (or downgrade within the tested range in ``pyproject.toml``)
is a one-file fix.  Resolution happens at import time; the ``resolve_*``
helpers take the module as an argument so tests can exercise both API
spellings without touching the installed JAX.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax.experimental.pallas.tpu as _pltpu

__all__ = [
    "CompilerParams",
    "VMEM",
    "compiler_params",
    "resolve_compiler_params_cls",
    "resolve_vmem",
]

# Preferred spelling first: the current JAX name wins when both exist.
_COMPILER_PARAMS_NAMES = ("CompilerParams", "TPUCompilerParams")
_VMEM_NAMES = ("VMEM",)


def resolve_compiler_params_cls(module: Any = _pltpu) -> Any:
    """The TPU compiler-options class under whichever name ``module`` has."""
    for name in _COMPILER_PARAMS_NAMES:
        cls = getattr(module, name, None)
        if cls is not None:
            return cls
    raise ImportError(
        f"jax.experimental.pallas.tpu exposes none of "
        f"{_COMPILER_PARAMS_NAMES}; this JAX version is outside the "
        "range supported by repro.kernels (see pyproject.toml)"
    )


def resolve_vmem(module: Any = _pltpu) -> Any:
    """The VMEM memory-space handle used for scratch shapes."""
    for name in _VMEM_NAMES:
        obj = getattr(module, name, None)
        if obj is not None:
            return obj
    ms = getattr(module, "MemorySpace", None)
    if ms is not None and hasattr(ms, "VMEM"):
        return ms.VMEM
    raise ImportError(
        "jax.experimental.pallas.tpu has no VMEM handle; this JAX version "
        "is outside the range supported by repro.kernels"
    )


CompilerParams = resolve_compiler_params_cls()
VMEM = resolve_vmem()


def compiler_params(
    *, dimension_semantics: Sequence[str], **kwargs: Any
) -> Any:
    """Build TPU compiler params portably.

    ``dimension_semantics`` is accepted by every known spelling of the
    class; further keywords pass through verbatim for callers that need
    version-specific knobs.
    """
    return CompilerParams(
        dimension_semantics=tuple(dimension_semantics), **kwargs
    )

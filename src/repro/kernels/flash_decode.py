"""Flash decode Pallas TPU kernel: one query token vs. a long KV cache.

Layout: q (B, H, D), k/v (B, Kv, S, D), valid (B, S) int8, out (B, H, D).

Grid: (B, H, nKV) — the KV axis is the sequential reduction with running
max / denominator in VMEM scratch (split-K style flash decoding).  The
validity mask (cache occupancy, ring-buffer slots) rides along as a blocked
int8 input, so arbitrary cache lengths need no recompile.

Decode attention is HBM-bandwidth-bound (read the whole KV cache once per
token); the kernel's job is to keep the reads streaming at full ``(8,128)``
tile efficiency with zero intermediate HBM traffic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, valid_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    n_kv: int,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                # (1, D) row block
    k = k_ref[0, 0].astype(jnp.float32)             # (bkv, D)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = valid_ref[0] != 0                        # (bkv,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0] * scale                                     # (bkv,)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[0]
    l_prev = l_scr[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[0] = l_prev * corr + p.sum()
    m_scr[0] = m_new
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p[None, :], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[0], 1e-20)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)   # (1, D)


def flash_decode_bhd(
    q: jax.Array,                 # (B, H, D)
    k: jax.Array,                 # (B, Kv, S, D)
    v: jax.Array,
    valid: jax.Array,             # (B, S) int8/bool
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    Kv, S = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / math.sqrt(D)

    block_kv = min(block_kv, S)
    pad = (-S) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nkv = (S + pad) // block_kv
    valid = valid.astype(jnp.int8)

    kernel = functools.partial(_kernel, scale=scale, n_kv=nkv)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, j, G=G: (b, h // G, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, j, G=G: (b, h // G, j, 0),
            ),
            pl.BlockSpec((1, block_kv), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            compat.VMEM((1,), jnp.float32),
            compat.VMEM((1,), jnp.float32),
            compat.VMEM((1, D), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, valid)
    return out

"""Pallas TPU kernels for the data-plane hot spots.

Each kernel ships three artifacts:

* ``<name>.py``  — the ``pl.pallas_call`` + explicit BlockSpec VMEM tiling,
* ``ops.py``     — jit'd wrappers with model-layout transforms and the
                   ``interpret`` switch (True on CPU: the kernel body runs
                   in Python for correctness validation),
* ``ref.py``     — pure-jnp oracles the tests ``assert_allclose`` against.

``compat.py`` absorbs Pallas TPU API drift across JAX versions
(``TPUCompilerParams`` vs ``CompilerParams``, the VMEM handle); kernels
never touch ``jax.experimental.pallas.tpu`` symbols directly.

Kernels:

* ``flash_attention``  — prefill attention (online softmax, causal /
  sliding-window block skipping, GQA via index_map head folding).
* ``flash_decode``     — one-query-token attention vs. a long KV cache,
  blocked over KV with running max/denominator.
* ``selective_scan``   — Mamba-1 within-chunk recurrence h' = a·h + b.
* ``moe_gmm``          — grouped (per-expert) matmul for MoE FFNs.

TPU tiling notes: MXU wants the two minor dims in multiples of (8, 128)
for fp32 / (16, 128) for bf16; all BlockSpecs here keep the last dim a
multiple of 128 and the second-minor a multiple of the sublane count.
"""

from repro.kernels import compat, ops, ref

__all__ = ["compat", "ops", "ref"]

"""Build a model object from a ModelConfig (``--arch`` entry point)."""

from __future__ import annotations

from typing import Any, Union

from repro.models.config import ModelConfig
from repro.models.lm import TransformerLM
from repro.models.whisper import EncDecLM

Model = Union[TransformerLM, EncDecLM]


def build_model(cfg: ModelConfig, **kwargs: Any) -> Model:
    """Instantiate the right model class for a config.

    kwargs are forwarded (``impl``, ``q_block``, ``kv_block``, ``ssm_chunk``,
    ``remat``) so callers can select jnp vs Pallas paths and block shapes.
    """
    if cfg.is_encdec:
        kwargs.pop("ssm_chunk", None)
        return EncDecLM(cfg, **kwargs)
    return TransformerLM(cfg, **kwargs)

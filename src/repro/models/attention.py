"""Attention: GQA / sliding-window / prefix-LM, prefill + decode paths.

Three compute paths:

* ``naive_attention``      O(S²) memory — smoke tests and kernel oracles only.
* ``blockwise_attention``  online-softmax double-``lax.scan`` over Q and KV
  blocks: O(S·block) live memory.  This is the default full-sequence path —
  it keeps the dry-run's ``memory_analysis()`` honest at 32k-500k context.
  Sliding-window attention gathers only the KV blocks inside the window
  (O(S·W) compute instead of O(S²)).
* ``decode_attention``     one query token vs. the KV cache (O(S) compute);
  supports ring-buffer caches for SWA.

The Pallas TPU kernels in ``repro.kernels`` implement the same contracts
(``flash_attention``, ``flash_decode``) and are validated against the naive
oracle; model code selects kernels via the ``impl`` argument.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec, dense_spec
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rmsnorm_spec, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter blueprint
# ---------------------------------------------------------------------------


def attention_blueprint(cfg: ModelConfig) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    bp: Dict[str, Any] = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        bp["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        bp["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        bp["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        bp["q_norm"] = rmsnorm_spec(hd, "head_dim")
        bp["k_norm"] = rmsnorm_spec(hd, "head_dim")
    return bp


# ---------------------------------------------------------------------------
# Mask helpers
# ---------------------------------------------------------------------------


_PAD_POS = jnp.iinfo(jnp.int32).max - 1   # sentinel for padded kv slots


def _pair_mask(
    q_pos: jax.Array,        # (Sq,)
    kv_pos: jax.Array,       # (Skv,)
    *,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
) -> jax.Array:
    """(Sq, Skv) boolean mask. prefix_len>0 = prefix-LM bidirectional zone.
    Padded KV slots (position == sentinel) are always masked — this is what
    keeps the blockwise path exact for non-causal (encoder) attention."""
    m = kv_pos[None, :] < _PAD_POS
    m = jnp.broadcast_to(m, (q_pos.shape[0], kv_pos.shape[0]))
    if causal:
        c = q_pos[:, None] >= kv_pos[None, :]
        if prefix_len:
            c = c | (kv_pos[None, :] < prefix_len)
        m = m & c
    if window is not None:
        m = m & (q_pos[:, None] - kv_pos[None, :] < window)
    return m


# ---------------------------------------------------------------------------
# Naive O(S^2) oracle
# ---------------------------------------------------------------------------


def naive_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, Kv, D)
    v: jax.Array,            # (B, Skv, Kv, D)
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    kv_valid: Optional[jax.Array] = None,   # (B, Skv) extra validity
) -> jax.Array:
    B, Sq, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    scores = jnp.einsum(
        "bqkgd,bmkd->bkgqm", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    mask = _pair_mask(
        q_pos, kv_pos, causal=causal, window=window, prefix_len=prefix_len
    )
    if kv_valid is not None:
        mask = mask[None] & kv_valid[:, None, :]
        mask = mask[:, None, None]          # (B,1,1,Sq,Skv)
    else:
        mask = mask[None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqm,bmkd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# Blockwise (memory-efficient) attention
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad), n


def blockwise_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, Kv, D)
    v: jax.Array,
    *,
    q_pos: jax.Array,        # (Sq,) int32
    kv_pos: jax.Array,       # (Skv,)
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    causal_split: int = 2,   # triangle-decomposition depth (0 = off)
) -> jax.Array:
    """Online-softmax attention; O(q_block·kv_block) live score memory.

    Outer ``lax.scan`` over Q blocks; inner ``lax.scan`` over KV blocks.
    For sliding-window attention only the KV blocks that intersect the
    window are visited (dynamic_slice on the block axis), making prefill
    O(S·W) rather than O(S²).

    Causal triangle decomposition (``causal_split`` > 0): a dense scan
    computes the full S×S rectangle and masks half of it away — 2× wasted
    MXU work.  Splitting the sequence in half turns the lower-left quarter
    into an unmasked (dense, zero-waste) rectangle and recurses on the two
    diagonal triangles; partial softmax states merge exactly via the
    (m, l, acc) algebra.  FLOPs: S²·(1 + 2^-depth)/2 vs S².  §Perf
    iteration 1 measures this on paligemma-3b × prefill_32k.
    """
    if (
        causal_split > 0
        and causal
        and window is None
        and q.shape[1] == k.shape[1]
        and q.shape[1] >= 4 * q_block
        and q.shape[1] % 2 == 0
        and prefix_len <= q.shape[1] // 2     # prefix-LM: zone in top half
    ):
        S = q.shape[1]
        h = S // 2
        # bottom-left rectangle: every q >= h attends every kv < h under
        # causal AND under prefix-LM (kv < prefix < h also attends) — dense
        top = blockwise_attention(
            q[:, :h], k[:, :h], v[:, :h],
            q_pos=q_pos[:h], kv_pos=kv_pos[:h], causal=True,
            prefix_len=prefix_len,
            q_block=q_block, kv_block=kv_block,
            causal_split=causal_split - 1,
        )
        # bottom-left: dense rectangle, zero masked work
        acc_l, m_l, l_l = _attend_raw(
            q[:, h:], k[:, :h], v[:, :h],
            q_pos=q_pos[h:], kv_pos=kv_pos[:h], causal=False,
            window=None, prefix_len=0,
            q_block=q_block, kv_block=kv_block,
        )
        # bottom-right: the recursive triangle
        acc_r, m_r, l_r = _attend_raw(
            q[:, h:], k[:, h:], v[:, h:],
            q_pos=q_pos[h:], kv_pos=kv_pos[h:], causal=True,
            window=None, prefix_len=0,
            q_block=q_block, kv_block=kv_block,
        )
        m = jnp.maximum(m_l, m_r)
        wl = jnp.exp(m_l - m)
        wr = jnp.exp(m_r - m)
        l = l_l * wl + l_r * wr
        acc = acc_l * wl[..., None] + acc_r * wr[..., None]
        l = jnp.maximum(l, 1e-20)
        bottom = (acc / l[..., None])
        B, _, Kv, G, D = bottom.shape
        bottom = bottom.reshape(B, S - h, Kv * G, D).astype(q.dtype)
        return jnp.concatenate([top, bottom], axis=1)
    acc, m, l = _attend_raw(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, window=window,
        prefix_len=prefix_len, q_block=q_block, kv_block=kv_block,
    )
    B, Sq, Kv, G, D = acc.shape
    l = jnp.maximum(l, 1e-20)
    out = (acc / l[..., None]).reshape(B, Sq, Kv * G, D)
    return out.astype(q.dtype)


def _attend_raw(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, Kv, D)
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    q_block: int,
    kv_block: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized online-softmax attention.

    Returns (acc (B,Sq,Kv,G,D), m (B,Sq,Kv,G), l (B,Sq,Kv,G)) so partial
    results over disjoint KV ranges merge exactly (triangle decomposition,
    sequence-parallel attention)."""
    B, Sq, H, D = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / math.sqrt(D)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)

    qp, _ = _pad_to(q, 1, q_block)
    qpos_p, _ = _pad_to(q_pos, 0, q_block)
    kp, _ = _pad_to(k, 1, kv_block)
    vp, _ = _pad_to(v, 1, kv_block)
    kvpos_p, _ = _pad_to(kv_pos, 0, kv_block)
    # padded kv positions must never be attended: sentinel position
    if kvpos_p.shape[0] != Skv:
        kvpos_p = kvpos_p.at[Skv:].set(_PAD_POS)
    nq = qp.shape[1] // q_block
    nkv = kp.shape[1] // kv_block

    qb = qp.reshape(B, nq, q_block, Kv, G, D).astype(jnp.float32)
    kb = kp.reshape(B, nkv, kv_block, Kv, D).astype(jnp.float32)
    vb = vp.reshape(B, nkv, kv_block, Kv, D).astype(jnp.float32)
    qposb = qpos_p.reshape(nq, q_block)
    kvposb = kvpos_p.reshape(nkv, kv_block)

    # SWA: per q-block, number of kv blocks that can intersect the window
    if window is not None and causal and prefix_len == 0:
        span = (window + q_block) // kv_block + 2
        span = min(span, nkv)
    else:
        span = nkv

    def q_step(_, qi):
        qblk = qb[:, qi]                     # (B, q_block, Kv, G, D)
        qpos_i = qposb[qi]

        def kv_step(carry, kj):
            m_prev, l_prev, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
            kvpos_j = jax.lax.dynamic_index_in_dim(
                kvposb, kj, 0, keepdims=False
            )
            s = (
                jnp.einsum("bqkgd,bmkd->bkgqm", qblk, kblk) * scale
            )  # (B, Kv, G, q_block, kv_block)
            mask = _pair_mask(
                qpos_i, kvpos_j, causal=causal, window=window,
                prefix_len=prefix_len,
            )
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqm,bmkd->bkgqd", p, vblk
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Kv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, q_block, D), jnp.float32)

        if span == nkv:
            kv_ids = jnp.arange(nkv)
        else:
            # visit only blocks [hi-span+1 .. hi] where hi is the last block
            # whose first position <= this q-block's last position
            hi = (qpos_i[-1] // kv_block).astype(jnp.int32)
            kv_ids = jnp.clip(hi - span + 1 + jnp.arange(span), 0, nkv - 1)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kv_ids)
        # -> (B, q_block, Kv, G[, D])
        return None, (
            acc.transpose(0, 3, 1, 2, 4),
            m.transpose(0, 3, 1, 2),
            l.transpose(0, 3, 1, 2),
        )

    _, (accs, ms, ls) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # accs: (nq, B, q_block, Kv, G, D)
    acc = accs.transpose(1, 0, 2, 3, 4, 5).reshape(
        B, nq * q_block, Kv, G, D
    )[:, :Sq]
    m = ms.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, Kv, G)[:, :Sq]
    l = ls.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, Kv, G)[:, :Sq]
    return acc, m, l


# ---------------------------------------------------------------------------
# Decode attention (one new token vs cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,            # (B, 1, H, D)
    k_cache: jax.Array,      # (B, S_cache, Kv, D) — RoPE already applied
    v_cache: jax.Array,
    *,
    kv_valid: jax.Array,     # (B, S_cache) bool — slot validity
) -> jax.Array:
    B, _, H, D = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, D).astype(jnp.float32)
    s = jnp.einsum(
        "bkgd,bmkd->bkgm", qg, k_cache.astype(jnp.float32)
    ) / math.sqrt(D)
    s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgm,bmkd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention module (projections + rope + cache management)
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any
) -> Dict[str, Any]:
    """Per-layer-stack KV cache.  SWA archs use a ring buffer of the window
    size; dense archs use the full context length."""
    if cfg.sliding_window is not None:
        slots = min(max_len, cfg.sliding_window)
    else:
        slots = max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, slots, kv, hd), dtype),
        "v": jnp.zeros((L, batch, slots, kv, hd), dtype),
    }


def kv_cache_abstract(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any
) -> Dict[str, Any]:
    if cfg.sliding_window is not None:
        slots = min(max_len, cfg.sliding_window)
    else:
        slots = max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    shape = (L, batch, slots, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def attention_apply(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                      # (B, S, d_model)
    *,
    positions: jax.Array,              # (S,) absolute positions
    mode: str,                         # "full" | "decode"
    layer_cache: Optional[Dict[str, jax.Array]] = None,  # (B, slots, Kv, D)
    cache_len: Optional[jax.Array] = None,   # scalar int32: tokens already in cache
    causal: bool = True,
    prefix_len: int = 0,
    impl: str = "blockwise",
    q_block: int = 512,
    kv_block: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (output (B,S,d_model), updated layer cache or None)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "full":
        if impl == "naive":
            out = naive_attention(
                q, k, v, q_pos=positions, kv_pos=positions, causal=causal,
                window=cfg.sliding_window, prefix_len=prefix_len,
            )
        elif impl == "pallas":
            from repro.kernels import ops as kops

            out = kops.flash_attention(
                q, k, v, causal=causal, window=cfg.sliding_window,
                prefix_len=prefix_len,
            )
        else:
            out = blockwise_attention(
                q, k, v, q_pos=positions, kv_pos=positions, causal=causal,
                window=cfg.sliding_window, prefix_len=prefix_len,
                q_block=q_block, kv_block=kv_block,
            )
        new_cache = None
        if layer_cache is not None:
            # prefill: write K/V (post-RoPE) into the cache
            slots = layer_cache["k"].shape[1]
            if cfg.sliding_window is not None and S > slots:
                # keep the last `slots` positions, ring-aligned
                k_tail, v_tail = k[:, -slots:], v[:, -slots:]
                pos_tail = positions[-slots:]
                idx = pos_tail % slots
                ck = layer_cache["k"].at[:, idx].set(
                    k_tail.astype(layer_cache["k"].dtype)
                )
                cv = layer_cache["v"].at[:, idx].set(
                    v_tail.astype(layer_cache["v"].dtype)
                )
            else:
                start = positions[0]
                if cfg.sliding_window is not None:
                    start = start % slots
                ck = jax.lax.dynamic_update_slice(
                    layer_cache["k"],
                    k.astype(layer_cache["k"].dtype),
                    (0, start, 0, 0),
                )
                cv = jax.lax.dynamic_update_slice(
                    layer_cache["v"],
                    v.astype(layer_cache["v"].dtype),
                    (0, start, 0, 0),
                )
            new_cache = {"k": ck, "v": cv}
    elif mode == "decode":
        assert layer_cache is not None and cache_len is not None
        slots = layer_cache["k"].shape[1]
        pos = positions[0]  # scalar: absolute position of the new token
        slot = pos % slots if cfg.sliding_window is not None else pos
        ck = jax.lax.dynamic_update_slice(
            layer_cache["k"], k.astype(layer_cache["k"].dtype),
            (0, slot, 0, 0),
        )
        cv = jax.lax.dynamic_update_slice(
            layer_cache["v"], v.astype(layer_cache["v"].dtype),
            (0, slot, 0, 0),
        )
        n_filled = jnp.minimum(cache_len + 1, slots)
        slot_ids = jnp.arange(slots)
        if cfg.sliding_window is not None:
            valid = slot_ids[None, :] < n_filled
        else:
            valid = slot_ids[None, :] < (cache_len + 1)
        valid = jnp.broadcast_to(valid, (B, slots))
        if impl == "pallas":
            from repro.kernels import ops as kops

            out = kops.flash_decode(q, ck, cv, kv_valid=valid)
        else:
            out = decode_attention(q, ck, cv, kv_valid=valid)
        new_cache = {"k": ck, "v": cv}
    else:
        raise ValueError(f"unknown mode {mode!r}")

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return y, new_cache

"""Parameter blueprints: one definition, three views.

A model definition builds a *blueprint* — a pytree (nested dict) of
:class:`ParamSpec` leaves.  From it we derive:

* ``init_params(bp, key)``     materialized parameters,
* ``abstract_params(bp)``      ``jax.ShapeDtypeStruct`` stand-ins — the
                               multi-pod dry-run lowers full-size models
                               (35B+) without allocating anything,
* ``logical_axes(bp)``         logical sharding axes per leaf, consumed by
                               ``repro.distributed.sharding`` rule tables,
* ``param_count(bp)``          exact parameter count (roofline §MODEL_FLOPS).

Logical axis names used throughout the zoo:

    "embed"     residual/model dimension
    "heads"     query heads            "kv_heads"  key/value heads
    "head_dim"  per-head dim           "mlp"       feed-forward hidden
    "vocab"     vocabulary             "layers"    stacked (scanned) layers
    "experts"   MoE experts            "expert_mlp" per-expert hidden
    "ssm_inner" SSM inner dim          "ssm_state" SSM state dim
    "conv"      conv kernel taps        None        never sharded
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declares one parameter tensor."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | embed
    scale: float = 1.0          # stddev multiplier for "normal"
    dtype: Any = jnp.float32

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} and logical {self.logical} rank mismatch"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


Blueprint = Any  # nested dict with ParamSpec leaves


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(spec: ParamSpec) -> int:
    """Fan-in for variance scaling: all dims but the last."""
    if len(spec.shape) <= 1:
        return max(spec.shape[0] if spec.shape else 1, 1)
    return max(int(np.prod(spec.shape[:-1])), 1)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        # embedding init: unit normal scaled down
        std = spec.scale
        return (
            jax.random.normal(key, spec.shape, jnp.float32) * std
        ).astype(spec.dtype)
    if spec.init == "normal":
        # truncated-normal variance scaling (fan-in), like flax defaults
        std = spec.scale / math.sqrt(_fan_in(spec))
        x = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape,
                                        jnp.float32)
        return (x * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(bp: Blueprint, key: jax.Array) -> Any:
    """Materialize parameters (smoke tests / examples / checkpoints)."""
    leaves, treedef = jax.tree_util.tree_flatten(bp, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(spec, k) for spec, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(bp: Blueprint, dtype: Any = None) -> Any:
    """ShapeDtypeStruct view — zero allocation (dry-run input)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        bp,
        is_leaf=_is_spec,
    )


def logical_axes(bp: Blueprint) -> Any:
    """Logical-axis pytree, mirroring the parameter structure."""
    return jax.tree_util.tree_map(lambda s: s.logical, bp, is_leaf=_is_spec)


def param_count(bp: Blueprint) -> int:
    return sum(
        s.size for s in jax.tree_util.tree_leaves(bp, is_leaf=_is_spec)
    )


def cast_params(params: Any, dtype: Any) -> Any:
    """Cast float leaves (weights) to ``dtype`` — serving runs bf16."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, params)


# ---------------------------------------------------------------------------
# Spec construction helpers (used by the model definitions)
# ---------------------------------------------------------------------------


def dense_spec(
    in_dim: int,
    out_dim: int,
    in_axis: Optional[str],
    out_axis: Optional[str],
    *,
    scale: float = 1.0,
    dtype: Any = jnp.float32,
) -> ParamSpec:
    return ParamSpec((in_dim, out_dim), (in_axis, out_axis), "normal",
                     scale, dtype)


def stacked(spec: ParamSpec, layers: int) -> ParamSpec:
    """Stack a per-layer spec along a leading scanned 'layers' axis."""
    return ParamSpec(
        (layers,) + spec.shape,
        ("layers",) + spec.logical,
        spec.init,
        spec.scale,
        spec.dtype,
    )


def stack_blueprint(bp: Blueprint, layers: int) -> Blueprint:
    """Stack every leaf of a per-layer blueprint for ``lax.scan``."""
    return jax.tree_util.tree_map(
        lambda s: stacked(s, layers), bp, is_leaf=_is_spec
    )

"""ModelConfig — a single declarative description covering all 10 assigned
architectures (dense / GQA / SWA / MoE / SSM / hybrid / enc-dec / VLM).

Every field is explicit; ``repro/configs/<arch>.py`` files instantiate the
exact published configurations.  ``scaled(...)`` derives the reduced smoke
configs (same family, small dims) required by the per-arch smoke tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str                       # dense | ssm | moe | hybrid | audio | vlm

    # -- core dims -----------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attention-free)
    num_kv_heads: int                 # GQA kv heads
    d_ff: int                         # FFN hidden (0 for attention-free/MoE-only)
    vocab_size: int

    head_dim: Optional[int] = None    # defaults to d_model // num_heads

    # -- attention flavor ----------------------------------------------------
    rope: bool = True                      # False: absolute positions (whisper)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # SWA window (h2o-danube3)
    qkv_bias: bool = False                 # qwen2.5
    qk_norm: bool = False                  # qwen3-moe
    prefix_lm: bool = False                # paligemma: bidirectional prefix
    logit_softcap: Optional[float] = None  # gemma-style logit soft capping

    # -- block structure -------------------------------------------------------
    parallel_block: bool = False      # command-r: attn + FFN in parallel
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu (SwiGLU) | gelu
    gated_mlp: Optional[bool] = None  # default: gated iff act == "silu"

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None    # per-expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # quantize tokens for the EP dispatch/combine all-to-all (e.g.
    # "float8_e4m3fn" halves MoE collective bytes; None = native dtype)
    moe_dispatch_dtype: Optional[str] = None

    # -- SSM (mamba) -----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 0            # 1 (falcon-mamba) | 2/SSD (zamba2)
    ssm_head_dim: int = 64            # mamba2 head dim

    # -- hybrid (zamba2) ---------------------------------------------------
    # a SHARED attention block applied after every ``hybrid_attn_every``
    # mamba layers (0 = no hybrid attention)
    hybrid_attn_every: int = 0

    # -- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    cross_attention: bool = False

    # -- modality frontend (stub per assignment) --------------------------------
    frontend: Optional[str] = None    # "audio-stub" | "vision-stub"
    frontend_seq: int = 0             # frames / patches fed by input_specs()

    # -- numerics ---------------------------------------------------------------
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.num_heads and self.num_kv_heads:
            if self.num_heads % self.num_kv_heads:
                raise ValueError(
                    f"{self.name}: num_heads {self.num_heads} not divisible "
                    f"by kv heads {self.num_kv_heads}"
                )

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if not self.num_heads:
            return 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        """SSM inner dim."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        """Mamba-2 head count."""
        if self.mamba_version != 2:
            return 0
        return self.d_inner // self.ssm_head_dim

    @property
    def mlp_gated(self) -> bool:
        if self.gated_mlp is not None:
            return self.gated_mlp
        return self.act == "silu"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing → runs the ``long_500k`` shape."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    # -- zamba2 layer arithmetic ------------------------------------------
    @property
    def hybrid_blocks(self) -> int:
        """Number of (shared-attn + mamba-group) super-blocks."""
        if not self.hybrid_attn_every:
            return 0
        # num_layers = prelude_mamba + blocks * (1 attn + (every-1) mamba)
        per_block = self.hybrid_attn_every
        return self.num_layers // per_block

    @property
    def hybrid_prelude(self) -> int:
        if not self.hybrid_attn_every:
            return 0
        return self.num_layers - self.hybrid_blocks * self.hybrid_attn_every

    @property
    def hybrid_mamba_layers(self) -> int:
        """Total mamba layers in the hybrid stack."""
        if not self.hybrid_attn_every:
            return 0
        return self.hybrid_prelude + self.hybrid_blocks * (
            self.hybrid_attn_every - 1
        )

    # ------------------------------------------------------------------
    def scaled(
        self,
        *,
        num_layers: Optional[int] = None,
        d_model: int = 128,
        d_ff_ratio: Optional[float] = None,
        vocab: int = 512,
        num_experts: Optional[int] = None,
        frontend_seq: Optional[int] = None,
    ) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        nh = self.num_heads
        nkv = self.num_kv_heads
        if nh:
            # keep the GQA *ratio*, shrink the counts
            ratio = nh // max(nkv, 1)
            nh = max(2, min(nh, 4))
            nkv = max(1, nh // min(ratio, nh))
        layers = num_layers
        if layers is None:
            layers = 2 if not self.hybrid_attn_every else self.hybrid_attn_every
        ratio_ff = (
            d_ff_ratio
            if d_ff_ratio is not None
            else (self.d_ff / self.d_model if self.d_ff else 0.0)
        )
        n_exp = num_experts if num_experts is not None else (
            min(self.num_experts, 8) if self.num_experts else 0
        )
        return dataclasses.replace(
            self,
            name=f"{self.name}-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=(d_model // nh) if nh else None,
            d_ff=int(d_model * ratio_ff) if self.d_ff else 0,
            moe_d_ff=(
                max(32, int(d_model * (self.expert_d_ff / self.d_model)))
                if self.is_moe
                else None
            ),
            vocab_size=vocab,
            vocab_pad_multiple=64,
            num_experts=n_exp,
            experts_per_token=(
                min(self.experts_per_token, n_exp) if n_exp else 0
            ),
            sliding_window=(
                min(self.sliding_window, 64)
                if self.sliding_window is not None
                else None
            ),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.mamba_version == 2 else self.ssm_head_dim,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_seq=(
                frontend_seq
                if frontend_seq is not None
                else (16 if self.frontend_seq else 0)
            ),
        )

    # -- parameter count estimate (roofline MODEL_FLOPS uses the exact
    #    blueprint count; this is a sanity cross-check) ---------------------
    def approx_params(self) -> int:
        d, L, V = self.d_model, self.num_layers, self.padded_vocab
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = 0
        if self.num_heads:
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
        ffn = 0
        if self.d_ff and not self.is_moe:
            mult = 3 if self.act == "silu" else 2
            ffn = mult * d * self.d_ff
        if self.is_moe:
            ffn = self.num_experts * 3 * d * self.expert_d_ff
        return emb + L * (attn + ffn)

"""Mixture-of-Experts layer (phi3.5-moe 16e/top-2, qwen3-moe 128e/top-8).

GShard/Switch-style capacity-based dispatch: static shapes, shardable with
EP (experts over the 'model' mesh axis).  Per expert capacity
``C = ceil(tokens · top_k / E · capacity_factor)``; overflow tokens drop
their contribution from the overflowing expert (their other experts still
fire).  The expert matmul is the MoE grouped-matmul hot spot — on TPU it is
served by the ``repro.kernels.moe_gmm`` Pallas kernel; the jnp path uses a
batched einsum over the expert axis.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec, dense_spec
from repro.models.config import ModelConfig


def moe_blueprint(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    bp: Dict[str, Any] = {
        "router": dense_spec(d, e, "embed", None),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.mlp_gated:
        bp["wg"] = ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"))
    return bp


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(
        n_tokens * cfg.experts_per_token / cfg.num_experts
        * cfg.capacity_factor
    )
    return max(int(c), 1)


def route_topk(
    router_logits: jax.Array,   # (N, E) fp32
    top_k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing with softmax-renormalized combine weights."""
    gates = jax.nn.softmax(router_logits, axis=-1)
    weights, idx = jax.lax.top_k(gates, top_k)          # (N, k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9
    )
    return weights, idx


def moe_apply(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                    # (B, S, d)
    *,
    impl: str = "einsum",            # "einsum" | "pallas"
    return_aux: bool = False,
    chunk_tokens: int = 16_384,
):
    """Capacity-based top-k MoE, chunked over tokens.

    Expert capacity is proportional to the CHUNK token count, so the
    dispatch buffer is O(chunk x d) regardless of sequence length (a 1M-
    token prefill would otherwise materialize a multi-GiB (E, C, d)
    scatter target).  Chunks run under ``lax.scan``.
    Returns (y, aux_loss?) — aux is the Switch load-balancing loss."""
    B, S, d = x.shape
    N = B * S
    if N > chunk_tokens and N % chunk_tokens == 0:
        xf = x.reshape(N // chunk_tokens, 1, chunk_tokens, d)

        def step(aux_acc, xc):
            y, aux = moe_apply(
                p, cfg, xc, impl=impl, return_aux=return_aux,
                chunk_tokens=chunk_tokens,
            )
            if aux is None:
                aux = jnp.zeros((), jnp.float32)
            return aux_acc + aux, y

        aux_sum, ys = jax.lax.scan(
            step, jnp.zeros((), jnp.float32), xf
        )
        y = ys.reshape(B, S, d)
        return (y, aux_sum / (N // chunk_tokens)) if return_aux \
            else (y, None)
    E, k = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, N)
    dt = x.dtype

    xf = x.reshape(N, d)
    router_logits = (
        xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    )
    weights, expert_idx = route_topk(router_logits, k)   # (N,k)

    # ---- capacity assignment -------------------------------------------
    # position of each (token, k) within its expert's queue
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (N,k,E)
    flat_onehot = onehot.reshape(N * k, E)
    pos_in_expert = (
        jnp.cumsum(flat_onehot, axis=0) * flat_onehot
    ).sum(axis=-1) - 1                                    # (N*k,)
    expert_flat = expert_idx.reshape(N * k)
    keep = pos_in_expert < C
    slot = jnp.where(keep, pos_in_expert, C)              # C = overflow bin

    # dispatch: scatter tokens into (E, C+1, d), drop the overflow bin.
    # Each (token, k) owns a unique slot, so scatter-add == scatter-set and
    # the transport dtype may be quantized: with moe_dispatch_dtype =
    # "float8_e4m3fn" the cross-shard token movement (the EP all-to-all —
    # the dominant collective of high-top-k MoE) halves (§Perf 5).
    wire_dt = (
        jnp.dtype(cfg.moe_dispatch_dtype) if cfg.moe_dispatch_dtype else dt
    )
    dispatch_idx = expert_flat * (C + 1) + slot           # (N*k,)
    token_idx = jnp.repeat(jnp.arange(N), k)
    buf = jnp.zeros((E * (C + 1), d), wire_dt)
    buf = buf.at[dispatch_idx].add(
        (xf[token_idx] * keep[:, None]).astype(wire_dt)
    )
    xe = buf.reshape(E, C + 1, d)[:, :C].astype(dt)       # (E, C, d)

    # ---- expert FFN -------------------------------------------------------
    if impl == "pallas":
        from repro.kernels import ops as kops

        ye = kops.moe_ffn(
            xe, p["wi"].astype(dt),
            p.get("wg", None) if "wg" in p else None,
            p["wo"].astype(dt), act=cfg.act,
        )
    else:
        h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        if "wg" in p:
            g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
            h = act(g) * h
        else:
            h = act(h)
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))

    # ---- combine (same quantized wire format on the way back) -----------
    ye_flat = jnp.concatenate(
        [ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1
    ).reshape(E * (C + 1), d).astype(wire_dt)
    gathered = ye_flat[dispatch_idx].astype(dt)           # (N*k, d)
    w = (weights.reshape(N * k) * keep).astype(dt)
    y = jnp.zeros((N, d), dt).at[token_idx].add(gathered * w[:, None])
    y = y.reshape(B, S, d)

    if not return_aux:
        return y, None
    # Switch aux loss: E * sum_e f_e * P_e
    probs = jax.nn.softmax(router_logits, axis=-1)        # (N,E)
    f = (onehot.sum(axis=1) > 0).astype(jnp.float32).mean(axis=0)  # (E,)
    pbar = probs.mean(axis=0)
    aux = cfg.num_experts * jnp.sum(f * pbar) * cfg.router_aux_coef
    return y, aux

"""Model zoo: the 10 assigned architectures as composable pure-JAX modules.

Everything is expressed with the *blueprint* system in ``repro.models.base``:
a model definition builds a pytree of :class:`ParamSpec` (shape, dtype,
logical axes, initializer).  From that single definition we derive

* ``init_params``      — materialized parameters (smoke tests, examples),
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod
  dry-run lowers 35B-parameter models without allocating a byte),
* ``logical_axes``     — logical sharding axes, mapped to mesh axes by
  ``repro.distributed.sharding``.

``TransformerLM`` covers dense / GQA / SWA / MoE / SSM / hybrid decoder-only
architectures (plus PaliGemma's prefix-embedding mode); ``EncDecLM`` covers
Whisper.  ``repro.models.registry`` builds either from a ``ModelConfig``.
"""

from repro.models.base import (
    ParamSpec,
    abstract_params,
    init_params,
    logical_axes,
    param_count,
)
from repro.models.config import ModelConfig
from repro.models.lm import TransformerLM
from repro.models.whisper import EncDecLM
from repro.models.registry import build_model

__all__ = [
    "ParamSpec",
    "abstract_params",
    "init_params",
    "logical_axes",
    "param_count",
    "ModelConfig",
    "TransformerLM",
    "EncDecLM",
    "build_model",
]

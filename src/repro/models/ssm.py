"""State-space sequence mixers: Mamba-1 (falcon-mamba) and Mamba-2/SSD
(zamba2).

Full-sequence processing is *chunked*: an outer ``lax.scan`` carries the SSM
state across chunks; within a chunk Mamba-1 uses an associative scan and
Mamba-2 uses the SSD matrix form (chunk-local quadratic + state passing).
Live memory is O(chunk) — the 500k-token dry-run depends on this.

Decode is a single-step recurrence with carried ``(conv_state, ssm_state)``
— O(1) in context length, which is why the SSM/hybrid architectures are the
ones that run the ``long_500k`` shape.

TPU hot spot: the within-chunk scan is served by the
``repro.kernels.selective_scan`` Pallas kernel (Mamba-1) — jnp paths here
double as its oracle.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec, dense_spec
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, rmsnorm_spec


# ---------------------------------------------------------------------------
# Depthwise causal conv (shared by both mamba versions)
# ---------------------------------------------------------------------------


def causal_conv1d(
    x: jax.Array,               # (B, S, C)
    w: jax.Array,               # (K, C) depthwise taps
    bias: Optional[jax.Array],  # (C,)
    prev: Optional[jax.Array] = None,   # (B, K-1, C) carried context
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,C), new_prev (B,K-1,C))."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros_like(x)
    for tap in range(K):
        y = y + xp[:, tap : tap + x.shape[1]] * w[tap].astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    new_prev = xp[:, -(K - 1):] if K > 1 else prev
    return y, new_prev


# ===========================================================================
# Mamba-1 (falcon-mamba-7b)
# ===========================================================================


def mamba1_blueprint(cfg: ModelConfig) -> Dict[str, Any]:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, di), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "x_proj": ParamSpec((di, dt_rank + 2 * N), ("ssm_inner", None)),
        "dt_proj": ParamSpec((dt_rank, di), (None, "ssm_inner")),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), "zeros"),
        # A_log: A = -exp(A_log); init so A ~ -[1..N] rows (S4D-real)
        "A_log": ParamSpec((di, N), ("ssm_inner", "ssm_state"), "zeros"),
        "D": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _mamba1_coeffs(p, cfg, x_conv, dt):
    """delta/B/C from the conv output; returns (a, bx, C) per step."""
    di, N = cfg.d_inner, cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    proj = x_conv @ p["x_proj"].astype(dt)               # (B,S,R+2N)
    delta_r, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(
        delta_r @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt)
    ).astype(jnp.float32)                                 # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di,N)
    a = jnp.exp(delta[..., None] * A)                     # (B,S,di,N)
    bx = (delta * x_conv.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[..., None, :]            # (B,S,di,N)
    return a, bx, Cc.astype(jnp.float32)


def mamba1_full(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                    # (B, S, d)
    *,
    chunk: int = 256,
    state: Optional[Dict[str, jax.Array]] = None,
    impl: str = "jnp",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence mamba-1; returns (y, {"conv","ssm"} final state)."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    dt = x.dtype

    xz = x @ p["in_proj"].astype(dt)                      # (B,S,2di)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_prev = None if state is None else state["conv"]
    x_conv, conv_state = causal_conv1d(
        xin, p["conv_w"], p["conv_b"], conv_prev
    )
    x_conv = jax.nn.silu(x_conv)

    a, bx, Cc = _mamba1_coeffs(p, cfg, x_conv, dt)

    h0 = (
        jnp.zeros((B, di, N), jnp.float32)
        if state is None
        else state["ssm"].astype(jnp.float32)
    )

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    nchunks = (S + pad) // chunk

    ach = a.reshape(B, nchunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    bch = bx.reshape(B, nchunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    cch = Cc.reshape(B, nchunks, chunk, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inputs):
        ac, bc, cc = inputs           # (B,chunk,di,N), ..., (B,chunk,N)
        if impl == "pallas":
            from repro.kernels import ops as kops

            hs = kops.selective_scan(ac, bc, h)
        else:
            # within-chunk associative scan: (a, b) ∘ (a', b') =
            # (a'·a, a'·b + b')
            def combine(l, r):
                al, bl = l
                ar, br = r
                return al * ar, bl * ar + br

            a_s, b_s = jax.lax.associative_scan(combine, (ac, bc), axis=1)
            hs = b_s + a_s * h[:, None]                   # (B,chunk,di,N)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cc)
        return hs[:, -1], y

    hN, ys = jax.lax.scan(
        chunk_step, h0, (ach, bch, cch)
    )  # ys: (nchunks, B, chunk, di)
    y = ys.transpose(1, 0, 2, 3).reshape(B, nchunks * chunk, di)[:, :S]
    y = y + x_conv.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt)
    out = y @ p["out_proj"].astype(dt)
    return out, {"conv": conv_state, "ssm": hN.astype(jnp.float32)}


def mamba1_decode(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                    # (B, 1, d)
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    xin, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = causal_conv1d(
        xin, p["conv_w"], p["conv_b"], state["conv"]
    )
    x_conv = jax.nn.silu(x_conv)
    a, bx, Cc = _mamba1_coeffs(p, cfg, x_conv, dt)
    h = state["ssm"].astype(jnp.float32) * a[:, 0] + bx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + x_conv.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt)
    return y @ p["out_proj"].astype(dt), {"conv": conv_state, "ssm": h}


def mamba1_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, tuple]:
    return {
        "conv": (batch, cfg.ssm_conv - 1, cfg.d_inner),
        "ssm": (batch, cfg.d_inner, cfg.ssm_state),
    }


# ===========================================================================
# Mamba-2 / SSD (zamba2)
# ===========================================================================


def mamba2_blueprint(cfg: ModelConfig) -> Dict[str, Any]:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = di + 2 * N          # conv over [x, B, C], single group
    return {
        # zxbcdt projection: [z(di), x(di), B(N), C(N), dt(H)]
        "in_proj": ParamSpec((d, 2 * di + 2 * N + H), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
        "dt_bias": ParamSpec((H,), ("heads",), "zeros"),
        "A_log": ParamSpec((H,), ("heads",), "zeros"),
        "D": ParamSpec((H,), ("heads",), "ones"),
        "norm": rmsnorm_spec(di, "ssm_inner"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _segsum(loga: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum_{j<t<=i} loga[..., t],
    -inf for j > i.  loga: (..., Q) -> (..., Q, Q)."""
    Q = loga.shape[-1]
    cs = jnp.cumsum(loga, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]            # sum_(j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_full(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                    # (B, S, d)
    *,
    chunk: int = 256,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked SSD (Mamba-2).  Single B/C group."""
    Bsz, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_prev = None if state is None else state["conv"]
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_prev)
    xbc = jax.nn.silu(xbc)
    xin, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)

    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                     # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    loga = delta * A                                       # (B,S,H)
    xh = xin.reshape(Bsz, S, H, P).astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)                            # (B,S,N)
    Cc = Cc.astype(jnp.float32)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
    nchunks = (S + pad) // chunk

    def r(t, shape):  # (B, nchunks, chunk, ...) -> scan-major
        return t.reshape((Bsz, nchunks, chunk) + shape).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(shape)))
        )

    loga_c = r(loga, (H,))
    x_c = r(xh, (H, P))
    B_c = r(Bc, (N,))
    C_c = r(Cc, (N,))
    dt_c = r(delta, (H,))

    h0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if state is None
        else state["ssm"].astype(jnp.float32)
    )

    def chunk_step(h, inputs):
        la, xc, bc, cc, dc = inputs
        # la: (B,Q,H)  xc: (B,Q,H,P)  bc/cc: (B,Q,N)  dc: (B,Q,H)
        lah = la.transpose(0, 2, 1)                        # (B,H,Q)
        L = jnp.exp(_segsum(lah))                          # (B,H,Q,Q)
        # intra-chunk (attention-like): Y1[i] = sum_j<=i C_i·B_j L_ij dt_j x_j
        G = jnp.einsum("bin,bjn->bij", cc, bc)             # (B,Q,Q)
        M = G[:, None] * L                                  # (B,H,Q,Q)
        y_intra = jnp.einsum("bhij,bjh,bjhp->bihp", M, dc, xc)
        # inter-chunk: contribution of the carried state
        cumla = jnp.exp(jnp.cumsum(lah, axis=-1))          # (B,H,Q)
        y_inter = jnp.einsum(
            "bin,bhnp,bhi->bihp", cc, h.transpose(0, 1, 3, 2), cumla
        )
        y = y_intra + y_inter                               # (B,Q,H,P)
        # state update: h' = a_tot h + sum_j (prod_{t>j} a) dt_j B_j x_j
        a_tot = cumla[..., -1]                              # (B,H)
        decay = jnp.exp(
            jnp.cumsum(lah[..., ::-1], axis=-1)[..., ::-1] - lah
        )                                                   # (B,H,Q): prod_{t>j}
        dBx = jnp.einsum("bjh,bjn,bjhp->bhpn", dc * decay.transpose(0, 2, 1),
                         bc, xc)
        h_new = h * a_tot[..., None, None] + dBx
        return h_new, y

    hN, ys = jax.lax.scan(chunk_step, h0, (loga_c, x_c, B_c, C_c, dt_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nchunks * chunk, H, P)
    y = y[:, :S]
    y = y + xh[:, :S] * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt)
    return out, {"conv": conv_state, "ssm": hN}


def mamba2_decode(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,                    # (B,1,d)
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    Bsz = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc, conv_state = causal_conv1d(
        xbc, p["conv_w"], p["conv_b"], state["conv"]
    )
    xbc = jax.nn.silu(xbc)
    xin, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
    delta = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                     # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(delta * A)                                 # (B,H)
    xh = xin[:, 0].reshape(Bsz, H, P).astype(jnp.float32)
    h = state["ssm"].astype(jnp.float32)
    h = h * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", delta, Bc[:, 0].astype(jnp.float32), xh
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0].astype(jnp.float32))
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt), {"conv": conv_state, "ssm": h}


def mamba2_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, tuple]:
    return {
        "conv": (batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
        "ssm": (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
    }

"""TransformerLM — the unified decoder-only model.

One class covers the dense / GQA / SWA / MoE / SSM / hybrid families of the
assignment (everything except Whisper's encoder-decoder, see ``whisper.py``):

* per-layer parameters are stacked and the layer stack is a single
  ``lax.scan`` (compile time is O(1) in depth — an 81-layer zamba2 compiles
  one block),
* zamba2's *shared* attention block is closed over by the scan body: its
  weights appear once in the pytree but are applied every
  ``hybrid_attn_every``-th step, each application with its own KV-cache
  slice (weight sharing ≠ cache sharing),
* the loss head is a *chunked* cross-entropy: logits are never materialized
  for the full sequence (vocab 257k × seq 4k would be hundreds of GB),
* PaliGemma's vision frontend is a stub per the assignment:
  ``prefix_embed`` (precomputed patch embeddings) is concatenated in front
  of the token embeddings with a bidirectional prefix-LM mask.

Modes
-----
``forward``      full-sequence logits/hidden (training, scoring)
``prefill``      full-sequence + KV/SSM cache write (serving prompt phase)
``decode_step``  one token per replica step with carried cache (serving)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.base import (
    ParamSpec,
    abstract_params,
    init_params,
    stack_blueprint,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_spec,
    embed_tokens,
    logits_from_hidden,
    mlp_apply,
    mlp_blueprint,
    rms_norm,
    rmsnorm_spec,
    unembed_spec,
)


class TransformerLM:
    """Decoder-only LM over a ModelConfig."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        impl: str = "blockwise",       # attention impl: blockwise|naive|pallas
        q_block: int = 512,
        kv_block: int = 1024,
        ssm_chunk: int = 256,
        remat: bool = False,           # checkpoint each scanned block
    ) -> None:
        self.cfg = cfg
        self.impl = impl
        self.q_block = q_block
        self.kv_block = kv_block
        self.ssm_chunk = ssm_chunk
        self.remat = remat

    # ==================================================================
    # Blueprint
    # ==================================================================
    def _layer_blueprint(self) -> Dict[str, Any]:
        cfg = self.cfg
        bp: Dict[str, Any] = {"ln1": rmsnorm_spec(cfg.d_model)}
        if cfg.family == "ssm":
            bp["mixer"] = ssm_mod.mamba1_blueprint(cfg)
            return bp
        bp["attn"] = attn.attention_blueprint(cfg)
        if not cfg.parallel_block:
            bp["ln2"] = rmsnorm_spec(cfg.d_model)
        if cfg.is_moe:
            bp["moe"] = moe_mod.moe_blueprint(cfg)
        else:
            bp["mlp"] = mlp_blueprint(cfg)
        return bp

    def _hybrid_blueprints(self) -> Dict[str, Any]:
        """zamba2: stacked mamba2 layers + ONE shared attention block."""
        cfg = self.cfg
        m_bp = {
            "ln1": rmsnorm_spec(cfg.d_model),
            "mixer": ssm_mod.mamba2_blueprint(cfg),
        }
        shared = {
            "ln1": rmsnorm_spec(cfg.d_model),
            "attn": attn.attention_blueprint(cfg),
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_blueprint(cfg),
        }
        n_pre = cfg.hybrid_prelude
        per_blk = cfg.hybrid_attn_every - 1
        return {
            "prelude": stack_blueprint(m_bp, n_pre) if n_pre else {},
            "blocks": stack_blueprint(
                stack_blueprint(m_bp, per_blk), cfg.hybrid_blocks
            ),
            "shared_attn": shared,
        }

    def blueprint(self) -> Dict[str, Any]:
        cfg = self.cfg
        bp: Dict[str, Any] = {"embed": embed_spec(cfg)}
        if not cfg.tie_embeddings:
            bp["unembed"] = unembed_spec(cfg)
        bp["final_norm"] = rmsnorm_spec(cfg.d_model)
        if cfg.family == "hybrid":
            bp["decoder"] = self._hybrid_blueprints()
        else:
            bp["decoder"] = stack_blueprint(
                self._layer_blueprint(), cfg.num_layers
            )
        return bp

    def init(self, key: jax.Array) -> Any:
        return init_params(self.blueprint(), key)

    def abstract(self, dtype=jnp.bfloat16) -> Any:
        return abstract_params(self.blueprint(), dtype)

    # ==================================================================
    # Cache
    # ==================================================================
    def _cache_template(
        self, batch: int, max_len: int, dtype, abstract: bool
    ) -> Dict[str, Any]:
        cfg = self.cfg
        mk = (
            (lambda s, d: jax.ShapeDtypeStruct(s, d))
            if abstract
            else (lambda s, d: jnp.zeros(s, d))
        )
        cache: Dict[str, Any] = {
            "len": mk((), jnp.int32),
        }
        if cfg.family == "ssm":
            shapes = ssm_mod.mamba1_state_shapes(cfg, batch)
            L = cfg.num_layers
            cache["ssm_state"] = {
                k: mk((L,) + s, jnp.float32) for k, s in shapes.items()
            }
        elif cfg.family == "hybrid":
            shapes = ssm_mod.mamba2_state_shapes(cfg, batch)
            n_pre, n_blk = cfg.hybrid_prelude, cfg.hybrid_blocks
            per_blk = cfg.hybrid_attn_every - 1
            if n_pre:
                cache["prelude_state"] = {
                    k: mk((n_pre,) + s, jnp.float32)
                    for k, s in shapes.items()
                }
            cache["block_state"] = {
                k: mk((n_blk, per_blk) + s, jnp.float32)
                for k, s in shapes.items()
            }
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            slots = max_len
            cache["attn_kv"] = {
                "k": mk((n_blk, batch, slots, kv, hd), dtype),
                "v": mk((n_blk, batch, slots, kv, hd), dtype),
            }
        else:
            slots = (
                min(max_len, cfg.sliding_window)
                if cfg.sliding_window is not None
                else max_len
            )
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            L = cfg.num_layers
            cache["kv"] = {
                "k": mk((L, batch, slots, kv, hd), dtype),
                "v": mk((L, batch, slots, kv, hd), dtype),
            }
        return cache

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return self._cache_template(batch, max_len, dtype, abstract=False)

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return self._cache_template(batch, max_len, dtype, abstract=True)

    # ==================================================================
    # Blocks
    # ==================================================================
    def _attn_block(
        self, lp, x, *, positions, mode, layer_kv, cache_len, prefix_len
    ):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, new_kv = attn.attention_apply(
            lp["attn"], cfg, h,
            positions=positions, mode=mode, layer_cache=layer_kv,
            cache_len=cache_len, prefix_len=prefix_len, impl=self.impl,
            q_block=self.q_block, kv_block=self.kv_block,
        )
        if cfg.parallel_block:
            # command-r: attn and FFN read the SAME normed input, summed
            if cfg.is_moe:
                f, aux_l = moe_mod.moe_apply(
                    lp["moe"], cfg, h, return_aux=True
                )
                aux = aux + aux_l
            else:
                f = mlp_apply(lp["mlp"], cfg, h)
            x = x + a + f
        else:
            x = x + a
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                f, aux_l = moe_mod.moe_apply(
                    lp["moe"], cfg, h2, return_aux=True
                )
                aux = aux + (aux_l if aux_l is not None else 0.0)
                x = x + f
            else:
                x = x + mlp_apply(lp["mlp"], cfg, h2)
        return x, new_kv, aux

    def _mamba_block(self, lp, x, *, mode, state, version):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if version == 1:
            fn_full, fn_dec = ssm_mod.mamba1_full, ssm_mod.mamba1_decode
        else:
            fn_full, fn_dec = ssm_mod.mamba2_full, ssm_mod.mamba2_decode
        if mode == "decode":
            y, new_state = fn_dec(lp["mixer"], cfg, h, state)
        else:
            kwargs = {"chunk": self.ssm_chunk, "state": state}
            if version == 1:
                kwargs["impl"] = "pallas" if self.impl == "pallas" else "jnp"
            y, new_state = fn_full(lp["mixer"], cfg, h, **kwargs)
        return x + y, new_state

    # ==================================================================
    # Stacks
    # ==================================================================
    def _run_uniform_stack(
        self, params, x, *, positions, mode, cache, prefix_len
    ):
        """Dense / MoE / SSM: one scanned stack."""
        cfg = self.cfg
        cache_len = None if cache is None else cache["len"]

        if cfg.family == "ssm":
            def body(carry, per_layer):
                xc = carry
                lp, st = per_layer
                y, new_st = self._mamba_block(
                    lp, xc, mode=mode, state=st, version=1
                )
                return y, new_st

            if self.remat:
                body = jax.checkpoint(body)
            states = None
            if cache is not None:
                states = cache["ssm_state"]
            else:
                states = {
                    k: jnp.zeros((cfg.num_layers,) + s, jnp.float32)
                    for k, s in ssm_mod.mamba1_state_shapes(
                        cfg, x.shape[0]
                    ).items()
                }
            x, new_states = jax.lax.scan(
                body, x, (params["decoder"], states)
            )
            new_cache = None
            if cache is not None:
                new_cache = dict(cache)
                new_cache["ssm_state"] = new_states
            return x, new_cache, jnp.zeros((), jnp.float32)

        # attention families
        def body(carry, per_layer):
            xc, aux_acc = carry
            lp, kv_slice = per_layer
            y, new_kv, aux = self._attn_block(
                lp, xc, positions=positions, mode=mode,
                layer_kv=kv_slice, cache_len=cache_len,
                prefix_len=prefix_len,
            )
            return (y, aux_acc + aux), new_kv

        if self.remat:
            body = jax.checkpoint(body)
        kv = cache["kv"] if cache is not None else None
        if kv is None:
            # no-cache forward still scans a dummy so the body is uniform
            (x, aux), _ = jax.lax.scan(
                lambda c, lp: (
                    body(c, (lp, None))[0],
                    0.0,
                ),
                (x, jnp.zeros((), jnp.float32)),
                params["decoder"],
            )
            return x, None, aux
        (x, aux), new_kv = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["decoder"], kv)
        )
        new_cache = dict(cache)
        new_cache["kv"] = new_kv
        return x, new_cache, aux

    def _run_hybrid_stack(
        self, params, x, *, positions, mode, cache, prefix_len
    ):
        """zamba2: prelude mamba2 layers, then (shared-attn + mamba2 group)
        super-blocks."""
        cfg = self.cfg
        dec = params["decoder"]
        cache_len = None if cache is None else cache["len"]
        shared = dec["shared_attn"]

        def mamba_body(carry, per_layer):
            xc = carry
            lp, st = per_layer
            y, new_st = self._mamba_block(
                lp, xc, mode=mode, state=st, version=2
            )
            return y, new_st

        if self.remat:
            mamba_body = jax.checkpoint(mamba_body)

        def zero_states(n_shape):
            return {
                k: jnp.zeros(n_shape + s, jnp.float32)
                for k, s in ssm_mod.mamba2_state_shapes(
                    cfg, x.shape[0]
                ).items()
            }

        # ---- prelude -----------------------------------------------------
        new_prelude_state = None
        if cfg.hybrid_prelude:
            st = (
                cache["prelude_state"]
                if cache is not None
                else zero_states((cfg.hybrid_prelude,))
            )
            x, new_prelude_state = jax.lax.scan(
                mamba_body, x, (dec["prelude"], st)
            )

        # ---- super-blocks ---------------------------------------------------
        blk_state = (
            cache["block_state"]
            if cache is not None
            else zero_states((cfg.hybrid_blocks, cfg.hybrid_attn_every - 1))
        )

        if cache is not None:
            def block_body(carry, per_block):
                xc = carry
                blk_params, st, blk_kv = per_block
                # shared attention (weights shared; per-block cache slice)
                y, new_kv, _ = self._attn_block(
                    shared, xc, positions=positions, mode=mode,
                    layer_kv=blk_kv, cache_len=cache_len,
                    prefix_len=prefix_len,
                )
                y, new_state = jax.lax.scan(mamba_body, y, (blk_params, st))
                return y, (new_state, new_kv)

            x, (new_blk_state, new_blk_kv) = jax.lax.scan(
                block_body, x, (dec["blocks"], blk_state, cache["attn_kv"])
            )
            new_cache = dict(cache)
            if new_prelude_state is not None:
                new_cache["prelude_state"] = new_prelude_state
            new_cache["block_state"] = new_blk_state
            new_cache["attn_kv"] = new_blk_kv
            return x, new_cache, jnp.zeros((), jnp.float32)

        def block_body_nc(carry, per_block):
            xc = carry
            blk_params, st = per_block
            y, _, _ = self._attn_block(
                shared, xc, positions=positions, mode=mode,
                layer_kv=None, cache_len=cache_len, prefix_len=prefix_len,
            )
            y, new_state = jax.lax.scan(mamba_body, y, (blk_params, st))
            return y, new_state

        if self.remat:
            block_body_nc = jax.checkpoint(block_body_nc)
        x, _ = jax.lax.scan(block_body_nc, x, (dec["blocks"], blk_state))
        return x, None, jnp.zeros((), jnp.float32)

    def _run_stack(self, params, x, *, positions, mode, cache, prefix_len):
        if self.cfg.family == "hybrid":
            return self._run_hybrid_stack(
                params, x, positions=positions, mode=mode, cache=cache,
                prefix_len=prefix_len,
            )
        return self._run_uniform_stack(
            params, x, positions=positions, mode=mode, cache=cache,
            prefix_len=prefix_len,
        )

    # ==================================================================
    # Public entry points
    # ==================================================================
    def _embed_inputs(
        self, params, tokens, prefix_embed, dtype
    ) -> Tuple[jax.Array, int]:
        x = embed_tokens(params["embed"], tokens, dtype)
        prefix_len = 0
        if prefix_embed is not None:
            x = jnp.concatenate([prefix_embed.astype(dtype), x], axis=1)
            prefix_len = prefix_embed.shape[1]
        return x, prefix_len

    def forward(
        self,
        params,
        tokens: jax.Array,               # (B, S)
        *,
        prefix_embed: Optional[jax.Array] = None,
        dtype=jnp.bfloat16,
    ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence hidden states; returns (hidden (B,S',d), aux)."""
        x, prefix_len = self._embed_inputs(params, tokens, prefix_embed,
                                           dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, _, aux = self._run_stack(
            params, x, positions=positions, mode="full", cache=None,
            prefix_len=prefix_len if self.cfg.prefix_lm else 0,
        )
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return x, aux

    def logits(self, params, hidden: jax.Array) -> jax.Array:
        return logits_from_hidden(
            hidden, self.cfg,
            embedding=params.get("embed"),
            unembed=params.get("unembed"),
        )

    def loss(
        self,
        params,
        tokens: jax.Array,               # (B, S)
        labels: jax.Array,               # (B, S) — next-token targets
        *,
        prefix_embed: Optional[jax.Array] = None,
        dtype=jnp.bfloat16,
        ce_chunk: int = 512,
    ) -> jax.Array:
        """Mean next-token CE + MoE aux loss; logits chunked over sequence."""
        hidden, aux = self.forward(
            params, tokens, prefix_embed=prefix_embed, dtype=dtype
        )
        if prefix_embed is not None:
            hidden = hidden[:, prefix_embed.shape[1]:]
        ce = chunked_ce(
            hidden, labels, self.cfg,
            embedding=params.get("embed"),
            unembed=params.get("unembed"),
            chunk=ce_chunk,
        )
        return ce + aux

    def prefill(
        self,
        params,
        tokens: jax.Array,               # (B, S)
        cache: Dict[str, Any],
        *,
        prefix_embed: Optional[jax.Array] = None,
        dtype=jnp.bfloat16,
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Process the prompt, fill the cache, return last-position logits."""
        x, prefix_len = self._embed_inputs(params, tokens, prefix_embed,
                                           dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, new_cache, _ = self._run_stack(
            params, x, positions=positions, mode="full", cache=cache,
            prefix_len=prefix_len if self.cfg.prefix_lm else 0,
        )
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = self.logits(params, x)
        new_cache["len"] = jnp.asarray(positions.shape[0], jnp.int32)
        return logits, new_cache

    def decode_step(
        self,
        params,
        tokens: jax.Array,               # (B, 1)
        cache: Dict[str, Any],
        *,
        dtype=jnp.bfloat16,
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decode step: next-token logits + updated cache."""
        x = embed_tokens(params["embed"], tokens, dtype)
        positions = cache["len"][None].astype(jnp.int32)
        x, new_cache, _ = self._run_stack(
            params, x, positions=positions, mode="decode", cache=cache,
            prefix_len=0,
        )
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = self.logits(params, x)
        new_cache["len"] = cache["len"] + 1
        return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked cross-entropy (vocab-sharding-friendly)
# ---------------------------------------------------------------------------


def chunked_ce(
    hidden: jax.Array,        # (B, S, d)
    labels: jax.Array,        # (B, S)
    cfg: ModelConfig,
    *,
    embedding: Optional[jax.Array],
    unembed: Optional[jax.Array],
    chunk: int = 512,
) -> jax.Array:
    """Next-token CE without materializing (B,S,V): scan over S chunks.

    The label logit is extracted with a one-hot einsum (not a gather) so a
    vocab-sharded unembedding keeps the computation local + one all-reduce.
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = (S + pad) // chunk
    hc = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    valid_count = jnp.asarray(B * S, jnp.float32)

    def step(acc, inp):
        h, lab = inp
        logits = logits_from_hidden(
            h, cfg, embedding=embedding, unembed=unembed
        ).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)           # (B, chunk)
        onehot = jax.nn.one_hot(lab, cfg.padded_vocab, dtype=logits.dtype)
        lab_logit = jnp.einsum("bcv,bcv->bc", logits, onehot)
        ce = lse - lab_logit
        return acc + ce.sum(), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
    return total / valid_count

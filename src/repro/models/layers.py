"""Shared layers: norms, RoPE, activations, MLP blocks."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec, dense_spec
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int, axis: str = "embed") -> ParamSpec:
    return ParamSpec((dim,), (axis,), "ones")


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm_spec(dim: int, axis: str = "embed") -> dict:
    return {
        "scale": ParamSpec((dim,), (axis,), "ones"),
        "bias": ParamSpec((dim,), (axis,), "zeros"),
    }


def layer_norm(x: jax.Array, p: dict, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(
    x: jax.Array,            # (B, S, H, D)
    positions: jax.Array,    # (S,) or (B, S)
    theta: float,
) -> jax.Array:
    """Rotary position embedding on the trailing head_dim."""
    assert x.ndim == 4, f"apply_rope expects (B,S,H,D), got {x.shape}"
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (S,half)/(B,S,half)
    if ang.ndim == 2:
        ang = ang[None]                        # (1, S, half)
    cos = jnp.cos(ang)[:, :, None, :]           # (B|1, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [
            x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
            x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations & MLP
# ---------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


def mlp_blueprint(cfg: ModelConfig, d_ff: Optional[int] = None,
                  hidden_axis: str = "mlp") -> dict:
    """SwiGLU (silu) or plain 2-matrix MLP (gelu)."""
    d, f = cfg.d_model, d_ff if d_ff is not None else cfg.d_ff
    bp = {
        "wi": dense_spec(d, f, "embed", hidden_axis),
        "wo": dense_spec(f, d, hidden_axis, "embed"),
    }
    if cfg.mlp_gated:
        bp["wg"] = dense_spec(d, f, "embed", hidden_axis)
    return bp


def mlp_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = activation(cfg.act)
    h = x @ p["wi"].astype(x.dtype)
    if "wg" in p:                       # gated (SwiGLU / GeGLU)
        h = act(x @ p["wg"].astype(x.dtype)) * h
    else:
        h = act(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(cfg: ModelConfig) -> ParamSpec:
    # normal(0.02): with tied unembedding, unit-normal embeddings would put
    # init logits at std ~ sqrt(d) (CE in the hundreds); 0.02 gives the
    # standard ln(V) init loss.
    return ParamSpec(
        (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed",
        scale=0.02,
    )


def unembed_spec(cfg: ModelConfig) -> ParamSpec:
    return ParamSpec(
        (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), "normal"
    )


def embed_tokens(embedding: jax.Array, tokens: jax.Array,
                 dtype: Any) -> jax.Array:
    return embedding.astype(dtype)[tokens]


def logits_from_hidden(
    x: jax.Array,
    cfg: ModelConfig,
    *,
    embedding: Optional[jax.Array] = None,
    unembed: Optional[jax.Array] = None,
) -> jax.Array:
    """Project hidden states to (padded) vocab logits; padding masked."""
    if cfg.tie_embeddings:
        assert embedding is not None
        logits = x @ embedding.astype(x.dtype).T
    else:
        assert unembed is not None
        logits = x @ unembed.astype(x.dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate(
            [
                jnp.zeros((cfg.vocab_size,), logits.dtype),
                jnp.full((pad,), jnp.finfo(logits.dtype).min, logits.dtype),
            ]
        )
        logits = logits + mask
    return logits

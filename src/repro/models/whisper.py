"""EncDecLM — Whisper-style encoder-decoder backbone.

Per the assignment the conv/audio frontend is a STUB: ``input_specs()``
feeds precomputed frame embeddings ``(B, S_enc, d_model)``.  The backbone is
faithful otherwise: LayerNorm (not RMSNorm), GELU MLPs, absolute sinusoidal
positions (no RoPE), bidirectional encoder self-attention, causal decoder
self-attention with a KV cache, and per-layer cross-attention whose K/V are
computed once at prefill and cached read-only.

Deviation (documented in DESIGN.md): Whisper biases K projections are zero
in the original; we carry full qkv biases — a no-op at init and irrelevant
to systems behaviour.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.base import (
    ParamSpec,
    abstract_params,
    init_params,
    stack_blueprint,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_spec,
    embed_tokens,
    layer_norm,
    layernorm_spec,
    logits_from_hidden,
    mlp_apply,
    mlp_blueprint,
)
from repro.models.lm import chunked_ce


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_blueprint(cfg: ModelConfig) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


class EncDecLM:
    """Whisper-medium-style encoder-decoder."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        impl: str = "blockwise",
        q_block: int = 512,
        kv_block: int = 1024,
        remat: bool = False,
    ) -> None:
        assert cfg.is_encdec
        self.cfg = cfg
        self.impl = impl
        self.q_block = q_block
        self.kv_block = kv_block
        self.remat = remat

    # ------------------------------------------------------------------
    def _enc_layer(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": layernorm_spec(cfg.d_model),
            "attn": attn.attention_blueprint(cfg),
            "ln2": layernorm_spec(cfg.d_model),
            "mlp": mlp_blueprint(cfg),
        }

    def _dec_layer(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": layernorm_spec(cfg.d_model),
            "self_attn": attn.attention_blueprint(cfg),
            "ln_x": layernorm_spec(cfg.d_model),
            "cross_attn": _xattn_blueprint(cfg),
            "ln2": layernorm_spec(cfg.d_model),
            "mlp": mlp_blueprint(cfg),
        }

    def blueprint(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": embed_spec(cfg),
            "encoder": stack_blueprint(self._enc_layer(),
                                       cfg.encoder_layers),
            "enc_norm": layernorm_spec(cfg.d_model),
            "decoder": stack_blueprint(self._dec_layer(), cfg.num_layers),
            "dec_norm": layernorm_spec(cfg.d_model),
        }

    def init(self, key: jax.Array) -> Any:
        return init_params(self.blueprint(), key)

    def abstract(self, dtype=jnp.bfloat16) -> Any:
        return abstract_params(self.blueprint(), dtype)

    # ------------------------------------------------------------------
    # Encoder
    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, S_enc, d) precomputed frontend embeddings (stub)."""
        cfg = self.cfg
        dt = frames.dtype
        x = frames + sinusoidal_positions(
            frames.shape[1], cfg.d_model
        ).astype(dt)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(xc, lp):
            h = layer_norm(xc, lp["ln1"], cfg.norm_eps)
            a, _ = attn.attention_apply(
                lp["attn"], cfg, h, positions=positions, mode="full",
                causal=False, impl=self.impl, q_block=self.q_block,
                kv_block=self.kv_block,
            )
            xc = xc + a
            h2 = layer_norm(xc, lp["ln2"], cfg.norm_eps)
            return xc + mlp_apply(lp["mlp"], cfg, h2), None

        if self.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return layer_norm(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # Cross attention
    # ------------------------------------------------------------------
    def _cross_kv(self, lp, enc_out: jax.Array):
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["wv"].astype(dt))
        return k, v

    def _cross_attend(self, lp, cfg, x, ck, cv):
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"].astype(dt))
        S_enc = ck.shape[1]
        valid = jnp.ones((x.shape[0], S_enc), bool)
        if x.shape[1] == 1:
            out = attn.decode_attention(q, ck, cv, kv_valid=valid)
        else:
            pos_q = jnp.arange(x.shape[1], dtype=jnp.int32)
            pos_k = jnp.arange(S_enc, dtype=jnp.int32)
            out = attn.blockwise_attention(
                q, ck, cv, q_pos=pos_q, kv_pos=pos_k, causal=False,
                q_block=self.q_block, kv_block=self.kv_block,
            )
        return jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(dt))

    # ------------------------------------------------------------------
    # Decoder
    # ------------------------------------------------------------------
    def _dec_block(self, lp, x, *, positions, mode, self_kv, cross_k,
                   cross_v, cache_len):
        cfg = self.cfg
        h = layer_norm(x, lp["ln1"], cfg.norm_eps)
        a, new_kv = attn.attention_apply(
            lp["self_attn"], cfg, h, positions=positions, mode=mode,
            layer_cache=self_kv, cache_len=cache_len, impl=self.impl,
            q_block=self.q_block, kv_block=self.kv_block,
        )
        x = x + a
        hx = layer_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + self._cross_attend(lp["cross_attn"], cfg, hx, cross_k,
                                   cross_v)
        h2 = layer_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], cfg, h2)
        return x, new_kv

    def _decoder_stack(self, params, x, *, positions, mode, cache, enc_out):
        cfg = self.cfg
        cache_len = None if cache is None else cache["len"]

        if cache is None:
            # training path: cross-KV recomputed per layer inside the scan
            def body(xc, lp):
                ck, cv = self._cross_kv(lp["cross_attn"], enc_out)
                y, _ = self._dec_block(
                    lp, xc, positions=positions, mode=mode, self_kv=None,
                    cross_k=ck, cross_v=cv, cache_len=None,
                )
                return y, None

            if self.remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["decoder"])
            return x, None

        def body(xc, per_layer):
            lp, kv_slice, ck, cv = per_layer
            y, new_kv = self._dec_block(
                lp, xc, positions=positions, mode=mode, self_kv=kv_slice,
                cross_k=ck, cross_v=cv, cache_len=cache_len,
            )
            return y, new_kv

        x, new_kv = jax.lax.scan(
            body,
            x,
            (params["decoder"], cache["kv"], cache["cross_k"],
             cache["cross_v"]),
        )
        new_cache = dict(cache)
        new_cache["kv"] = new_kv
        return x, new_cache

    # ------------------------------------------------------------------
    # Cache
    # ------------------------------------------------------------------
    def _cache_template(self, batch, max_len, enc_len, dtype, abstract):
        cfg = self.cfg
        mk = (
            (lambda s, d: jax.ShapeDtypeStruct(s, d))
            if abstract
            else (lambda s, d: jnp.zeros(s, d))
        )
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        return {
            "len": mk((), jnp.int32),
            "kv": {
                "k": mk((L, batch, max_len, kv, hd), dtype),
                "v": mk((L, batch, max_len, kv, hd), dtype),
            },
            "cross_k": mk((L, batch, enc_len, kv, hd), dtype),
            "cross_v": mk((L, batch, enc_len, kv, hd), dtype),
        }

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16,
                   enc_len: Optional[int] = None):
        return self._cache_template(
            batch, max_len, enc_len or self.cfg.frontend_seq, dtype, False
        )

    def abstract_cache(self, batch, max_len, dtype=jnp.bfloat16,
                       enc_len: Optional[int] = None):
        return self._cache_template(
            batch, max_len, enc_len or self.cfg.frontend_seq, dtype, True
        )

    # ------------------------------------------------------------------
    # Entry points
    # ------------------------------------------------------------------
    def _embed_dec(self, params, tokens, dtype, offset):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, dtype)
        pos = sinusoidal_positions(
            offset + tokens.shape[1], cfg.d_model
        )[offset:].astype(dtype)
        return x + pos[None]

    def loss(self, params, frames, tokens, labels, *, dtype=jnp.bfloat16,
             ce_chunk: int = 512) -> jax.Array:
        """Teacher-forced seq2seq CE."""
        cfg = self.cfg
        enc_out = self.encode(params, frames.astype(dtype))
        x = self._embed_dec(params, tokens, dtype, 0)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, _ = self._decoder_stack(
            params, x, positions=positions, mode="full", cache=None,
            enc_out=enc_out,
        )
        x = layer_norm(x, params["dec_norm"], cfg.norm_eps)
        return chunked_ce(
            x, labels, cfg, embedding=params["embed"], unembed=None,
            chunk=ce_chunk,
        )

    def prefill(self, params, frames, tokens, cache, *,
                dtype=jnp.bfloat16):
        """Encode audio, fill cross-KV + self-KV, return last logits."""
        cfg = self.cfg
        enc_out = self.encode(params, frames.astype(dtype))

        # compute per-layer cross KV once (scan over layers)
        def xkv(_, lp):
            k, v = self._cross_kv(lp["cross_attn"], enc_out)
            return None, (k, v)

        _, (cross_k, cross_v) = jax.lax.scan(xkv, None, params["decoder"])
        cache = dict(cache)
        cache["cross_k"] = cross_k.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cross_v.astype(cache["cross_v"].dtype)

        x = self._embed_dec(params, tokens, dtype, 0)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, new_cache = self._decoder_stack(
            params, x, positions=positions, mode="full", cache=cache,
            enc_out=enc_out,
        )
        x = layer_norm(x[:, -1:], params["dec_norm"], cfg.norm_eps)
        logits = logits_from_hidden(x, cfg, embedding=params["embed"])
        new_cache["len"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return logits, new_cache

    def decode_step(self, params, tokens, cache, *, dtype=jnp.bfloat16):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, dtype)
        # absolute sinusoidal position for the current slot (closed form —
        # no table lookup needed at a traced position)
        posf = cache["len"].astype(jnp.float32)
        d = cfg.d_model
        dim = jnp.arange(d // 2, dtype=jnp.float32)
        ang = posf / jnp.power(10_000.0, 2 * dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pe.astype(dtype)
        positions = cache["len"][None].astype(jnp.int32)
        x, new_cache = self._decoder_stack(
            params, x, positions=positions, mode="decode", cache=cache,
            enc_out=None,
        )
        x = layer_norm(x, params["dec_norm"], cfg.norm_eps)
        logits = logits_from_hidden(x, cfg, embedding=params["embed"])
        new_cache["len"] = cache["len"] + 1
        return logits, new_cache

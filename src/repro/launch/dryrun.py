import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and record memory/cost/collective analysis.

MUST be run as a module/script (the two lines above run before any jax
import — jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
consumed by ``benchmarks/roofline.py`` and EXPERIMENTS.md §Dry-run.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.launch.analysis import Roofline, model_flops_for
from repro.launch.hlo_count import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.steps import build_step

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


def _mesh_desc(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names) + ":" + \
        ",".join(mesh.axis_names)


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    verbose: bool = True,
    step_kwargs: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the analysis record."""
    cfg = get_config(arch)
    status = dict(cells_for(cfg)).get(shape_name, "run")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mesh_desc": _mesh_desc(mesh),
        "chips": mesh_chip_count(mesh),
        "status": status,
    }
    if status != "run":
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: {status}")
        return record

    t0 = time.perf_counter()
    built = build_step(arch, shape_name, mesh, **(step_kwargs or {}))
    with mesh:
        lowered = built.jitted().lower(*built.abstract_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # loop-aware per-device counts (XLA:CPU cost_analysis counts while
    # bodies once — verified; analyze_hlo scales by known trip counts)
    counts = analyze_hlo(hlo)
    flops = counts.flops
    bytes_accessed = counts.bytes
    link_bytes = counts.coll_bytes

    shape = SHAPES[shape_name]
    roof = Roofline(
        arch=arch,
        shape=shape_name,
        mesh_desc=record["mesh_desc"],
        chips=record["chips"],
        hlo_flops=flops,
        hlo_bytes=bytes_accessed,
        collective_link_bytes=link_bytes,
        model_flops=model_flops_for(cfg, shape),
    )

    record.update(
        {
            "desc": built.static_desc,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            },
            "cost_analysis_raw": {   # XLA's own (loop bodies ONCE)
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            "hlo_counts": {          # loop-scaled per-device
                "flops": flops,
                "bytes_traffic_model": bytes_accessed,
                "collective_link_bytes": link_bytes,
                "collective_raw_bytes": counts.coll_raw,
                "collective_counts": counts.coll_counts,
            },
            "roofline": roof.row(),
        }
    )
    # v5e: 16 GiB HBM per chip.  memory_analysis is per-device (post-SPMD).
    ma = record["memory_analysis"]
    hbm_need = ma.get("argument_size_in_bytes", 0) + ma.get(
        "temp_size_in_bytes", 0
    )
    record["hbm_bytes_per_chip"] = hbm_need
    record["fits_hbm_16gib"] = bool(hbm_need <= 16 * 2**30)
    if verbose:
        ma = record["memory_analysis"]
        args_gib = ma.get("argument_size_in_bytes", 0) / 2**30
        tmp_gib = ma.get("temp_size_in_bytes", 0) / 2**30
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
            f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
            f"args={args_gib:.2f}GiB temp={tmp_gib:.2f}GiB "
            f"flops={flops:.3e} coll={link_bytes:.3e}B "
            f"bottleneck={roof.bottleneck}"
        )
        # the two artifacts the deliverable asks to print:
        print(f"  memory_analysis: {ma}")
        print(
            "  cost_analysis: flops=%.4g bytes=%.4g" % (flops, bytes_accessed)
        )
    return record


def save_record(record: Dict[str, Any]) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(
        ARTIFACT_DIR,
        f"{record['arch']}__{record['shape']}__{record['mesh']}.json",
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            out = os.path.join(
                ARTIFACT_DIR, f"{arch}__{shape_name}__{mesh_name}.json"
            )
            if args.skip_existing and os.path.exists(out):
                print(f"[dryrun] skip existing {out}")
                continue
            try:
                record = run_cell(arch, shape_name, multi_pod=multi)
                save_record(record)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_name, repr(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        return 1
    print(f"[dryrun] all {len(cells) * len(meshes)} cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

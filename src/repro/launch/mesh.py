"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state): single pod = (16, 16) chips over ("data", "model");
multi-pod = (2, 16, 16) over ("pod", "data", "model").  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so both meshes can be built on this CPU-only container.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic restarts, tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1x1 mesh on the local device (smoke tests, examples)."""
    n = len(jax.devices())
    if n >= 2:
        return jax.make_mesh((1, n), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def data_axis_size(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n

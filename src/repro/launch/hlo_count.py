"""Loop-aware HLO analysis: FLOPs / bytes / collective bytes per device.

XLA:CPU's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which
under-reports a scanned transformer by ~num_layers × microbatches (verified
empirically — see EXPERIMENTS.md §Dry-run).  This module re-derives the
counts from the optimized HLO text with a call-graph walk:

* computations are parsed into (ops, callsites);
* ``while`` ops multiply their body+condition by the
  ``backend_config.known_trip_count`` (1 if unknown);
* ``fusion`` / ``call`` / ``conditional`` ops add their callee at each site
  (conditional: max over branches);
* FLOPs: ``dot`` ops contribute 2 × result_numel × K (K = product of the
  lhs contracting dims, looked up from the per-computation symbol table);
* collective bytes: tensor bytes through all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, with the ring-traffic
  factor (AR 2×, others 1×);
* HBM bytes: a TPU-oriented traffic model.  XLA:CPU leaves elementwise ops
  unfused that XLA:TPU would fuse into neighbouring matmuls, so counting
  every op would grossly over-state HBM traffic.  We count only the ops
  that necessarily touch HBM on TPU:

      dot / convolution        lhs + rhs + result bytes (weights re-read
                               per use — what makes decode memory-bound)
      fusion                   result×2 (one read+write pass per region)
      copy / *slice / gather / scatter / reduce / transpose / select-and-*
                               result×2
      everything else          free (assumed fused)

  This is an estimate, but it is loop-scaled and self-consistent, which is
  what the §Perf iteration needs.

All quantities describe the per-device (post-SPMD) module.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_OPERANDS = re.compile(r"\bdot\(\s*([^)]*)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_FREE_OPS = (
    "parameter", "constant", "get-tuple-element", "bitcast", "tuple",
    "after-all", "iota",
)


def _shape_info(shape_str: str) -> Tuple[int, int]:
    """(numel, bytes) summed over every shape token in the string."""
    numel_total, bytes_total = 0, 0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt, dims = m.groups()
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * b
    return numel_total, bytes_total


def _first_shape(s: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_TOKEN.search(s)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0       # ring-factored link bytes
    coll_raw: float = 0.0         # raw tensor bytes through collectives
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Counts", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_raw += other.coll_raw * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


@dataclasses.dataclass
class _Comp:
    name: str
    own: Counts
    # callsites: (callee_name, multiplier)
    calls: List[Tuple[str, float]]
    # conditionals: list of branch-name lists (take max across branches)
    cond_branches: List[List[str]]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_START.match(line)
        if m and "{" in line:
            current = m.group(2)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


def _parse_computation(name: str, lines: List[str]) -> _Comp:
    own = Counts()
    calls: List[Tuple[str, float]] = []
    cond_branches: List[List[str]] = []
    shapes: Dict[str, str] = {}

    # first pass: symbol table (op name -> result shape string)
    for ln in lines:
        m = _OP_LINE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)

    for ln in lines:
        m = _OP_LINE.match(ln)
        if not m:
            continue
        opname, rhs = m.groups()
        # opcode = first word after the result shape(s)
        opcode_m = re.search(
            r"(?:\([^=]*\)|\S+)\s+([\w\-]+)\(", rhs
        )
        opcode = opcode_m.group(1) if opcode_m else ""

        # ---- while ----------------------------------------------------
        if opcode == "while":
            trips = 1
            mt = _TRIP.search(ln)
            if mt:
                trips = int(mt.group(1))
            mb, mc = _BODY.search(ln), _COND.search(ln)
            if mb:
                calls.append((mb.group(1), float(trips)))
            if mc:
                calls.append((mc.group(1), float(trips + 1)))
            continue

        # ---- fusion / call ----------------------------------------------
        if opcode in ("fusion", "call", "async-start"):
            mc = _CALLS.search(ln)
            if mc:
                calls.append((mc.group(1), 1.0))
            # fall through: also count result bytes below

        if opcode == "conditional":
            mb = _BRANCHES.search(ln)
            if mb:
                branches = [
                    b.strip().lstrip("%")
                    for b in mb.group(1).split(",")
                    if b.strip()
                ]
                cond_branches.append(branches)

        # ---- collectives -------------------------------------------------
        matched_coll = None
        for ckind in _COLLECTIVES:
            if re.search(rf"\b{ckind}(?:-start)?\(", rhs):
                matched_coll = ckind
                break
        if matched_coll and f"{matched_coll}-done" not in rhs:
            # result shape(s) left of the opcode
            lhs_str = rhs.split(matched_coll)[0]
            _, nbytes = _shape_info(lhs_str)
            own.coll_raw += nbytes
            own.coll_bytes += _COLL_FACTOR[matched_coll] * nbytes
            own.coll_counts[matched_coll] = (
                own.coll_counts.get(matched_coll, 0) + 1
            )

        # ---- dot flops + dot bytes ----------------------------------------
        is_dot = bool(re.search(r"\bdot\(", rhs))
        if is_dot:
            res = _first_shape(rhs.split("dot(")[0])
            mops = _DOT_OPERANDS.search(rhs)
            mk = _LHS_CONTRACT.search(rhs)
            if res and mops and mk:
                operands = [
                    o.strip().lstrip("%")
                    for o in mops.group(1).split(",")
                ]
                def _op_bytes(name: str) -> float:
                    nm = name.split(" ")[-1].lstrip("%")
                    if nm in shapes:
                        return _shape_info(
                            shapes[nm].split("(")[0]
                        )[1]
                    return 0.0
                lhs_name = operands[0].split(" ")[-1].lstrip("%")
                lhs_shape = None
                if lhs_name in shapes:
                    lhs_shape = _first_shape(shapes[lhs_name])
                if lhs_shape is None:
                    lhs_shape = _first_shape(mops.group(1))
                if lhs_shape:
                    dims = lhs_shape[1]
                    K = 1
                    for idx in mk.group(1).split(","):
                        if idx:
                            K *= dims[int(idx)]
                    numel = 1
                    for d in res[1]:
                        numel *= d
                    own.flops += 2.0 * numel * K
                    # dot HBM traffic: both operands + the result
                    _, res_bytes = _shape_info(rhs.split("dot(")[0])
                    own.bytes += res_bytes + sum(
                        _op_bytes(o) for o in operands[:2]
                    )

        # ---- bytes traffic model (fusion-aware; see module docstring) ----
        _BYTE_OPS = (
            "fusion", "copy", "dynamic-slice", "dynamic-update-slice",
            "gather", "scatter", "reduce", "reduce-window", "transpose",
            "convolution", "sort", "cumsum",
        )
        if not is_dot and opcode in _BYTE_OPS:
            lhs_str = rhs.split(opcode)[0] if opcode in rhs else rhs
            _, nbytes = _shape_info(lhs_str)
            own.bytes += 2.0 * nbytes

    return _Comp(name=name, own=own, calls=calls,
                 cond_branches=cond_branches)


def analyze_hlo(hlo: str) -> Counts:
    comps_raw = _split_computations(hlo)
    comps = {
        name: _parse_computation(name, lines)
        for name, lines in comps_raw.items()
    }
    memo: Dict[str, Counts] = {}

    def total(name: str, stack=()) -> Counts:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Counts()
        c = comps[name]
        out = Counts()
        out.add(c.own)
        for callee, mult in c.calls:
            out.add(total(callee, stack + (name,)), mult)
        for branches in c.cond_branches:
            best = Counts()
            for b in branches:
                cand = total(b, stack + (name,))
                if cand.flops + cand.bytes > best.flops + best.bytes:
                    best = cand
            out.add(best)
        memo[name] = out
        return out

    entry = None
    for name in comps_raw:
        # ENTRY computation name: detect via original text
        pass
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    entry = m.group(1) if m else next(iter(comps_raw), None)
    if entry is None:
        return Counts()
    return total(entry)

"""Compiled-artifact analysis: cost model, collective bytes, roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes accessed; collective traffic
is NOT in cost_analysis, so we parse the optimized HLO text and sum the
shapes flowing through every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Roofline terms (per chip, TPU v5e):

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips × 819e9 B/s HBM)
    collective = link_bytes  / (chips × 50e9 B/s ICI)

``link_bytes`` applies a per-op traffic model (ring collectives):
all-reduce 2×(n−1)/n ≈ 2×, all-gather / reduce-scatter / all-to-all
(n−1)/n ≈ 1×, collective-permute 1× of the tensor size.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

# TPU v5e constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[1,2,3]' shape token; 0 if unparsable."""
    m = _SHAPE_RE.match(shape_str.strip().strip("(").strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]      # raw tensor bytes per op kind
    link_bytes: float                  # traffic-model bytes over links

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    by_kind: Dict[str, int] = {}
    link = 0.0
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.groups()
        # async pairs: count the -start, skip the matching -done
        if f"{kind}-done" in line:
            continue
        # tuple results "(f32[8], f32[8])": sum all member shapes
        nbytes = 0
        for tok in re.findall(r"\w+\[[\d,]*\]", shapes_str):
            nbytes += _shape_bytes(tok)
        if nbytes == 0:
            # fall back: first shape anywhere in the line
            m2 = re.search(r"\w+\[[\d,]*\]", line)
            if m2:
                nbytes = _shape_bytes(m2.group(0))
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + nbytes
        link += _COLLECTIVE_FACTOR[kind] * nbytes
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind,
                           link_bytes=link)


# ---------------------------------------------------------------------------
# While-loop (scan) trip-count multiplication
# ---------------------------------------------------------------------------
# cost_analysis on a lowered module counts a while body ONCE; the layer scan
# makes this badly wrong.  jax's compiled.cost_analysis() (XLA's HloCostAnalysis
# on the optimized module) DOES account for known trip counts on TPU, but the
# CPU backend leaves some loops opaque.  We therefore also scale parsed
# collective bytes by the trip count of the loop they appear in.

_TRIP_RE = re.compile(r"trip_count=(\d+)")


def scale_collectives_by_loops(hlo_text: str) -> float:
    """Best-effort multiplier map: returns total link bytes with while-loop
    bodies multiplied by their known trip counts."""
    # Split the module into computations; find while loops with known trip
    # counts and which computation they call.
    comp_bodies: Dict[str, str] = {}
    current = None
    lines = hlo_text.splitlines()
    for ln in lines:
        m = re.match(r"\s*%?([\w\.\-]+)\s*\([^)]*\)\s*->", ln)
        if ln.startswith("ENTRY") or (m and "{" in ln):
            name = ln.split("(")[0].strip().lstrip("%").split()[-1] \
                if not ln.startswith("ENTRY") else "ENTRY"
            current = name
            comp_bodies[current] = ""
        elif current is not None:
            comp_bodies[current] = comp_bodies[current] + ln + "\n"

    # map body computation -> trip count
    trips: Dict[str, int] = {}
    for ln in lines:
        if " while(" in ln and "body=" in ln:
            mb = re.search(r"body=%?([\w\.\-]+)", ln)
            mt = _TRIP_RE.search(ln)
            if mb:
                trips[mb.group(1)] = int(mt.group(1)) if mt else 1

    total = 0.0
    for name, body in comp_bodies.items():
        stats = parse_collectives(body)
        total += stats.link_bytes * trips.get(name, 1)
    return total


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    """Roofline terms.

    IMPORTANT semantics: ``compiled.cost_analysis()`` and the optimized HLO
    text both describe the *per-device* (post-SPMD-partitioning) module, so
    ``hlo_flops`` / ``hlo_bytes`` / ``collective_link_bytes`` are per-chip
    quantities and the terms below divide by single-chip peaks.
    ``model_flops`` is the *global* 6·N·D (train) / 2·N·D (inference)
    figure; the useful-fraction therefore divides by (hlo_flops × chips).
    """

    arch: str
    shape: str
    mesh_desc: str
    chips: int
    hlo_flops: float                    # per-device
    hlo_bytes: float                    # per-device
    collective_link_bytes: float        # per-device
    model_flops: float                  # GLOBAL 6·N·D / 6·N_active·D (MoE)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_link_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — catches remat/redundancy."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / (self.hlo_flops * self.chips)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time (global model
        FLOPs over all chips running for the roofline step time)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh_desc,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_link_bytes": self.collective_link_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_at_roofline": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (per step:
    D = tokens processed).  MoE uses active params; frontend stub tokens
    count as processed tokens."""
    from repro.models import build_model, param_count

    n_total = param_count(build_model(cfg).blueprint())
    n = n_total
    if cfg.is_moe:
        # active params: replace full expert count with top-k experts
        expert_params = (
            cfg.num_layers
            * cfg.num_experts
            * (3 if cfg.mlp_gated else 2)
            * cfg.d_model
            * cfg.expert_d_ff
        )
        active_expert = expert_params * (
            cfg.experts_per_token / cfg.num_experts
        )
        n = n_total - expert_params + active_expert
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * (
            shape.seq_len + (cfg.frontend_seq or 0)
        )
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch

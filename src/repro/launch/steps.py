"""Step builders: (arch × shape × mesh) -> a pjit-able function + abstract
inputs + in/out shardings.

This is the seam between the model zoo and the distribution layer, used by
the multi-pod dry-run, the roofline benchmark and the real drivers:

* ``train_4k``     lowers ``train_step``   (loss + grads + AdamW/ZeRO-1)
* ``prefill_32k``  lowers ``prefill_step`` (prompt -> cache + last logits)
* ``decode_32k`` / ``long_500k`` lower ``serve_step`` (1 new token against
  a KV cache of seq_len; SSM archs carry O(1) state instead)

Sharding policy (single pod 16x16 = ("data","model"); multi-pod adds
"pod"):

* weights: Megatron TP over "model" (heads/mlp/vocab/experts/ssm_inner);
  non-dividing dims fall back to replication per-tensor.
* train: batch over ("pod","data"); optimizer state ZeRO-1 over "data".
* prefill: batch over ("pod","data"); cache written out in the *decode*
  layout so serving needs no resharding step between phases.
* decode: context parallelism — KV-cache seq over "model", batch over
  ("pod","data"); works for every kv_heads count (paligemma kv=1).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.distributed.sharding import (
    logical_to_pspec,
    make_rules,
    shard_pytree_specs,
)
from repro.models import abstract_params, build_model, logical_axes
from repro.models.config import ModelConfig
from repro.training.data import abstract_batch
from repro.training.optimizer import AdamWConfig, zero1_logical_tree
from repro.training.train_loop import make_train_step


@dataclasses.dataclass
class BuiltStep:
    """Everything needed to lower / run one cell."""

    fn: Callable                  # jit-able python callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any
    static_desc: str
    donate: Tuple[int, ...] = ()  # donated args (cache / params+opt): the
                                  # output reuses the input buffer — decode
                                  # would otherwise hold 2x the KV cache

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate,
        )

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def _named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_spec(mesh: Mesh, batch: int = 0) -> P:
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if batch:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch % n != 0:
            # shrink to the largest prefix that divides (batch=1 cells)
            if batch % mesh.shape.get("data", 1) == 0 and batch > 1:
                return P("data")
            return P()
    return P(axes if len(axes) > 1 else axes[0])


def _data_axis_size(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# ---------------------------------------------------------------------------
# Cache sharding (decode layout; prefill writes this layout out)
# ---------------------------------------------------------------------------

_CACHE_LOGICAL_AXES = {
    # kv caches: (layers/blocks, batch, seq, kv_heads, head_dim)
    "kv": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    # whisper cross kv: seq is the (short) encoder length
    "cross": ("layers", "batch", None, "kv_heads", "head_dim"),
}


def _cache_pspec_tree(cache_abs: Any, mesh: Mesh, rules) -> Any:
    """PartitionSpec tree matching a cache pytree (keyed heuristically)."""
    def leaf_spec(path: Tuple, ab) -> P:
        keys = [getattr(p, "key", str(p)) for p in path]
        name = keys[0] if keys else ""
        if name == "len":
            return P()
        if name in ("kv", "attn_kv"):
            return logical_to_pspec(
                _CACHE_LOGICAL_AXES["kv"], ab.shape, mesh, rules
            )
        if name in ("cross_k", "cross_v"):
            return logical_to_pspec(
                _CACHE_LOGICAL_AXES["cross"], ab.shape, mesh, rules
            )
        if name in ("ssm_state", "prelude_state", "block_state"):
            # (stack..., batch, channels...) — shard batch; channels over
            # model where divisible
            nd = len(ab.shape)
            if keys[-1] == "conv":
                logical = (None,) * (nd - 3) + ("batch", None, "ssm_inner")
            elif nd >= 4 and keys[-1] == "ssm":
                # mamba1: (L,B,di,N); mamba2: (stack..,B,H,P,N)
                if nd == 4:
                    logical = (None, "batch", "ssm_inner", None)
                else:
                    logical = (None,) * (nd - 4) + (
                        "batch", "heads", None, None
                    )
            else:
                logical = (None,) * (nd - 1) + ("batch",)
            return logical_to_pspec(logical, ab.shape, mesh, rules)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    specs = [leaf_spec(path, ab) for path, ab in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeSpec,
    *,
    microbatches: int = 16,
    rules_name: str = "tp",
    param_dtype=jnp.bfloat16,   # mixed precision: bf16 params, fp32 m/v
    compress_grads: bool = False,
    impl: str = "blockwise",
    remat: bool = True,
    grad_accum: str = "f32_sharded",
    opt_cfg: Optional[AdamWConfig] = None,
) -> BuiltStep:
    model = build_model(cfg, impl=impl, remat=remat)
    rules = make_rules(rules_name)
    bp = model.blueprint()
    abs_p = abstract_params(bp, param_dtype)
    logical = logical_axes(bp)
    p_specs = shard_pytree_specs(logical, abs_p, mesh, rules)

    # optimizer state: ZeRO-1 over data
    z_logical = zero1_logical_tree(logical, abs_p, _data_axis_size(mesh))
    z_specs = shard_pytree_specs(z_logical, abs_p, mesh, rules)
    abs_opt = {
        "m": jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), abs_p
        ),
        "v": jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), abs_p
        ),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    opt_specs = {"m": z_specs, "v": z_specs, "step": P()}

    abs_b = abstract_batch(cfg, shape.global_batch, shape.seq_len)
    bspec = _batch_spec(mesh, shape.global_batch)
    b_specs = {k: P(*bspec) for k in abs_b}

    step = make_train_step(
        model, cfg, opt_cfg or AdamWConfig(),
        microbatches=microbatches, compress_grads=compress_grads,
        grad_specs=z_specs, batch_spec=bspec, grad_accum=grad_accum,
    )
    if compress_grads:
        abs_opt["ef_error"] = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), abs_p
        )
        opt_specs["ef_error"] = z_specs

    metrics_specs = {"loss": P(), "grad_norm": P(), "step": P()}
    return BuiltStep(
        fn=step,
        abstract_args=(abs_p, abs_opt, abs_b),
        in_shardings=_named((p_specs, opt_specs, b_specs), mesh),
        out_shardings=_named((p_specs, opt_specs, metrics_specs), mesh),
        static_desc=(
            f"train {cfg.name} seq={shape.seq_len} gb={shape.global_batch} "
            f"mb={microbatches}"
        ),
        donate=(0, 1),        # params + opt_state update in place
    )


def build_prefill_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeSpec,
    *,
    rules_name: str = "tp",
    cache_rules_name: str = "decode_cp",
    dtype=jnp.bfloat16,
    impl: str = "blockwise",
) -> BuiltStep:
    model = build_model(cfg, impl=impl)
    rules = make_rules(rules_name)
    cache_rules = make_rules(cache_rules_name)
    bp = model.blueprint()
    abs_p = abstract_params(bp, dtype)
    p_specs = shard_pytree_specs(logical_axes(bp), abs_p, mesh, rules)

    B, S = shape.global_batch, shape.seq_len
    cache_len = S + (cfg.frontend_seq if (cfg.frontend and not
                                          cfg.is_encdec) else 0)
    abs_cache = model.abstract_cache(B, cache_len, dtype)
    cache_specs = _cache_pspec_tree(abs_cache, mesh, cache_rules)
    bspec = _batch_spec(mesh, B)
    abs_tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)

    extra_abs = ()
    extra_specs = ()
    if cfg.is_encdec:
        extra_abs = (
            jax.ShapeDtypeStruct((B, cfg.frontend_seq, cfg.d_model), dtype),
        )
        extra_specs = (P(*bspec),)

        def fn(params, tokens, frames, cache):
            return model.prefill(params, frames, tokens, cache, dtype=dtype)
    elif cfg.frontend:
        extra_abs = (
            jax.ShapeDtypeStruct((B, cfg.frontend_seq, cfg.d_model), dtype),
        )
        extra_specs = (P(*bspec),)

        def fn(params, tokens, patches, cache):
            return model.prefill(
                params, tokens, cache, prefix_embed=patches, dtype=dtype
            )
    else:

        def fn(params, tokens, cache):
            return model.prefill(params, tokens, cache, dtype=dtype)

    logits_spec = P(*bspec)
    return BuiltStep(
        fn=fn,
        abstract_args=(abs_p, abs_tokens) + extra_abs + (abs_cache,),
        in_shardings=_named(
            (p_specs, P(*bspec)) + extra_specs + (cache_specs,), mesh
        ),
        out_shardings=_named((logits_spec, cache_specs), mesh),
        static_desc=f"prefill {cfg.name} seq={S} gb={B}",
        donate=(len((abs_p, abs_tokens) + extra_abs),),   # the cache
    )


def build_serve_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeSpec,
    *,
    rules_name: str = "tp",
    cache_rules_name: str = "decode_cp",
    dtype=jnp.bfloat16,
    kv_dtype=None,            # e.g. jnp.float8_e4m3fn: halves KV traffic
    impl: str = "blockwise",
) -> BuiltStep:
    """One-token decode step against a cache of shape.seq_len tokens."""
    model = build_model(cfg, impl=impl)
    rules = make_rules(rules_name)
    cache_rules = make_rules(cache_rules_name)
    bp = model.blueprint()
    abs_p = abstract_params(bp, dtype)
    p_specs = shard_pytree_specs(logical_axes(bp), abs_p, mesh, rules)

    B, S = shape.global_batch, shape.seq_len
    cache_len = S + (cfg.frontend_seq if (cfg.frontend and not
                                          cfg.is_encdec) else 0)
    abs_cache = model.abstract_cache(B, cache_len, kv_dtype or dtype)
    cache_specs = _cache_pspec_tree(abs_cache, mesh, cache_rules)
    bspec = _batch_spec(mesh, B)
    abs_tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def fn(params, tokens, cache):
        logits, new_cache = model.decode_step(
            params, tokens, cache, dtype=dtype
        )
        # greedy next token (serving returns tokens, not logit tensors)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return BuiltStep(
        fn=fn,
        abstract_args=(abs_p, abs_tokens, abs_cache),
        in_shardings=_named((p_specs, P(*bspec), cache_specs), mesh),
        out_shardings=_named((P(*bspec), cache_specs), mesh),
        static_desc=f"decode {cfg.name} ctx={S} gb={B}",
        donate=(2,),          # the cache
    )


def build_step(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    **kwargs,
) -> BuiltStep:
    """Dispatch on the shape's kind."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kwargs)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kwargs)
    if shape.kind == "decode":
        return build_serve_step(cfg, mesh, shape, **kwargs)
    raise ValueError(f"unknown shape kind {shape.kind}")


def input_specs(arch: str, shape_name: str, mesh: Mesh, **kwargs):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    dry-run contract: weak-type-correct, shardable, no allocation)."""
    return build_step(arch, shape_name, mesh, **kwargs).abstract_args

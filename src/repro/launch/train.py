"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 20 --batch 4 --seq 128 --scale smoke

On a real TPU fleet this runs the full config on the production mesh with
ZeRO-1/2 sharding, per-layer remat, microbatching, int8 error-feedback
gradient compression across pods, and atomic checkpoints; ``--scale smoke``
runs the reduced config on the host device (the path CI exercises).  The
full-config + production-mesh lowering is proven by ``dryrun.py``.

Fault tolerance: atomic checkpoints every ``--ckpt-every`` steps; on
restart the driver resumes from the newest complete checkpoint.  On
capacity loss, ``repro.distributed.elastic.plan_remesh`` shrinks the data
axis and re-lowers (see DESIGN.md §7).
"""

import argparse
import sys
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.models import build_model, param_count
from repro.training import AdamWConfig, adamw_init, make_train_step
from repro.training.data import make_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.scale == "smoke" else get_config)(
        args.arch
    )
    model = build_model(cfg, remat=True)
    print(f"[train] {cfg.name}: "
          f"{param_count(model.blueprint())/1e6:.1f}M params, "
          f"devices={len(jax.devices())}")

    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(total_steps=max(args.steps, 100))
    step_fn = jax.jit(
        make_train_step(
            model, cfg, opt_cfg, microbatches=args.microbatches,
            compress_grads=args.compress_grads,
        )
    )

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        restored, start = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt_state": opt_state}
        )
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"[train] resumed from step {start}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = make_batch(cfg, args.batch, args.seq, seed=0, step=step)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:4d} loss {float(m['loss']):9.4f} "
                  f"gnorm {float(m['grad_norm']):9.3f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params, opt_state)
    print(f"[train] {args.steps - start} steps in "
          f"{time.perf_counter()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())

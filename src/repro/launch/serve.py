"""Production serving driver: SpotHedge-managed fleet + request replay.

    PYTHONPATH=src python -m repro.launch.serve --arch command-r-35b \
        --trace aws-3 --policy spothedge --hours 4

Runs the full control plane (SpotHedge placement + dynamic fallback +
autoscaler + least-loaded LB) against a recorded spot trace with the
roofline-derived data-plane latency model — the §5.1 methodology.  Swap
``--live`` (reduced arch) to serve real tokens from in-process JAX engines
(see examples/serve_llm.py for the live path).
"""

import argparse
import sys

from repro.cluster.simulator import SimConfig
from repro.cluster.traces import TraceLibrary
from repro.configs import ARCH_IDS, get_config
from repro.core.autoscaler import LoadAutoscaler
from repro.core.policy import make_policy, registered_policies
from repro.serving.sim import ServingSimulator
from repro.workloads import make_workload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="command-r-35b")
    ap.add_argument("--trace", default="aws-3")
    ap.add_argument("--policy", default="spothedge",
                    choices=registered_policies())
    ap.add_argument("--workload", default="arena",
                    choices=["poisson", "arena", "maf"])
    ap.add_argument("--itype", default="g5.48xlarge")
    ap.add_argument("--hours", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--qps-per-replica", type=float, default=0.8)
    ap.add_argument("--timeout", type=float, default=100.0)
    args = ap.parse_args(argv)

    trace = TraceLibrary().get(args.trace)
    cfg = get_config(args.arch)
    kw = {"rate_per_s": args.rate} if args.workload == "poisson" else {
        "base_rate_per_s": args.rate
    }
    reqs = make_workload(args.workload, seed=11, **kw).generate(
        args.hours * 3600 - 600
    )
    print(f"[serve] {args.policy} serving {cfg.name} on {args.itype}: "
          f"{len(reqs)} requests / {args.hours}h over trace {trace.name}")
    sim = ServingSimulator(
        trace, make_policy(args.policy), reqs, cfg, itype=args.itype,
        autoscaler=LoadAutoscaler(
            args.qps_per_replica, min_replicas=2, max_replicas=12,
            upscale_delay_s=60.0, downscale_delay_s=600.0,
            initial_target=4,
        ),
        timeout_s=args.timeout, workload_name=args.workload, concurrency=4,
        sim_config=SimConfig(itype=args.itype, control_interval_s=15.0),
    )
    res = sim.run(args.hours * 3600)
    print(res.summary())
    return 0


if __name__ == "__main__":
    sys.exit(main())

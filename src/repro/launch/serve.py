"""Production serving driver: SpotHedge-managed fleet + request replay.

    PYTHONPATH=src python -m repro.launch.serve --arch command-r-35b \
        --trace aws-3 --policy spothedge --hours 4

    # or run a declarative service file (paper Listing 1):
    PYTHONPATH=src python -m repro.launch.serve --spec examples/service.yaml

    # or expand a spec's sweep: section into a scenario matrix and run
    # every cell (report JSON lands under artifacts/bench/):
    PYTHONPATH=src python -m repro.launch.serve --spec examples/sweep.yaml \
        --sweep --workers auto

Runs the full control plane (SpotHedge placement + dynamic fallback +
autoscaler + least-loaded LB) against a recorded spot trace with the
roofline-derived data-plane latency model — the §5.1 methodology.  Every
run is a :class:`repro.service.ServiceSpec`; the CLI flags are just a spec
built for you.  Swap ``--live`` (reduced arch) to serve real tokens from
in-process JAX engines (see examples/serve_llm.py for the live path).
"""

import argparse
import json
import sys

from repro.configs import ARCH_IDS
from repro.core.policy import registered_policies
from repro.service import Service, load_spec


def spec_from_args(args: argparse.Namespace) -> dict:
    """The CLI's kwarg soup, expressed as the one true spec dict."""
    return {
        "name": f"serve-{args.arch}",
        "model": args.arch,
        "trace": args.trace,
        "resources": {"instance_type": args.itype},
        "replica_policy": {"name": args.policy},
        "autoscaler": {
            "kind": "load",
            "target": 4,
            "qps_per_replica": args.qps_per_replica,
            "min_replicas": 2,
            "max_replicas": 12,
            "upscale_delay_s": 60.0,
            "downscale_delay_s": 600.0,
        },
        "workload": {"kind": args.workload, "rate_per_s": args.rate,
                     "seed": 11},
        "sim": {
            "duration_hours": args.hours,
            "control_interval_s": 15.0,
            "timeout_s": args.timeout,
            "concurrency": 4,
        },
    }


def _run_sweep(spec, args: argparse.Namespace) -> int:
    """Expand spec.sweep into a ScenarioSuite, run it, save the report."""
    import os

    from repro.experiments import ScenarioSuite

    suite = ScenarioSuite.from_spec(spec)
    print(f"[serve] sweep {spec.name!r}: {len(suite)} scenarios "
          f"({spec.sweep.size if spec.sweep else 1} grid cells)")
    report = suite.run(
        workers=args.workers,
        save_to=os.path.join("artifacts", "bench"),
        progress=True,
    )
    print(report.summary())
    print(f"[serve] report: artifacts/bench/scenario_{suite.name}.json")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="run a service spec file (.yaml/.json); "
                    "other flags are ignored")
    ap.add_argument("--arch", choices=ARCH_IDS, default="command-r-35b")
    ap.add_argument("--trace", default="aws-3")
    ap.add_argument("--policy", default="spothedge",
                    choices=registered_policies())
    ap.add_argument("--workload", default="arena",
                    choices=["poisson", "arena", "maf"])
    ap.add_argument("--itype", default="g5.48xlarge")
    ap.add_argument("--hours", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--qps-per-replica", type=float, default=0.8)
    ap.add_argument("--timeout", type=float, default=100.0)
    ap.add_argument("--status", action="store_true",
                    help="print the resolved service status as JSON")
    ap.add_argument("--sweep", action="store_true",
                    help="expand the spec's sweep: grid into a scenario "
                    "suite and run every cell")
    ap.add_argument("--workers", default=None, metavar="N|auto",
                    help="run sweep cells in N worker processes "
                    "('auto' = one per CPU); default serial")
    ap.add_argument("--engine", default=None,
                    choices=["vector", "legacy"],
                    help="override sim.engine for this run")
    ap.add_argument("--replica-model", default=None,
                    choices=["request", "token"],
                    help="override sim.replica_model for this run "
                    "(token = continuous batching + TTFT/TPOT/goodput)")
    args = ap.parse_args(argv)

    from repro.service import SpecError

    try:
        spec = load_spec(args.spec if args.spec else spec_from_args(args))
        if args.engine and spec.sim.engine != args.engine:
            import dataclasses

            spec = dataclasses.replace(
                spec, sim=dataclasses.replace(spec.sim, engine=args.engine)
            )
        if args.replica_model and \
                spec.sim.replica_model != args.replica_model:
            import dataclasses

            spec = dataclasses.replace(
                spec,
                sim=dataclasses.replace(
                    spec.sim, replica_model=args.replica_model
                ),
            )
        if args.sweep:
            return _run_sweep(spec, args)
        if args.workers is not None:
            print("error: --workers requires --sweep (a single service "
                  "run is one cell)", file=sys.stderr)
            return 2
        svc = Service(spec)
        resolved = svc.resolve()
        print(f"[serve] {spec.replica_policy.name} serving "
              f"{resolved.model_config.name} on "
              f"{spec.resources.instance_type}: {len(resolved.requests)} "
              f"requests / {spec.sim.duration_hours:g}h over trace "
              f"{resolved.trace.name} ({len(resolved.zones)} zones)")
        res = svc.run()
    except SpecError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    print(res.summary())
    if args.status:
        print(json.dumps(svc.status(), indent=1, default=float))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""MigrationRuntime: the engine-facing executor of the preemption plan.

One instance is shared by a serving engine for the whole run.  On a
warned preemption the engine hands it the dying replica's
:class:`~repro.serving.token.batch.ContinuousBatch`, its
:class:`~repro.cluster.instance.Instance`, and the surviving candidate
replicas; the runtime snapshots the batch, runs the pure planner,
injects migrated sequences into the target batches (they join after the
transfer delay, KV intact, counting against the target's KV budget) and
kills the residue.  Both engines call this one code path with
identically-constructed inputs, so their migration decisions are
identical by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.cluster.catalog import link_bandwidth_gbps
from repro.migration.config import MigrationSpec
from repro.migration.planner import SeqState, TargetInfo, plan_preemption
from repro.obs.events import MigrationPlanEvent, ReplicaLifecycleEvent
from repro.obs.recorder import ObsRecorder

__all__ = ["MigratedSeq", "PreemptionOutcome", "MigrationRuntime"]


@dataclasses.dataclass(frozen=True)
class MigratedSeq:
    """One sequence shipped to a surviving replica."""

    state: SeqState
    target_rid: int                 # instance id of the receiving replica
    transfer_s: float               # this sequence's own wire time
    resume_s: float                 # absolute time it joins the target


@dataclasses.dataclass(frozen=True)
class PreemptionOutcome:
    """Everything a serving engine needs to account one preemption."""

    drained: Tuple[SeqState, ...]   # finish in place at the kill instant
    migrated: Tuple[MigratedSeq, ...]
    kill_report: Any                # KillReport for the residue
    migrated_kv_tokens: int
    saved_prefill_tokens: int       # prefill work NOT re-done elsewhere
    saved_decode_tokens: int
    transfer_s_total: float
    recompute_saved_s: float        # engine-seconds of recompute avoided

    @property
    def n_drained(self) -> int:
        return len(self.drained)

    @property
    def n_migrated(self) -> int:
        return len(self.migrated)


class MigrationRuntime:
    """Plans and executes grace-period KV migration for one engine run."""

    def __init__(
        self,
        spec: MigrationSpec,
        engine_cfg,
        obs: Optional[ObsRecorder] = None,
    ) -> None:
        if not spec.enabled:
            raise ValueError(
                "MigrationRuntime requires migration.enabled: true"
            )
        self.spec = spec
        self.engine_cfg = engine_cfg    # TokenEngineConfig (duck-typed)
        # events derive solely from inputs + the pure planner's outcome,
        # so both engines emit identical streams through here
        self.obs = obs if obs is not None else ObsRecorder(detail="off")

    # ------------------------------------------------------------------
    def bandwidth_bytes_per_s(self, src_inst, dst_inst) -> float:
        """Link bandwidth from the dying to a surviving instance: the
        spec's flat override when set, else the catalog's locality tiers."""
        if self.spec.bandwidth_gbps is not None:
            gbps = self.spec.bandwidth_gbps
        else:
            gbps = link_bandwidth_gbps(
                src_inst.cloud, src_inst.region, src_inst.zone,
                dst_inst.cloud, dst_inst.region, dst_inst.zone,
            )
        return gbps * 1e9 / 8.0

    # ------------------------------------------------------------------
    def execute_preemption(
        self,
        src_batch,                  # ContinuousBatch of the dying replica
        src_inst,                   # its Instance
        candidates: Sequence[Tuple[int, Any, Any]],  # (rid, batch, inst)
        now: float,
        grace_s: float,
    ) -> PreemptionOutcome:
        states = [SeqState(*row) for row in src_batch.iter_states()]
        targets: List[TargetInfo] = []
        bmap: Dict[int, Any] = {}
        for rid, tb, inst in candidates:
            bmap[rid] = tb
            targets.append(TargetInfo(
                rid=rid,
                headroom_tokens=(
                    tb.cfg.kv_budget_tokens - tb.committed_tokens
                ),
                bandwidth_bytes_per_s=self.bandwidth_bytes_per_s(
                    src_inst, inst
                ),
            ))
        decisions = plan_preemption(
            states, targets, grace_s, self.engine_cfg, self.spec
        )
        drained: List[SeqState] = []
        migrated: List[MigratedSeq] = []
        removed: List[int] = []
        # span taps ride the source batch's sampled-key map; consult it
        # before remove()/kill() evict the entries below
        tord = getattr(src_batch, "_tord", None)
        for d in decisions:
            s = d.state
            if d.action == "drain":
                drained.append(s)
                removed.append(s.key)
            elif d.action == "migrate":
                resume = now + d.resume_offset_s
                ok = bmap[d.target_rid].enqueue_migrated(
                    s.key, s.prompt_tokens, s.output_tokens,
                    s.arrival_s, resume, s.prefilled, s.decoded,
                    s.first_s,
                )
                if ok:
                    migrated.append(MigratedSeq(
                        state=s, target_rid=d.target_rid,
                        transfer_s=d.transfer_s, resume_s=resume,
                    ))
                    removed.append(s.key)
                    if tord:
                        o = tord.get(s.key)
                        if o is not None:
                            to_ord = self.obs.replica_ordinal(
                                d.target_rid
                            )
                            src_batch.tap.migrate(
                                o, now, to_replica=to_ord,
                                transfer_s=d.transfer_s, plan_t=now,
                            )
                            src_batch.tap.migrate_arrive(
                                o, resume, replica=to_ord
                            )
                            bmap[d.target_rid].track(s.key, o)
                # else: planner headroom said yes but the target refused
                # (over-large request) — falls through to the kill path
        if removed:
            src_batch.remove(removed)
        kr = src_batch.kill()
        saved_p = sum(s.prefilled for s in drained) + sum(
            m.state.prefilled for m in migrated
        )
        saved_d = sum(s.decoded for s in drained) + sum(
            m.state.decoded for m in migrated
        )
        cfg = self.engine_cfg
        if self.obs.enabled:
            # lifecycle phases precede the cluster's "dead" event: the
            # engine runs inside the preempt listener, and the cluster
            # emits death only after all listeners return
            src_ord = self.obs.replica_ordinal(src_inst.id)
            if drained:
                self.obs.emit(ReplicaLifecycleEvent(
                    t=now, phase="draining",
                    instance_id=src_ord, zone=src_inst.zone,
                ))
            if migrated:
                self.obs.emit(ReplicaLifecycleEvent(
                    t=now, phase="migrating",
                    instance_id=src_ord, zone=src_inst.zone,
                ))
            self.obs.emit(MigrationPlanEvent(
                t=now,
                instance_id=src_ord,
                n_drained=len(drained),
                n_migrated=len(migrated),
                n_killed=kr.n_batch + kr.n_queued,
                migrated_kv_tokens=sum(
                    m.state.resident_tokens for m in migrated
                ),
                transfer_s=sum(m.transfer_s for m in migrated),
                grace_s=grace_s,
            ))
        return PreemptionOutcome(
            drained=tuple(drained),
            migrated=tuple(migrated),
            kill_report=kr,
            migrated_kv_tokens=sum(
                m.state.resident_tokens for m in migrated
            ),
            saved_prefill_tokens=saved_p,
            saved_decode_tokens=saved_d,
            transfer_s_total=sum(m.transfer_s for m in migrated),
            recompute_saved_s=(
                saved_p * cfg.prefill_s_per_token
                + saved_d * cfg.weight_read_s
            ),
        )

"""The drain/migrate/kill decision procedure.

Pure and deterministic: a snapshot of the dying batch's per-sequence
state plus a snapshot of the surviving targets' KV headroom in, a
decision per sequence out.  Both serving engines call this exact
function with identically-constructed inputs, which is what makes their
migration behavior decision-identical (enforced by the differential
test suite).

Per sequence, in descending resident-KV order (largest caches are the
most expensive to lose; ties by ascending key for determinism):

* **drain** — the remaining work (``(prompt-prefilled)·prefill_s +
  (out-decoded)·weight_read_s``) fits both ``drain_threshold_s`` and the
  grace window: finish in place, ship nothing.
* **migrate** — resident KV meets ``migrate_threshold_tokens``, some
  target has KV-budget headroom for the sequence's full ``prompt+out``
  reservation, and the cumulative transfer time (transfers serialize on
  the dying instance's NIC) still fits the grace window: ship
  ``resident × kv_bytes_per_token`` (× 0.5 under int8) and resume on
  the target after the transfer delay.
* **kill** — everything else: re-prefill elsewhere, the status quo.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.migration.config import MigrationSpec
from repro.migration.cost import kv_transfer_bytes, kv_transfer_s

__all__ = ["SeqState", "TargetInfo", "SeqDecision", "plan_preemption"]


@dataclasses.dataclass(frozen=True)
class SeqState:
    """Snapshot of one in-flight sequence on the dying batch."""

    key: int
    prompt_tokens: int
    output_tokens: int
    prefilled: int                  # prompt tokens prefilled so far
    decoded: int                    # output tokens produced so far
    arrival_s: float
    enqueued_s: float
    first_s: float                  # engine-clock first token (nan: none)

    @property
    def resident_tokens(self) -> int:
        return self.prefilled + self.decoded


@dataclasses.dataclass
class TargetInfo:
    """A surviving replica's capacity to receive migrations (mutable:
    headroom is decremented as the planner assigns sequences)."""

    rid: int
    headroom_tokens: int            # kv_budget - committed tokens
    bandwidth_bytes_per_s: float    # link from the dying instance


@dataclasses.dataclass(frozen=True)
class SeqDecision:
    state: SeqState
    action: str                     # "drain" | "migrate" | "kill"
    target_rid: Optional[int] = None
    transfer_s: float = 0.0         # this sequence's own wire time
    resume_offset_s: float = 0.0    # cumulative delay until it resumes


def plan_preemption(
    states: Sequence[SeqState],
    targets: Sequence[TargetInfo],
    grace_s: float,
    engine_cfg,                     # TokenEngineConfig (duck-typed)
    spec: MigrationSpec,
) -> List[SeqDecision]:
    """Decide drain/migrate/kill for every sequence on a dying batch."""
    order = sorted(
        states, key=lambda s: (-s.resident_tokens, s.arrival_s, s.key)
    )
    drain_cap = min(spec.drain_threshold_s, grace_s)
    pf = engine_cfg.prefill_s_per_token
    w = engine_cfg.weight_read_s
    cum = 0.0                       # transfers serialize on the src NIC
    out: List[SeqDecision] = []
    for s in order:
        remaining_s = (
            (s.prompt_tokens - s.prefilled) * pf
            + (s.output_tokens - s.decoded) * w
        )
        if remaining_s <= drain_cap:
            out.append(SeqDecision(s, "drain"))
            continue
        decision: Optional[SeqDecision] = None
        resident = s.resident_tokens
        if resident >= spec.migrate_threshold_tokens:
            nbytes = kv_transfer_bytes(
                resident, engine_cfg.kv_bytes_per_token, spec.compression
            )
            need = s.prompt_tokens + s.output_tokens
            best = None             # (sort_key, target, transfer_s)
            for t in targets:
                if t.headroom_tokens < need:
                    continue
                tr = kv_transfer_s(
                    nbytes, t.bandwidth_bytes_per_s, spec.link_latency_s
                )
                if cum + tr > grace_s:
                    continue
                rank = (-t.bandwidth_bytes_per_s, -t.headroom_tokens, t.rid)
                if best is None or rank < best[0]:
                    best = (rank, t, tr)
            if best is not None:
                _, tgt, tr = best
                cum += tr
                tgt.headroom_tokens -= need
                decision = SeqDecision(
                    s, "migrate",
                    target_rid=tgt.rid,
                    transfer_s=tr,
                    resume_offset_s=cum,
                )
        out.append(decision or SeqDecision(s, "kill"))
    return out

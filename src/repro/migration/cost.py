"""Migration cost model: KV transfer pricing + elastic re-shard pricing.

Two cost surfaces, both pure arithmetic:

* **KV transfer** — a migrated sequence ships ``resident_tokens ×
  kv_bytes_per_token`` over the inter-zone link; int8 KV compression
  (the symmetric per-tensor scheme of ``distributed/compression.py``,
  bf16 → int8) halves the bytes.  Transfer time is
  ``link_latency + bytes / bandwidth``.

* **Elastic re-shard** — SpotServe-style re-parallelization: instead of
  dying when chips are lost, shrink one mesh axis (power-of-two steps,
  the policy of ``distributed/elastic.plan_remesh``) and price the state
  movement.  Shrinking the ``data`` axis relocates the dropped replicas'
  KV; shrinking a model axis additionally re-partitions the weights.
  This is a pricing API for the planner and reports — replicas in the
  serving simulators are single-instance, so the engines consume only
  the KV-transfer surface.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

__all__ = [
    "INT8_KV_FACTOR",
    "compression_factor",
    "kv_transfer_bytes",
    "kv_transfer_s",
    "ReshardCost",
    "plan_reshard",
]

# bf16 KV quantized to int8 with a per-tensor scale: 2 bytes -> 1 byte
INT8_KV_FACTOR = 0.5


def compression_factor(compression: str) -> float:
    """Bytes-on-the-wire multiplier for a KV compression mode."""
    if compression == "int8":
        return INT8_KV_FACTOR
    if compression == "none":
        return 1.0
    raise ValueError(f"unknown KV compression mode {compression!r}")


def kv_transfer_bytes(
    resident_tokens: int,
    kv_bytes_per_token: float,
    compression: str = "none",
) -> float:
    """Bytes a migration must move for one sequence's resident KV."""
    return (
        float(resident_tokens)
        * float(kv_bytes_per_token)
        * compression_factor(compression)
    )


def kv_transfer_s(
    nbytes: float,
    bandwidth_bytes_per_s: float,
    link_latency_s: float = 0.0,
) -> float:
    """Wall-clock seconds to move ``nbytes`` over one link."""
    if nbytes <= 0.0:
        return float(link_latency_s)
    if bandwidth_bytes_per_s <= 0.0:
        return float("inf")
    return float(link_latency_s) + float(nbytes) / float(
        bandwidth_bytes_per_s
    )


# ---------------------------------------------------------------------------
# Elastic re-shard pricing (SpotServe §4.2 analogue)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReshardCost:
    """Priced plan for continuing on fewer chips instead of dying."""

    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_chips: int
    moved_bytes: float              # state that crosses the network
    transfer_s: float               # moved_bytes over the link
    relower_s: float                # recompile/re-lower the step fn

    @property
    def new_chip_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n

    @property
    def total_s(self) -> float:
        return self.transfer_s + self.relower_s

    def to_remesh_plan(self):
        """The equivalent ``distributed.elastic.RemeshPlan`` (lazy import:
        ``distributed/`` pulls in jax, which this package must not require
        at import time)."""
        try:
            from repro.distributed.elastic import RemeshPlan
        except Exception:  # jax unavailable: duck-typed stand-in
            @dataclasses.dataclass(frozen=True)
            class RemeshPlan:  # type: ignore[no-redef]
                old_shape: Tuple[int, ...]
                new_shape: Tuple[int, ...]
                axis_names: Tuple[str, ...]
                dropped_chips: int
        return RemeshPlan(
            old_shape=self.old_shape,
            new_shape=self.new_shape,
            axis_names=self.axis_names,
            dropped_chips=self.dropped_chips,
        )


def plan_reshard(
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    surviving_chips: int,
    *,
    kv_resident_bytes: float = 0.0,
    weight_bytes: float = 0.0,
    bandwidth_bytes_per_s: float,
    link_latency_s: float = 0.0,
    relower_s: float = 2.0,
    shrink_axis: str = "data",
) -> Optional[ReshardCost]:
    """Price a SpotServe-style degree change onto ``surviving_chips``.

    Mirrors ``distributed.elastic.plan_remesh``'s policy — shrink only
    ``shrink_axis``, power-of-two steps — in pure arithmetic.  Returns
    ``None`` when no shrink of that axis fits the survivors (the caller
    falls back to kill-and-restart).

    Cost model: the dropped chips' share of resident KV always moves
    (``kv_resident_bytes × dropped/old``); weights move only when a
    *model* axis changes degree (data-parallel survivors already hold
    full weight shards).
    """
    names = tuple(axis_names)
    shape = tuple(int(s) for s in mesh_shape)
    if len(names) != len(shape):
        raise ValueError(
            f"mesh_shape {shape} and axis_names {names} length mismatch"
        )
    if shrink_axis not in names:
        raise ValueError(f"mesh has no axis {shrink_axis!r}")
    idx = names.index(shrink_axis)
    other = 1
    for i, s in enumerate(shape):
        if i != idx:
            other *= s
    old_chips = other * shape[idx]
    new_dim = shape[idx]
    while new_dim > 1 and other * new_dim > surviving_chips:
        new_dim //= 2
    if other * new_dim > surviving_chips:
        return None
    new_shape = tuple(
        new_dim if i == idx else s for i, s in enumerate(shape)
    )
    dropped = old_chips - other * new_dim
    frac = dropped / old_chips
    moved = kv_resident_bytes * frac
    if shrink_axis != "data":
        moved += weight_bytes * frac
    transfer = kv_transfer_s(moved, bandwidth_bytes_per_s, link_latency_s)
    return ReshardCost(
        old_shape=shape,
        new_shape=new_shape,
        axis_names=names,
        dropped_chips=dropped,
        moved_bytes=moved,
        transfer_s=transfer,
        relower_s=relower_s,
    )

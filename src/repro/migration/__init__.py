"""Grace-period KV migration & elastic re-shard on preemption.

SpotServe (arxiv 2311.15566) observes that a spot preemption does not
have to destroy serving state: clouds deliver a 30–120 s warning
(``CloudSpec.preemption_warning_s``), and within that window an engine
can *drain* short sequences, *migrate* resident KV cache to a surviving
replica over the inter-zone network, or *re-shard* onto fewer chips —
killing and re-prefilling from scratch is the worst case, not the only
case.

This package is the planner + cost model for that decision, shared by
both serving engines so their migration behavior is decision-identical:

* :mod:`~repro.migration.config` — :class:`MigrationSpec`, the
  spec-visible knobs (stdlib-only; importable from the serving layer);
* :mod:`~repro.migration.cost` — KV transfer bytes/seconds (int8
  compression optionally halves bytes, reusing the quantization scheme
  of ``distributed/compression.py``) and SpotServe-style elastic
  re-shard pricing against ``distributed/elastic.RemeshPlan``;
* :mod:`~repro.migration.planner` — the pure drain/migrate/kill
  decision procedure over a snapshot of batch state;
* :mod:`~repro.migration.runtime` — :class:`MigrationRuntime`, the
  engine-facing executor that snapshots a dying
  :class:`~repro.serving.token.batch.ContinuousBatch`, plans, injects
  migrated sequences into target batches and returns the residual
  :class:`~repro.serving.token.batch.KillReport`.
"""

from repro.migration.config import MigrationSpec
from repro.migration.cost import (
    INT8_KV_FACTOR,
    ReshardCost,
    compression_factor,
    kv_transfer_bytes,
    kv_transfer_s,
    plan_reshard,
)
from repro.migration.planner import (
    SeqDecision,
    SeqState,
    TargetInfo,
    plan_preemption,
)
from repro.migration.runtime import (
    MigratedSeq,
    MigrationRuntime,
    PreemptionOutcome,
)

__all__ = [
    "MigrationSpec",
    "INT8_KV_FACTOR",
    "ReshardCost",
    "compression_factor",
    "kv_transfer_bytes",
    "kv_transfer_s",
    "plan_reshard",
    "SeqDecision",
    "SeqState",
    "TargetInfo",
    "plan_preemption",
    "MigratedSeq",
    "MigrationRuntime",
    "PreemptionOutcome",
]

"""MigrationSpec: spec-visible knobs of the KV-migration subsystem.

Kept stdlib-only (no numpy, no service-layer imports) so both serving
engines and the service spec can import it without layering violations:
``repro.serving`` must never import ``repro.service``, yet both need the
same frozen knob set.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

__all__ = ["MigrationSpec", "COMPRESSION_MODES"]

COMPRESSION_MODES = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class MigrationSpec:
    """Knobs of the grace-period drain/migrate/kill planner.

    ``enabled: False`` (the default) is the status quo: preemptions call
    ``kill()`` and every in-flight request re-prefills elsewhere.  All
    other knobs are inert until enabled.
    """

    enabled: bool = False
    # flat override of the catalog's locality-tiered bandwidth (Gbit/s);
    # None means use the inter-zone table on the catalog
    bandwidth_gbps: Optional[float] = None
    compression: str = "none"          # "none" | "int8" (halves KV bytes)
    # sequences whose remaining work fits this budget (and the grace
    # window) finish in place instead of moving
    drain_threshold_s: float = 30.0
    # sequences with fewer resident KV tokens than this re-prefill
    # (moving a near-empty cache is not worth the setup cost)
    migrate_threshold_tokens: int = 1
    # per-transfer connection setup / control-plane latency
    link_latency_s: float = 0.05

    def __post_init__(self) -> None:
        if self.compression not in COMPRESSION_MODES:
            raise ValueError(
                f"migration.compression must be one of {COMPRESSION_MODES},"
                f" got {self.compression!r}"
            )
        if self.bandwidth_gbps is not None and self.bandwidth_gbps <= 0:
            raise ValueError(
                f"migration.bandwidth_gbps must be positive, "
                f"got {self.bandwidth_gbps}"
            )
        if self.drain_threshold_s < 0:
            raise ValueError(
                f"migration.drain_threshold_s must be >= 0, "
                f"got {self.drain_threshold_s}"
            )
        if self.migrate_threshold_tokens < 0:
            raise ValueError(
                f"migration.migrate_threshold_tokens must be >= 0, "
                f"got {self.migrate_threshold_tokens}"
            )
        if self.link_latency_s < 0:
            raise ValueError(
                f"migration.link_latency_s must be >= 0, "
                f"got {self.link_latency_s}"
            )

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"enabled": self.enabled}
        if self.bandwidth_gbps is not None:
            out["bandwidth_gbps"] = self.bandwidth_gbps
        out["compression"] = self.compression
        out["drain_threshold_s"] = self.drain_threshold_s
        out["migrate_threshold_tokens"] = self.migrate_threshold_tokens
        out["link_latency_s"] = self.link_latency_s
        return out

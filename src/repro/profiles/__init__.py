"""Kernel step-time profiles: measured grounding for serving latencies.

The serving layer's roofline latency model prices every request from two
efficiency fractions (prefill MFU, decode MBU).  This package measures
them on the repo's own Pallas kernels — per model config, per target
instance type — and persists versioned JSON step-time tables under
``artifacts/profiles/`` that ``ProfiledLatencyModel`` loads when a
``ServiceSpec`` opts in with ``latency: {source: profile}``.

* ``schema``   — the versioned artifact contract (``ProfileEntry`` /
  ``ProfileTable`` / ``load_profiles``),
* ``profiler`` — kernel micro-benchmarks (interpret on CPU, compiled on
  TPU),
* ``run``      — the ``python -m repro.profiles.run`` CLI.
"""

from repro.profiles.profiler import profile_model, profile_models
from repro.profiles.schema import (
    DEFAULT_PROFILE_DIR,
    SCHEMA_VERSION,
    ProfileEntry,
    ProfileSchemaError,
    ProfileTable,
    load_profiles,
)

__all__ = [
    "DEFAULT_PROFILE_DIR",
    "SCHEMA_VERSION",
    "ProfileEntry",
    "ProfileSchemaError",
    "ProfileTable",
    "load_profiles",
    "profile_model",
    "profile_models",
]

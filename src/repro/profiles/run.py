"""CLI: generate step-time profile artifacts.

    PYTHONPATH=src python -m repro.profiles.run \
        --models llama3.2-1b --itype v5e-8 \
        --out artifacts/profiles/cpu-interpret.json

With ``--out`` pointing at an existing table the new entries merge in
(re-profiles supersede old rows; other rows survive), so one artifact can
accumulate the full model × accelerator matrix across runs.  The default
output name encodes provenance: ``artifacts/profiles/<backend>-<mode>.json``.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

from repro.cluster.catalog import default_catalog
from repro.configs import ARCH_IDS
from repro.profiles.profiler import profile_models
from repro.profiles.schema import (
    DEFAULT_PROFILE_DIR,
    ProfileSchemaError,
    ProfileTable,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.profiles.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--models", nargs="+", default=["llama3.2-1b"],
        help=f"arch ids to profile, or 'all' (available: {ARCH_IDS})",
    )
    ap.add_argument(
        "--itype", default="v5e-8",
        help="catalog instance type whose peaks normalize mfu/mbu",
    )
    ap.add_argument("--out", default=None,
                    help="output JSON path (merged if it exists); "
                    f"default {DEFAULT_PROFILE_DIR}/<backend>-<mode>.json")
    ap.add_argument("--prefill-tokens", type=int, default=256)
    ap.add_argument("--cache-tokens", type=int, default=512)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument(
        "--compiled", action="store_true",
        help="force compiled (non-interpret) kernels; default picks "
        "interpret off-TPU",
    )
    args = ap.parse_args(argv)

    models = list(args.models)
    if models == ["all"]:
        models = list(ARCH_IDS)
    unknown = [m for m in models if m not in ARCH_IDS]
    if unknown:
        ap.error(f"unknown models {unknown}; available: {ARCH_IDS}")

    catalog = default_catalog()
    try:
        itype = catalog.instance_type(args.itype)
    except KeyError:
        known = sorted(t.name for t in catalog.instance_types)
        ap.error(f"unknown --itype {args.itype!r}; catalog has {known}")

    interpret = False if args.compiled else None
    table = profile_models(
        models, itype,
        prefill_tokens=args.prefill_tokens,
        cache_tokens=args.cache_tokens,
        batch=args.batch,
        repeats=args.repeats,
        interpret=interpret,
    )

    out = args.out
    if out is None:
        out = os.path.join(
            DEFAULT_PROFILE_DIR, f"{table.backend}-{table.mode}.json"
        )
    if os.path.exists(out):
        try:
            prior = ProfileTable.load(out)
        except ProfileSchemaError as e:
            # never clobber rows we cannot read — measurements are not
            # reproducible for free on another machine
            print(
                f"error: existing table {out} cannot be merged ({e}); "
                "pass a fresh --out path or fix/remove the file",
                file=sys.stderr,
            )
            return 1
        prior.merge(table)
        table.entries = prior.entries
    table.jax_version = jax.__version__
    table.save(out)

    for key, e in sorted(table.entries.items()):
        print(
            f"{key:40s} prefill {e.prefill_flops_per_s:10.3e} FLOP/s "
            f"(mfu {e.mfu_prefill:8.2e})  decode "
            f"{e.decode_bytes_per_s:10.3e} B/s (mbu {e.mbu_decode:8.2e})"
        )
    print(f"wrote {out} ({len(table.entries)} entries, "
          f"{table.backend}/{table.mode})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

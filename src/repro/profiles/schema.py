"""Versioned step-time tables: the on-disk contract of ``repro.profiles``.

A profile artifact is one JSON document under ``artifacts/profiles/``:

    {
      "schema_version": 1,
      "jax_version": "0.4.37",
      "backend": "cpu",
      "mode": "interpret",
      "entries": {
        "llama3.2-1b|TPUv5e": {
          "model": "llama3.2-1b",
          "accelerator": "TPUv5e",
          "backend": "cpu",
          "mode": "interpret",
          "jax_version": "0.4.37",
          "prefill_tokens": 256,
          "prefill_flops": 1.7e9,
          "prefill_wall_s": 0.41,
          "decode_cache_tokens": 512,
          "decode_steps": 4,
          "decode_bytes": 2.1e6,
          "decode_wall_s": 0.012,
          "mfu_prefill": 2.6e-9,
          "mbu_decode": 2.1e-8
        },
        ...
      }
    }

Entries are keyed ``"<model>|<accelerator>"`` — the pair the serving
layer resolves a replica's latency model by.  ``mfu_prefill`` /
``mbu_decode`` are the measured kernel efficiencies *relative to the
target accelerator's peaks* (catalog ``peak_bf16_tflops`` ×
``hbm_bytes_per_s``): on a TPU backend in compiled mode these are real
utilization numbers; on CPU in interpret mode they validate the plumbing
end-to-end but are (documented) orders of magnitude below hardware
truth, which is why ``latency: {source: profile}`` is opt-in per spec.

``schema_version`` gates loading: a major-version bump means the field
contract changed and old readers must refuse rather than misprice runs.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Mapping, Optional

__all__ = [
    "SCHEMA_VERSION",
    "DEFAULT_PROFILE_DIR",
    "ProfileEntry",
    "ProfileTable",
    "ProfileSchemaError",
    "load_profiles",
]

SCHEMA_VERSION = 1
DEFAULT_PROFILE_DIR = os.path.join("artifacts", "profiles")


class ProfileSchemaError(ValueError):
    """A profile artifact is malformed or from an incompatible version."""


def _entry_key(model: str, accelerator: str) -> str:
    return f"{model}|{accelerator}"


@dataclasses.dataclass(frozen=True)
class ProfileEntry:
    """One measured (model × accelerator) step-time row."""

    model: str
    accelerator: str            # catalog accelerator name, e.g. "TPUv5e"
    backend: str                # jax backend the measurement ran on
    mode: str                   # "interpret" | "compiled"
    # prompt length of the attention measurement; selective-scan kernels
    # are always timed over one chunk (the unit a model repeats across a
    # prompt), so for attention-free archs this is that chunk length
    prefill_tokens: int
    prefill_flops: float        # FLOPs issued by the timed prefill kernels
    prefill_wall_s: float
    decode_cache_tokens: int    # KV/state occupancy during decode steps
    decode_steps: int
    decode_bytes: float         # HBM bytes one decode step moves
    decode_wall_s: float        # wall seconds per decode step
    mfu_prefill: float          # achieved / instance peak FLOPs
    mbu_decode: float           # achieved / instance peak HBM bytes/s
    # per-entry provenance: tables merge across runs, so the jax that
    # measured THIS row must not be inferred from table-level fields
    jax_version: str = ""

    @property
    def key(self) -> str:
        return _entry_key(self.model, self.accelerator)

    @property
    def prefill_flops_per_s(self) -> float:
        return self.prefill_flops / self.prefill_wall_s

    @property
    def decode_bytes_per_s(self) -> float:
        return self.decode_bytes / self.decode_wall_s

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "ProfileEntry":
        fields = dataclasses.fields(ProfileEntry)
        required = {
            f.name for f in fields if f.default is dataclasses.MISSING
        }
        missing = required - set(d)
        if missing:
            raise ProfileSchemaError(
                f"profile entry missing fields {sorted(missing)}"
            )
        names = {f.name for f in fields}
        return ProfileEntry(**{k: d[k] for k in names if k in d})


@dataclasses.dataclass
class ProfileTable:
    """A set of entries plus run-level provenance.

    Table-level ``jax_version``/``backend``/``mode`` describe the most
    recent run that wrote the file; tables merge across runs, so the
    authoritative provenance of each row is the entry's own fields.
    """

    jax_version: str = ""
    backend: str = ""
    mode: str = ""
    entries: Dict[str, ProfileEntry] = dataclasses.field(
        default_factory=dict
    )
    schema_version: int = SCHEMA_VERSION

    def add(self, entry: ProfileEntry) -> None:
        self.entries[entry.key] = entry

    def lookup(
        self, model: str, accelerator: str
    ) -> Optional[ProfileEntry]:
        return self.entries.get(_entry_key(model, accelerator))

    def merge(self, other: "ProfileTable") -> None:
        """Later tables win on key collision (re-profiles supersede)."""
        self.entries.update(other.entries)

    # -- (de)serialization ---------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "jax_version": self.jax_version,
            "backend": self.backend,
            "mode": self.mode,
            "entries": {
                k: e.to_dict() for k, e in sorted(self.entries.items())
            },
        }

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "ProfileTable":
        version = d.get("schema_version")
        if version != SCHEMA_VERSION:
            raise ProfileSchemaError(
                f"profile schema_version {version!r} is not the supported "
                f"{SCHEMA_VERSION}; re-generate the table with "
                "`python -m repro.profiles.run`"
            )
        raw = d.get("entries", {})
        if not isinstance(raw, Mapping):
            raise ProfileSchemaError("profile 'entries' must be a mapping")
        table = ProfileTable(
            jax_version=str(d.get("jax_version", "")),
            backend=str(d.get("backend", "")),
            mode=str(d.get("mode", "")),
        )
        for key, ed in raw.items():
            entry = ProfileEntry.from_dict(ed)
            if entry.key != key:
                raise ProfileSchemaError(
                    f"profile entry keyed {key!r} describes {entry.key!r}"
                )
            table.add(entry)
        return table

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @staticmethod
    def load(path: str) -> "ProfileTable":
        try:
            with open(path) as f:
                d = json.load(f)
        except OSError as e:
            raise ProfileSchemaError(
                f"cannot read profile table {path!r}: {e}"
            ) from e
        except json.JSONDecodeError as e:
            raise ProfileSchemaError(
                f"profile table {path!r} is not valid JSON: {e}"
            ) from e
        return ProfileTable.from_dict(d)


def load_profiles(path: str, *, missing_ok: bool = False) -> ProfileTable:
    """Load a profile table from a JSON file or a directory of them.

    Directory entries merge in sorted filename order (later files win on
    key collisions).  ``missing_ok`` returns an empty table for a path
    that does not exist — the serving layer's fallback-to-roofline path.
    """
    if not os.path.exists(path):
        if missing_ok:
            return ProfileTable()
        raise ProfileSchemaError(f"no profile table at {path!r}")
    if os.path.isdir(path):
        merged = ProfileTable()
        names = sorted(
            n for n in os.listdir(path) if n.endswith(".json")
        )
        for name in names:
            merged.merge(ProfileTable.load(os.path.join(path, name)))
        return merged
    return ProfileTable.load(path)

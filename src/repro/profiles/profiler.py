"""Micro-benchmark the Pallas kernels into per-model step-time tables.

For each model config the profiler times the kernels its architecture
actually runs per serving step — prefill (flash attention / selective
scan / MoE grouped matmul at config shapes) and per-token decode (flash
decode over a populated KV cache; a one-step scan for state-space
archs) — and converts the measurements into the two numbers the roofline
latency model consumes:

* ``mfu_prefill`` — achieved prefill FLOP/s over the target instance's
  peak (``accel_count × peak_bf16_tflops``),
* ``mbu_decode``  — achieved decode HBM bytes/s over the instance's peak
  bandwidth (``accel_count × hbm_bytes_per_s``).

On a TPU backend with ``interpret=False`` these are real utilization
measurements.  On CPU (interpret mode — the kernel body runs in Python)
the pipeline is identical but the efficiencies are orders of magnitude
below hardware truth; such tables validate the profile→latency plumbing
end-to-end and are tagged ``mode: interpret`` so nobody mistakes them
for silicon numbers.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cluster.catalog import InstanceType
from repro.configs import get_config
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.profiles.schema import ProfileEntry, ProfileTable

__all__ = ["profile_model", "profile_models"]

# keep interpret-mode scan chunks bounded: the recurrence is sequential
# in time, so one chunk is the natural (and repeated) unit of work
_SCAN_CHUNK = 64
# MoE prefill capacity per expert (tokens routed to one expert)
_MOE_CAPACITY = 128


def _time_call(
    fn: Callable[[], jax.Array], repeats: int
) -> float:
    """Best-of-``repeats`` wall seconds, after one untimed warmup call
    (tracing/compilation must not be billed as step time)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _rnd(seed: int, shape: Tuple[int, ...], dtype) -> jax.Array:
    return jax.random.normal(
        jax.random.PRNGKey(seed), shape, jnp.float32
    ).astype(dtype)


def _prefill_cases(
    cfg: ModelConfig, tokens: int, batch: int, interpret: bool
) -> List[Tuple[Callable[[], jax.Array], float]]:
    """(thunk, flops) per kernel the arch runs during prefill."""
    cases: List[Tuple[Callable[[], jax.Array], float]] = []
    if cfg.num_heads:
        B, S = batch, tokens
        H, Kv, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = _rnd(1, (B, S, H, D), jnp.bfloat16)
        k = _rnd(2, (B, S, Kv, D), jnp.bfloat16)
        v = _rnd(3, (B, S, Kv, D), jnp.bfloat16)
        # QK^T + PV are 2·S²·D MACs each per head; causal halves the
        # live blocks
        flops = 4.0 * B * H * S * S * D * 0.5
        cases.append((
            lambda: ops.flash_attention(
                q, k, v, causal=True, interpret=interpret
            ),
            flops,
        ))
    if cfg.family in ("ssm", "hybrid"):
        B, Q = batch, min(tokens, _SCAN_CHUNK)
        C, N = cfg.d_inner, cfg.ssm_state
        a = jax.nn.sigmoid(_rnd(4, (B, Q, C, N), jnp.float32))
        b = _rnd(5, (B, Q, C, N), jnp.float32) * 0.1
        h0 = jnp.zeros((B, C, N), jnp.float32)
        # h = a·h + b: one mul + one add per (C, N) element per step
        flops = 2.0 * B * Q * C * N
        cases.append((
            lambda: ops.selective_scan(a, b, h0, interpret=interpret),
            flops,
        ))
    if cfg.is_moe:
        E, C = cfg.num_experts, _MOE_CAPACITY
        D, F = cfg.d_model, cfg.expert_d_ff
        x = _rnd(6, (E, C, D), jnp.bfloat16)
        w = _rnd(7, (E, D, F), jnp.bfloat16)
        flops = 2.0 * E * C * D * F
        cases.append((
            lambda: ops.moe_gmm(x, w, interpret=interpret),
            flops,
        ))
    if not cases:
        raise ValueError(
            f"model family {cfg.family!r} maps to no profiled kernel"
        )
    return cases


def _decode_cases(
    cfg: ModelConfig, cache_tokens: int, batch: int, interpret: bool
) -> List[Tuple[Callable[[], jax.Array], float]]:
    """(thunk, bytes-moved) per kernel one decode step runs."""
    cases: List[Tuple[Callable[[], jax.Array], float]] = []
    if cfg.num_heads:
        B, S = batch, cache_tokens
        H, Kv, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = _rnd(8, (B, 1, H, D), jnp.bfloat16)
        kc = _rnd(9, (B, S, Kv, D), jnp.bfloat16)
        vc = _rnd(10, (B, S, Kv, D), jnp.bfloat16)
        valid = jnp.ones((B, S), jnp.int8)
        # decode attention streams the whole K and V cache once
        nbytes = 2.0 * B * Kv * S * D * kc.dtype.itemsize
        cases.append((
            lambda: ops.flash_decode(
                q, kc, vc, kv_valid=valid, interpret=interpret
            ),
            nbytes,
        ))
    if cfg.family in ("ssm", "hybrid"):
        B = batch
        C, N = cfg.d_inner, cfg.ssm_state
        a = jax.nn.sigmoid(_rnd(11, (B, 1, C, N), jnp.float32))
        b = _rnd(12, (B, 1, C, N), jnp.float32) * 0.1
        h0 = _rnd(13, (B, C, N), jnp.float32)
        # read a, b, h; write h' — all fp32
        nbytes = 4.0 * B * C * N * 4
        cases.append((
            lambda: ops.selective_scan(a, b, h0, interpret=interpret),
            nbytes,
        ))
    if not cases:
        raise ValueError(
            f"model family {cfg.family!r} maps to no profiled kernel"
        )
    return cases


def profile_model(
    model_id: str,
    itype: InstanceType,
    *,
    prefill_tokens: int = 256,
    cache_tokens: int = 512,
    batch: int = 1,
    decode_steps: int = 4,
    repeats: int = 2,
    interpret: Optional[bool] = None,
) -> ProfileEntry:
    """Measure one (model × instance-accelerator) step-time row."""
    cfg = get_config(model_id)
    if interpret is None:
        # same rule the kernels apply when models call them (ops.py)
        interpret = ops._default_interpret()

    # attention kernels measure the full requested prompt; scan kernels
    # always measure one chunk (the unit the model repeats across a
    # prompt — see schema.ProfileEntry.prefill_tokens).  For attention-
    # free archs the chunk therefore IS the measured prompt length.
    measured_tokens = (
        prefill_tokens if cfg.num_heads
        else min(prefill_tokens, _SCAN_CHUNK)
    )

    prefill_wall = 0.0
    prefill_flops = 0.0
    for fn, flops in _prefill_cases(cfg, prefill_tokens, batch, interpret):
        prefill_wall += _time_call(fn, repeats)
        prefill_flops += flops

    decode_wall = 0.0
    decode_bytes = 0.0
    for fn, nbytes in _decode_cases(cfg, cache_tokens, batch, interpret):
        decode_wall += _time_call(fn, max(repeats, decode_steps))
        decode_bytes += nbytes

    peak_flops = itype.accel_count * itype.peak_bf16_tflops * 1e12
    peak_bytes = itype.accel_count * itype.hbm_bytes_per_s
    return ProfileEntry(
        model=model_id,
        accelerator=itype.accelerator,
        backend=jax.default_backend(),
        mode="interpret" if interpret else "compiled",
        jax_version=jax.__version__,
        prefill_tokens=measured_tokens,
        prefill_flops=prefill_flops,
        prefill_wall_s=prefill_wall,
        decode_cache_tokens=cache_tokens,
        decode_steps=decode_steps,
        decode_bytes=decode_bytes,
        decode_wall_s=decode_wall,
        mfu_prefill=(prefill_flops / prefill_wall) / peak_flops,
        mbu_decode=(decode_bytes / decode_wall) / peak_bytes,
    )


def profile_models(
    model_ids,
    itype: InstanceType,
    *,
    table: Optional[ProfileTable] = None,
    **kwargs,
) -> ProfileTable:
    """Profile several models into one table (merging into ``table``)."""
    out = table if table is not None else ProfileTable()
    out.jax_version = jax.__version__
    for model_id in model_ids:
        entry = profile_model(model_id, itype, **kwargs)
        out.add(entry)
        out.backend = entry.backend
        out.mode = entry.mode
    return out

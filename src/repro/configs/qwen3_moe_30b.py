"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4), per-expert
d_ff=768, vocab=151936, 128 experts top-8, QK-norm, head_dim=128 != d/H.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151_936,
        rope_theta=1_000_000.0,
        qk_norm=True,
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        tie_embeddings=False,
        act="silu",
    )

"""Assigned architecture configs + shape suite.

``get_config(arch_id)`` returns the exact published ModelConfig;
``get_smoke_config(arch_id)`` the reduced same-family config used by the
per-arch smoke tests; ``SHAPES`` the four assigned input-shape cells.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec, cells_for

ARCH_IDS: List[str] = [
    "paligemma-3b",
    "falcon-mamba-7b",
    "command-r-35b",
    "h2o-danube3-4b",
    "qwen2.5-3b",
    "llama3.2-1b",
    "whisper-medium",
    "phi3.5-moe-42b",
    "qwen3-moe-30b",
    "zamba2-7b",
]

_MODULES: Dict[str, str] = {
    "paligemma-3b": "paligemma_3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "command-r-35b": "command_r_35b",
    "h2o-danube3-4b": "h2o_danube3_4b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-medium": "whisper_medium",
    "phi3.5-moe-42b": "phi3_5_moe_42b",
    "qwen3-moe-30b": "qwen3_moe_30b",
    "zamba2-7b": "zamba2_7b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    if hasattr(mod, "smoke_config"):
        return mod.smoke_config()
    return get_config(arch_id).scaled()


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "cells_for",
    "get_config",
    "get_smoke_config",
]

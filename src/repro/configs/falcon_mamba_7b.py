"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free, vocab=65024,
ssm_state=16 — Mamba-1 architecture.  [arXiv:2410.05355]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=65_024,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        mamba_version=1,
        tie_embeddings=False,
        act="silu",
    )

"""whisper-medium [audio]: 24L(enc) + 24L(dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 — enc-dec; conv frontend is a STUB (input_specs()
provides precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51_865,
        rope=False,
        qkv_bias=True,
        encoder_layers=24,
        cross_attention=True,
        frontend="audio-stub",
        frontend_seq=1500,
        tie_embeddings=True,
        act="gelu",
        norm_eps=1e-5,
    )

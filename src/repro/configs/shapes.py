"""The four assigned input-shape cells (LM transformer shapes).

    train_4k     seq 4,096   × global_batch 256   -> train_step
    prefill_32k  seq 32,768  × global_batch 32    -> prefill_step
    decode_32k   seq 32,768  × global_batch 128   -> serve_step (1 new token,
                                                    KV cache of seq_len)
    long_500k    seq 524,288 × global_batch 1     -> serve_step; requires
                                                    sub-quadratic attention

``cells_for(cfg)`` applies the assignment's skip rules: ``long_500k`` only
for SSM / hybrid / sliding-window archs (pure full-attention archs record
the skip), decode shapes skipped for encoder-only archs (none assigned).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """[(shape_name, status)] — status 'run' or a skip reason."""
    out: List[Tuple[str, str]] = []
    for name, spec in SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            out.append((name, "skip: quadratic full attention"))
            continue
        out.append((name, "run"))
    return out

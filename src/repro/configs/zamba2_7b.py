"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba-2 (SSD) backbone + SHARED attention
blocks.  [arXiv:2411.15242]

Layer accounting (DESIGN.md §Arch-applicability): 81 layers =
3 prelude mamba2 + 13 super-blocks x (1 shared-attn + 5 mamba2)
= 68 mamba2 layers + 13 applications of the single shared attention block.
The real model's per-application LoRA adapters are simplified to plain
shared-weight application (documented deviation).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14_336,
        vocab_size=32_000,
        ssm_state=64,
        ssm_conv=4,
        ssm_expand=2,
        mamba_version=2,
        ssm_head_dim=64,
        hybrid_attn_every=6,
        tie_embeddings=True,
        act="silu",
    )

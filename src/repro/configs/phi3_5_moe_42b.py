"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6_400,
        vocab_size=32_064,
        rope_theta=10_000.0,
        num_experts=16,
        experts_per_token=2,
        tie_embeddings=False,
        act="silu",
        norm_eps=1e-5,
    )

"""h2o-danube3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube3-4b",
        family="dense",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        head_dim=120,
        d_ff=10_240,
        vocab_size=32_000,
        rope_theta=10_000.0,
        sliding_window=4_096,
        tie_embeddings=True,
        act="silu",
    )

"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend (STUB: input_specs() provides precomputed
patch embeddings) + Gemma backbone with prefix-LM masking over the image
prefix.  [arXiv:2407.07726]"""

from repro.models.config import ModelConfig

# SigLIP-So400m/14 @ 224px -> 256 patch tokens
NUM_PATCHES = 256


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16_384,
        vocab_size=257_216,
        rope_theta=10_000.0,
        prefix_lm=True,
        frontend="vision-stub",
        frontend_seq=NUM_PATCHES,
        tie_embeddings=True,
        act="gelu",
        gated_mlp=True,   # Gemma: GeGLU
    )

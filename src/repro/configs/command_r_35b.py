"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attn+FFN block, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22_528,
        vocab_size=256_000,
        rope_theta=8_000_000.0,
        parallel_block=True,
        tie_embeddings=True,
        act="silu",
        norm_eps=1e-5,
    )

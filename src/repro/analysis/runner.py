"""Run selected passes over a repository and assemble the report."""

from __future__ import annotations

from typing import List, Optional, Sequence

import repro.analysis.passes  # noqa: F401  (registers all rules)
from repro.analysis.core import RULES, Finding, RepoContext, rule_ids
from repro.analysis.exemptions import Exemption, load_exemptions, match
from repro.analysis.report import AnalysisReport, ReportedFinding

__all__ = ["run_analysis"]

PARSE_ERROR_RULE = "parse-error"


def run_analysis(
    root: str,
    rules: Optional[Sequence[str]] = None,
    exemptions_path: Optional[str] = None,
) -> AnalysisReport:
    """Run ``rules`` (default: all registered) against the tree at
    ``root`` and return the exemption-annotated report.

    Unknown rule ids raise ``KeyError`` — a CI job asking for a rule
    that does not exist must fail loudly, not silently check nothing.
    """
    selected = list(rules) if rules is not None else rule_ids()
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise KeyError(
            f"unknown rule(s) {unknown}; registered: {rule_ids()}"
        )

    ctx = RepoContext(root)
    exemptions = load_exemptions(
        ctx, exemptions_path,
        known_rules=list(RULES) + [PARSE_ERROR_RULE],
    )

    findings: List[Finding] = []
    for rid in selected:
        findings.extend(RULES[rid].run(ctx))
    # files that failed to parse shrank every pass's scope: surface them
    for path, (line, msg) in sorted(ctx.parse_errors.items()):
        findings.append(Finding(
            rule=PARSE_ERROR_RULE, path=path, line=line,
            message=f"file failed to parse ({msg}); every pass skipped it",
            hint="fix the syntax error",
        ))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.symbol,
                                 f.message))

    covered = match(findings, exemptions)
    reported = [
        ReportedFinding(
            finding=f,
            exempted=i in covered,
            justification=covered[i].justification if i in covered else "",
        )
        for i, f in enumerate(findings)
    ]
    used = {id(covered[i]) for i in covered}
    unused = [e for e in exemptions if id(e) not in used]

    n_scanned = len(
        {p for p, s in ctx._source.items() if s is not None}
    )
    return AnalysisReport(
        rules=selected,
        n_files_scanned=n_scanned,
        findings=reported,
        unused_exemptions=unused,
    )

"""Exemption file: the only sanctioned way to silence a finding.

An exemption is a JSON entry that names the rule, the file, optionally
the symbol, and — mandatorily — a one-line justification.  The checker
refuses malformed files loudly: an exemption naming an unknown rule or a
path that does not exist is itself an error (stale exemptions must not
outlive the code they excused), and an empty justification is rejected
(the justification IS the review artifact).

    {
      "schema": 1,
      "exemptions": [
        {
          "rule": "determinism",
          "path": "src/repro/experiments/suite.py",
          "symbol": "_prune_worker_tapes",
          "justification": "set difference drives cache eviction only; "
                           "iteration order never reaches any output"
        }
      ]
    }

``symbol`` empty/omitted matches every finding of that rule in that
file; prefer a symbol so unrelated regressions in the same file still
fail the gate.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import Finding, RepoContext

__all__ = ["Exemption", "ExemptionError", "load_exemptions", "match"]

DEFAULT_EXEMPTIONS_FILE = "analysis_exemptions.json"
EXEMPTIONS_SCHEMA = 1


class ExemptionError(ValueError):
    """The exemption file is malformed; the message names the entry."""


@dataclasses.dataclass(frozen=True)
class Exemption:
    rule: str
    path: str
    justification: str
    symbol: str = ""

    def matches(self, f: Finding) -> bool:
        return (
            f.rule == self.rule
            and f.path == self.path
            and (not self.symbol or f.symbol == self.symbol)
        )

    def to_dict(self) -> Dict[str, str]:
        out = {
            "rule": self.rule,
            "path": self.path,
            "justification": self.justification,
        }
        if self.symbol:
            out["symbol"] = self.symbol
        return out


def _entry_error(i: int, msg: str) -> ExemptionError:
    return ExemptionError(f"exemption entry #{i}: {msg}")


def load_exemptions(
    ctx: RepoContext, path: Optional[str] = None,
    known_rules: Optional[Sequence[str]] = None,
) -> List[Exemption]:
    """Load + validate the exemption file (missing file -> no exemptions).

    Validation is strict by design: unknown rule ids, paths that do not
    exist in the repo, and missing/empty justifications all raise
    :class:`ExemptionError` — an invalid exemption silently matching
    nothing would defeat the gate.
    """
    rel = path or DEFAULT_EXEMPTIONS_FILE
    src = ctx.source(rel)
    if src is None:
        if path is not None:
            raise ExemptionError(f"exemption file {rel!r} not found")
        return []
    try:
        doc = json.loads(src)
    except json.JSONDecodeError as e:
        raise ExemptionError(f"invalid JSON in {rel!r}: {e}") from e
    if not isinstance(doc, dict) or doc.get("schema") != EXEMPTIONS_SCHEMA:
        raise ExemptionError(
            f"{rel!r} must be an object with \"schema\": "
            f"{EXEMPTIONS_SCHEMA}"
        )
    entries = doc.get("exemptions", [])
    if not isinstance(entries, list):
        raise ExemptionError(f"{rel!r}: \"exemptions\" must be a list")
    rules = set(known_rules) if known_rules is not None else None
    out: List[Exemption] = []
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise _entry_error(i, f"must be an object, got {type(e).__name__}")
        unknown = set(e) - {"rule", "path", "symbol", "justification"}
        if unknown:
            raise _entry_error(i, f"unknown keys {sorted(unknown)}")
        rule = e.get("rule")
        if not isinstance(rule, str) or not rule:
            raise _entry_error(i, "\"rule\" must be a non-empty string")
        if rules is not None and rule not in rules:
            raise _entry_error(
                i, f"unknown rule {rule!r}; known rules: {sorted(rules)}"
            )
        p = e.get("path")
        if not isinstance(p, str) or not p:
            raise _entry_error(i, "\"path\" must be a non-empty string")
        if not ctx.exists(p):
            raise _entry_error(
                i, f"path {p!r} does not exist in the repository "
                "(stale exemption? remove or update it)"
            )
        just = e.get("justification")
        if not isinstance(just, str) or not just.strip():
            raise _entry_error(
                i, "\"justification\" is mandatory and must be a "
                "non-empty string"
            )
        symbol = e.get("symbol", "")
        if not isinstance(symbol, str):
            raise _entry_error(i, "\"symbol\" must be a string")
        out.append(
            Exemption(rule=rule, path=p, justification=just.strip(),
                      symbol=symbol)
        )
    return out


def match(
    findings: Sequence[Finding], exemptions: Sequence[Exemption]
) -> Dict[int, Exemption]:
    """Map finding index -> the exemption that covers it (first match)."""
    out: Dict[int, Exemption] = {}
    for i, f in enumerate(findings):
        for ex in exemptions:
            if ex.matches(f):
                out[i] = ex
                break
    return out

"""Lint passes. Importing this package registers every rule.

Each module calls :func:`repro.analysis.core.register_rule` at import
time; the runner only ever consults the registry, so adding a pass is
adding a module here and importing it below.
"""

from repro.analysis.passes import (  # noqa: F401
    determinism,
    engine_parity,
    silent_fallback,
    spec_drift,
    tracing,
)

__all__ = [
    "determinism",
    "engine_parity",
    "silent_fallback",
    "spec_drift",
    "tracing",
]

"""spec-drift: a spec field nobody loads or demonstrates is drift.

Every field on the spec dataclasses is user-facing surface: it appears
in YAML, flows through ``service/loader.py``, and is compiled by
``service/builder.py``.  A field that the loader/builder never mention
is dead config — it parses, validates, and then changes nothing, which
is worse than an error.  A field no example demonstrates is invisible
surface — users discover it only by reading the dataclass.

Checked for every ``@dataclass`` in ``src/repro/service/spec.py`` and
``src/repro/migration/config.py``:

* **handled** — the field name appears in ``service/loader.py`` or
  ``service/builder.py`` source (substring match on the identifier; the
  loader's generic ``_pick`` walks dataclass fields reflectively, so
  explicit mentions in either file count, as do f-string references
  like ``"sim.duration_hours"``).  For ``MigrationSpec`` the loader is
  ``migration/config.py`` itself (``from_mapping``).
* **demonstrated** — the field name appears as a YAML/JSON key in some
  file under ``examples/`` (``^\\s*#?\\s*name\\s*:`` per line, so a
  commented ``# bandwidth_gbps: 10.0`` showing the knob counts).

A field failing either check is a finding anchored at its declaration;
fields that are internal-only by design get an exemption entry whose
justification says where they are exercised instead.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Tuple

from repro.analysis.astutil import dataclass_fields, is_dataclass_def
from repro.analysis.core import Finding, RepoContext, register_rule

RULE = "spec-drift"

#: spec module -> the loader/builder sources that must mention its fields
SPEC_SOURCES: Dict[str, Tuple[str, ...]] = {
    "src/repro/service/spec.py": (
        "src/repro/service/loader.py",
        "src/repro/service/builder.py",
    ),
    "src/repro/migration/config.py": (
        "src/repro/migration/config.py",
        "src/repro/service/builder.py",
    ),
}

EXAMPLES_DIR = "examples"
_EXAMPLE_SUFFIXES = (".yaml", ".yml", ".json")


def _example_keys(ctx: RepoContext) -> set:
    """Every key-looking token in the example files, commented or not."""
    keys: set = set()
    key_re = re.compile(r"^\s*#?\s*(?:-\s+)?([A-Za-z_][A-Za-z0-9_]*)\s*:")
    for path in ctx.files(EXAMPLES_DIR, _EXAMPLE_SUFFIXES):
        src = ctx.source(path)
        if src is None:
            continue
        for line in src.splitlines():
            m = key_re.match(line)
            if m:
                keys.add(m.group(1))
        # JSON keys: "name": ...
        for m in re.finditer(r'"([A-Za-z_][A-Za-z0-9_]*)"\s*:', src):
            keys.add(m.group(1))
    return keys


def _ident_mentioned(name: str, sources: List[str]) -> bool:
    pat = re.compile(rf"\b{re.escape(name)}\b")
    return any(pat.search(s) for s in sources)


@register_rule(
    RULE,
    "every spec dataclass field must be handled by the loader/builder "
    "and demonstrated (possibly commented) in an example file",
)
def run(ctx: RepoContext) -> List[Finding]:
    example_keys = _example_keys(ctx)
    has_examples = bool(ctx.files(EXAMPLES_DIR, _EXAMPLE_SUFFIXES))
    findings: List[Finding] = []
    for spec_path, handler_paths in SPEC_SOURCES.items():
        tree = ctx.tree(spec_path)
        if tree is None:
            continue
        handler_srcs = [
            s for p in handler_paths
            for s in [ctx.source(p)] if s is not None
        ]
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not is_dataclass_def(node):
                continue
            for field in dataclass_fields(node):
                name = field.target.id  # type: ignore[union-attr]
                if name.startswith("_"):
                    continue
                symbol = f"{node.name}.{name}"
                if handler_srcs and not _ident_mentioned(
                    name, handler_srcs
                ):
                    findings.append(Finding(
                        rule=RULE, path=spec_path, line=field.lineno,
                        symbol=symbol,
                        message=f"{symbol} is declared but never "
                                "mentioned by its loader/builder — dead "
                                "config that parses and then changes "
                                "nothing",
                        hint="wire the field through the loader/builder "
                             "or delete it",
                    ))
                if has_examples and name not in example_keys:
                    findings.append(Finding(
                        rule=RULE, path=spec_path, line=field.lineno,
                        symbol=symbol,
                        message=f"{symbol} never appears as a key in any "
                                "examples/ file — undemonstrated user "
                                "surface",
                        hint="add the knob (a commented line with its "
                             "default is enough) to an example YAML, or "
                             "exempt it with a pointer to where it is "
                             "exercised",
                    ))
    findings.sort(key=lambda f: (f.path, f.line, f.symbol, f.message))
    return findings

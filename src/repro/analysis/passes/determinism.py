"""determinism: keep nondeterminism out of persisted keys and decisions.

Encodes the tape-key bug class (PR 7): ``json.dumps(..., default=repr)``
leaked ``<object at 0x7f...>`` addresses into resume-tape keys, so a
restarted sweep never matched its own tape.  The checkable residue:

* ``repr()`` / ``id()`` / ``hash()`` / ``default=repr`` feeding
  ``json.dumps`` or key-building helpers — flagged anywhere in the
  scanned packages (addresses and PYTHONHASHSEED-salted hashes are
  process-local by construction);
* iterating a ``set`` (or set difference/union) directly in a ``for``
  or comprehension — order is hash-salted per process;
* unseeded RNG construction: ``np.random.default_rng()`` with no
  argument, bare ``random.random()``/``random.randint``/etc. module
  calls, ``np.random.<dist>`` module-level draws;
* wall-clock (``time.time``, ``datetime.now``, ``datetime.utcnow``,
  ``time.time_ns``) inside the deterministic core packages — replay and
  goldens require simulated clocks there.  ``perf_counter`` is fine: it
  measures, it never decides.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from repro.analysis.astutil import call_name, dotted, walk_calls
from repro.analysis.core import Finding, RepoContext, register_rule

RULE = "determinism"

#: packages that must stay deterministic end to end
SCAN_DIRS: Tuple[str, ...] = (
    "src/repro/serving",
    "src/repro/cluster",
    "src/repro/experiments",
    "src/repro/core",
    "src/repro/migration",
)

_WALL_CLOCK = {
    "time.time",
    "time.time_ns",
    "datetime.now",
    "datetime.utcnow",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
}

_UNSEEDED_RANDOM = {
    "random.random", "random.randint", "random.uniform", "random.choice",
    "random.shuffle", "random.sample", "random.gauss", "random.randrange",
}

_NP_MODULE_DRAWS = {
    "np.random.rand", "np.random.randn", "np.random.randint",
    "np.random.random", "np.random.uniform", "np.random.choice",
    "np.random.permutation", "np.random.shuffle", "np.random.normal",
    "numpy.random.rand", "numpy.random.randn", "numpy.random.randint",
    "numpy.random.random", "numpy.random.uniform", "numpy.random.choice",
    "numpy.random.permutation", "numpy.random.shuffle",
    "numpy.random.normal",
}


def _is_set_expr(node: ast.AST) -> bool:
    """Syntactically a set: literal, ``set(...)`` call, or an operation
    over such (``set(a) - set(b)``, ``a | b`` where a side is a set)."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and call_name(node) in {
        "set", "frozenset"
    }:
        return True
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.Sub, ast.BitOr, ast.BitAnd, ast.BitXor)
    ):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _rng_findings(path: str, tree: ast.AST) -> List[Finding]:
    out: List[Finding] = []
    for call in walk_calls(tree):
        name = call_name(call) or ""
        if name in {"np.random.default_rng", "numpy.random.default_rng"}:
            if not call.args and not call.keywords:
                out.append(Finding(
                    rule=RULE, path=path, line=call.lineno,
                    symbol="default_rng",
                    message="np.random.default_rng() without a seed draws "
                            "from OS entropy — every run differs",
                    hint="thread an explicit seed (spec.seed) into the "
                         "generator",
                ))
        elif name in _UNSEEDED_RANDOM or name in _NP_MODULE_DRAWS:
            out.append(Finding(
                rule=RULE, path=path, line=call.lineno,
                symbol=name.split(".")[-1],
                message=f"{name}() uses the shared global RNG whose state "
                        "no spec seed controls",
                hint="use a seeded np.random.Generator / random.Random "
                     "instance owned by the component",
            ))
    return out


def _clock_findings(path: str, tree: ast.AST) -> List[Finding]:
    out: List[Finding] = []
    for call in walk_calls(tree):
        name = call_name(call) or ""
        if name in _WALL_CLOCK:
            out.append(Finding(
                rule=RULE, path=path, line=call.lineno,
                symbol=name,
                message=f"{name}() reads the wall clock inside the "
                        "deterministic core — replay and goldens need "
                        "simulated time",
                hint="take the current time from the simulation clock, or "
                     "use time.perf_counter() if this only measures "
                     "elapsed durations",
            ))
    return out


def _repr_findings(path: str, tree: ast.AST) -> List[Finding]:
    out: List[Finding] = []
    for call in walk_calls(tree):
        name = call_name(call) or ""
        if name.endswith("json.dumps") or name == "dumps" or (
            name.split(".")[-1] == "dumps"
        ):
            for kw in call.keywords:
                if kw.arg == "default" and dotted(kw.value) in {
                    "repr", "str(repr)", "id", "hash"
                }:
                    out.append(Finding(
                        rule=RULE, path=path, line=call.lineno,
                        symbol="json.dumps",
                        message="json.dumps(default=repr) leaks object "
                                "addresses into serialized output — keys "
                                "built from it never match across "
                                "processes",
                        hint="serialize an explicit stable projection of "
                             "the object instead of repr()",
                    ))
    # id()/hash()/repr() embedded in f-strings (persisted-key smell)
    for node in ast.walk(tree):
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    for call in walk_calls(v.value):
                        if call_name(call) in {"id", "repr", "hash"}:
                            out.append(Finding(
                                rule=RULE, path=path, line=node.lineno,
                                symbol=call_name(call) or "",
                                message=f"{call_name(call)}() interpolated "
                                        "into a string — object addresses "
                                        "and salted hashes are "
                                        "process-local, so any key or "
                                        "artifact built from this string "
                                        "is nondeterministic",
                                hint="build the key from stable fields "
                                     "(names, indices, spec values)",
                            ))
    return out


def _set_iter_findings(path: str, tree: ast.AST) -> List[Finding]:
    out: List[Finding] = []

    def flag(node: ast.AST, line: int) -> None:
        out.append(Finding(
            rule=RULE, path=path, line=line, symbol="set-iteration",
            message="iterating a set directly — element order is "
                    "hash-salted per process, so anything order-sensitive "
                    "downstream (lists, JSON, tapes) diverges between "
                    "runs",
            hint="wrap in sorted(...) or iterate the original ordered "
                 "container",
        ))

    for node in ast.walk(tree):
        if isinstance(node, ast.For) and _is_set_expr(node.iter):
            flag(node.iter, node.lineno)
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.SetComp,
                               ast.DictComp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    flag(gen.iter, node.lineno)
    return out


@register_rule(
    RULE,
    "no repr()/id()/hash()-derived keys, unseeded RNGs, wall-clock reads, "
    "or raw set iteration in the deterministic core packages",
)
def run(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for d in SCAN_DIRS:
        for path in ctx.py_files(d):
            tree = ctx.tree(path)
            if tree is None:
                continue
            findings += _repr_findings(path, tree)
            findings += _set_iter_findings(path, tree)
            findings += _rng_findings(path, tree)
            findings += _clock_findings(path, tree)
    findings.sort(key=lambda f: (f.path, f.line, f.symbol))
    return findings

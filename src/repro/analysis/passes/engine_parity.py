"""engine-parity: a spec field one engine honors, every engine must honor.

The RTT-timeout bug class (PR 7): ``ServingSimulator`` applied the
client-RTT term to timeout expiry while ``VectorizedServingEngine``
initially did not — both "supported" ``SimSpec.timeout_s`` yet made
different decisions from the same spec.  The cheap, statically checkable
proxy for that invariant: every ``SimSpec`` / ``ServingSpec`` /
``MigrationSpec`` field *consumed* (attribute read, keyword, parameter)
by one engine's file set must be consumed by all three, or the field
must be exempted with a justification naming the fallback contract.

File sets:

* ``legacy`` — ``serving/sim.py`` + ``serving/replica.py``;
* ``vector`` — ``serving/engine.py``;
* ``jax`` — ``serving/jaxengine/*``, which *inherits* the vector set
  (``JaxServingEngine`` subclasses ``VectorizedServingEngine``, so
  everything the vector engine consumes is consumed on the jax path);
* shared data-plane/migration modules (``serving/token/*``,
  ``migration/planner|runtime``) count for every engine — both engines
  drive the same token batches and migration runtime, and jax delegates
  token cells to the vector path.

Fields consumed by *no* engine are builder-resolved (horizon, seeds,
engine selection) and are the spec-drift pass's problem, not parity's.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.astutil import (
    consumed_names,
    dataclass_fields,
)
from repro.analysis.core import Finding, RepoContext, register_rule

RULE = "engine-parity"

#: spec classes whose fields engines consume directly (name -> source file)
SPEC_CLASSES: Dict[str, str] = {
    "SimSpec": "src/repro/service/spec.py",
    "ServingSpec": "src/repro/service/spec.py",
    "MigrationSpec": "src/repro/migration/config.py",
}

ENGINE_FILES: Dict[str, Tuple[str, ...]] = {
    "legacy": (
        "src/repro/serving/sim.py",
        "src/repro/serving/replica.py",
    ),
    "vector": ("src/repro/serving/engine.py",),
    "jax": (
        "src/repro/serving/jaxengine/engine.py",
        "src/repro/serving/jaxengine/kernel.py",
        "src/repro/serving/jaxengine/schedule.py",
    ),
}

#: engine -> engine whose consumption it inherits (subclass relationship)
ENGINE_INHERITS: Dict[str, str] = {"jax": "vector"}

#: modules shared by every engine's data plane
SHARED_FILES: Tuple[str, ...] = (
    "src/repro/serving/token/batch.py",
    "src/repro/serving/token/config.py",
    "src/repro/serving/token/metrics.py",
    "src/repro/serving/token/replica.py",
    "src/repro/migration/planner.py",
    "src/repro/migration/runtime.py",
)


def _spec_fields(ctx: RepoContext) -> Dict[str, List[Tuple[str, str, int]]]:
    """class name -> [(field, source path, line)] for the spec classes."""
    out: Dict[str, List[Tuple[str, str, int]]] = {}
    for cls_name, path in SPEC_CLASSES.items():
        tree = ctx.tree(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == cls_name:
                out[cls_name] = [
                    (f.target.id, path, f.lineno)  # type: ignore[union-attr]
                    for f in dataclass_fields(node)
                ]
                break
    return out


def _engine_consumption(ctx: RepoContext) -> Dict[str, Set[str]]:
    shared: Set[str] = set()
    for path in SHARED_FILES:
        tree = ctx.tree(path)
        if tree is not None:
            shared |= consumed_names(tree)
    consumed: Dict[str, Set[str]] = {}
    for engine, paths in ENGINE_FILES.items():
        names = set(shared)
        for path in paths:
            tree = ctx.tree(path)
            if tree is not None:
                names |= consumed_names(tree)
        consumed[engine] = names
    for engine, base in ENGINE_INHERITS.items():
        consumed[engine] |= consumed[base]
    return consumed


@register_rule(
    RULE,
    "spec fields consumed by one serving engine must be consumed by all "
    "engines (or carry an exemption naming the fallback contract)",
)
def run(ctx: RepoContext) -> List[Finding]:
    # engines whose files are entirely absent (fixture trees) drop out of
    # the comparison rather than reading as "consumes nothing"
    present = [
        e for e, paths in ENGINE_FILES.items()
        if any(ctx.tree(p) is not None for p in paths)
        or any(
            ctx.tree(p) is not None
            for p in ENGINE_FILES.get(ENGINE_INHERITS.get(e, ""), ())
        )
    ]
    if len(present) < 2:
        return []
    consumed = _engine_consumption(ctx)
    findings: List[Finding] = []
    for cls_name, fields in sorted(_spec_fields(ctx).items()):
        for field, path, line in fields:
            consumers = [e for e in present if field in consumed[e]]
            if not consumers or len(consumers) == len(present):
                continue
            missing = [e for e in present if e not in consumers]
            findings.append(Finding(
                rule=RULE,
                path=path,
                line=line,
                symbol=f"{cls_name}.{field}",
                message=(
                    f"{cls_name}.{field} is consumed by the "
                    f"{'/'.join(consumers)} engine"
                    f"{'s' if len(consumers) > 1 else ''} but not by "
                    f"{'/'.join(missing)} — engines must stay "
                    "decision-identical for every spec knob"
                ),
                hint=(
                    "consume the field on the missing engine path, or add "
                    "an analysis exemption whose justification names the "
                    "documented fallback (e.g. 'token cells delegate to "
                    "the vector data plane')"
                ),
            ))
    return findings

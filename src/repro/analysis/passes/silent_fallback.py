"""silent-fallback: a degraded path must leave a measurable trace.

Encodes the dropped-futures bug class (PR 7): ``Suite`` submitted sweep
cells to a pool and iterated ``as_completed`` over a *filtered* subset —
cells that raised were simply absent from the results, and nothing
counted them.  The pattern generalizes: the repo is full of deliberate
fallbacks (jax engine -> oracle replay, calibrated latency -> roofline,
pallas kernel -> interpret mode), and each one is fine *only if* the
degraded run is observable afterwards.

Flagged:

* an ``except`` handler that warns/logs and then falls through to a
  degraded return/assignment without touching any counter, metrics
  object, or structured record (heuristic: the handler body contains a
  ``warn``/``warning``/``log`` call but no assignment/aug-assignment/
  method call whose target name smells like telemetry — ``*count*``,
  ``*stats*``, ``*metric*``, ``*record*``, ``*fallback*``, ``*event*``,
  ``*registry*`` — the run-scoped ``repro.obs.registry`` counters
  qualify);
* a bare ``except:`` or ``except Exception:`` whose body is only
  ``pass``/``continue``/``return <const>`` — the error is swallowed with
  no trace at all (``raise`` / logging / telemetry in the body clears
  it);
* a log/warn call whose message literally announces a fallback
  (``"falling back to ..."``) inside a function that touches no
  telemetry name — announced degradations are exactly the ones sweeps
  must be able to count afterwards;
* ``concurrent.futures`` result collection that filters the future set
  before ``as_completed`` without a completeness check (an explicit
  ``raise`` or ``assert`` mentioning the expected count in the same
  function clears it).
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional, Tuple

from repro.analysis.astutil import call_name, walk_calls
from repro.analysis.core import Finding, RepoContext, register_rule

RULE = "silent-fallback"

SCAN_DIRS: Tuple[str, ...] = (
    "src/repro",
)

_LOG_CALL = re.compile(r"(^|\.)((warn(ing)?)|log|error|info|debug)$")
_TELEMETRY = re.compile(
    r"(count|stats|metric|record|fallback|event|telemetry|registry)", re.I
)
_FALLBACK_MSG = re.compile(r"fall(ing|s|en)?[\s_-]*back", re.I)


def _is_log_call(call: ast.Call) -> bool:
    name = call_name(call) or ""
    return bool(_LOG_CALL.search(name))


def _mentions_telemetry(node: ast.AST) -> bool:
    """Does any statement in the handler touch a telemetry-ish name?"""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)):
            name = sub.attr if isinstance(sub, ast.Attribute) else sub.id
            if _TELEMETRY.search(name):
                return True
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            # structured record payloads often carry the marker as a key
            if _TELEMETRY.search(sub.value):
                return True
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    for sub in ast.walk(handler):
        if isinstance(sub, ast.Raise):
            return True
    return False


def _handler_findings(path: str, tree: ast.AST) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Try):
            continue
        for handler in node.handlers:
            if _reraises(handler):
                continue
            body = handler.body
            warns = any(
                _is_log_call(c)
                for stmt in body for c in walk_calls(stmt)
            )
            # swallowed entirely: pass/continue/constant return, no log
            trivially_swallowed = (
                not warns
                and all(
                    isinstance(s, (ast.Pass, ast.Continue))
                    or (
                        isinstance(s, ast.Return)
                        and (
                            s.value is None
                            or isinstance(s.value, (ast.Constant, ast.Name))
                        )
                    )
                    for s in body
                )
            )
            if trivially_swallowed:
                out.append(Finding(
                    rule=RULE, path=path, line=handler.lineno,
                    symbol="swallowed-except",
                    message="exception swallowed with no log, counter, or "
                            "re-raise — a degraded path nobody can "
                            "observe",
                    hint="log the failure AND bump a fallback counter (or "
                         "append a structured record) before degrading",
                ))
                continue
            if warns and not _mentions_telemetry(handler):
                out.append(Finding(
                    rule=RULE, path=path, line=handler.lineno,
                    symbol="warn-only-fallback",
                    message="handler warns and falls back but records no "
                            "counter or structured event — warnings "
                            "scroll away; sweeps need a measurable "
                            "fallback signal",
                    hint="bump a run-scoped counter alongside the warning "
                         "(repro.obs.registry: get_registry().inc(...)) "
                         "or append a structured record",
                ))
    return out


def _warn_fallback_findings(path: str, tree: ast.AST) -> List[Finding]:
    """Announced fallbacks ('falling back to ...') with no counter."""
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # attribute nodes to the innermost function: walk skipping
        # nested defs
        own: List[ast.AST] = []

        def collect(n: ast.AST) -> None:
            for child in ast.iter_child_nodes(n):
                if isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    continue
                own.append(child)
                collect(child)
        collect(node)

        fallback_warns = []
        warn_node_ids = set()
        for sub in own:
            if not isinstance(sub, ast.Call) or not _is_log_call(sub):
                continue
            announces = any(
                isinstance(c, ast.Constant) and isinstance(c.value, str)
                and _FALLBACK_MSG.search(c.value)
                for c in ast.walk(sub)
            )
            if announces:
                fallback_warns.append(sub)
                warn_node_ids.update(id(s) for s in ast.walk(sub))
        if not fallback_warns:
            continue
        has_telemetry = any(
            isinstance(sub, (ast.Name, ast.Attribute))
            and id(sub) not in warn_node_ids
            and _TELEMETRY.search(
                sub.attr if isinstance(sub, ast.Attribute) else sub.id
            )
            for sub in own
        )
        if has_telemetry:
            continue
        for call in fallback_warns:
            out.append(Finding(
                rule=RULE, path=path, line=call.lineno,
                symbol=node.name,
                message=f"{node.name!r} announces a fallback in a warning "
                        "but records no counter or structured event — the "
                        "degraded run is invisible to sweeps and CI",
                hint="bump a run-scoped fallback counter next to the "
                     "warning (repro.obs.registry: "
                     "get_registry().inc(...))",
            ))
    return out


def _futures_findings(path: str, tree: ast.AST) -> List[Finding]:
    """Filtered as_completed without a completeness check."""
    src_has_futures = False
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for call in walk_calls(node):
            name = call_name(call) or ""
            if name.split(".")[-1] != "as_completed" or not call.args:
                continue
            src_has_futures = True
            arg = call.args[0]
            filtered = isinstance(arg, (ast.ListComp, ast.GeneratorExp)) \
                and any(gen.ifs for gen in arg.generators)
            if not filtered:
                continue
            guarded = any(
                isinstance(sub, (ast.Raise, ast.Assert))
                for sub in ast.walk(node)
            )
            if not guarded:
                out.append(Finding(
                    rule=RULE, path=path, line=call.lineno,
                    symbol=node.name,
                    message="as_completed over a filtered future set with "
                            "no completeness check — futures dropped by "
                            "the filter vanish without an error",
                    hint="after collection, compare len(results) to the "
                         "submitted count and raise on mismatch",
                ))
    del src_has_futures
    return out


@register_rule(
    RULE,
    "every warn-and-degrade path must emit a counter or structured "
    "record; no swallowed exceptions or silently dropped futures",
)
def run(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for d in SCAN_DIRS:
        for path in ctx.py_files(d):
            if path.startswith("src/repro/analysis/"):
                continue  # the checker does not lint itself
            tree = ctx.tree(path)
            if tree is None:
                continue
            findings += _handler_findings(path, tree)
            findings += _warn_fallback_findings(path, tree)
            findings += _futures_findings(path, tree)
    findings.sort(key=lambda f: (f.path, f.line, f.symbol))
    return findings

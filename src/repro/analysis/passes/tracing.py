"""tracing-hazard: Python-side effects inside traced JAX code.

Encodes the backend-detection bug class (PR 3): calling
``jax.default_backend()`` inside a jitted function returns the backend
captured at *trace* time and silently bakes it into the compiled
artifact; the interpret-mode fallback it guarded then never triggers on
CPU.  Same family: ``bool(tracer)`` / ``tracer.item()`` raise
``ConcretizationTypeError`` only on the first real trace, and 64-bit
literals inside kernel bodies down-cast silently unless ``enable_x64``
is managed explicitly.

Scope: ``src/repro/kernels/`` and ``src/repro/serving/jaxengine/``.
Traced bodies are discovered syntactically:

* functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)`` /
  ``@jit``;
* functions wrapped at assignment time (``f = jax.jit(g)``,
  ``f = functools.partial(jax.jit, ...)(g)``);
* kernel functions handed to ``pl.pallas_call`` / ``pallas_call``;
* function arguments of ``lax.scan`` / ``lax.while_loop`` /
  ``lax.fori_loop`` / ``lax.cond`` / ``jax.vmap``;
* plus a fix-point closure over module-local helpers called from any
  traced body (a hazard two calls deep still fires at trace time).

Hazards flagged inside traced bodies:

* ``jax.default_backend()`` / ``jax.devices()`` /
  ``jax.local_devices()`` — trace-time constants masquerading as
  runtime queries; hoist to the un-jitted wrapper and pass the result
  as a static argument;
* ``bool(x)`` / ``x.item()`` / ``float(x)`` / ``int(x)`` on
  non-literal operands — concretization errors under trace;
* ``np.float64`` / ``np.int64`` / dtype-string ``"float64"`` literals —
  silent down-cast unless the module manages ``enable_x64`` itself (a
  module that mentions ``enable_x64`` is trusted and skipped).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import FuncDef, call_name, dotted, walk_calls
from repro.analysis.core import Finding, RepoContext, register_rule

RULE = "tracing-hazard"

SCAN_DIRS: Tuple[str, ...] = (
    "src/repro/kernels",
    "src/repro/serving/jaxengine",
)

_JIT_NAMES = {"jax.jit", "jit"}
_PALLAS_NAMES = {"pl.pallas_call", "pallas_call", "jax.experimental.pallas.pallas_call"}
_TRACED_HOFS = {
    "lax.scan": 0, "jax.lax.scan": 0,
    "lax.while_loop": (0, 1), "jax.lax.while_loop": (0, 1),
    "lax.fori_loop": 2, "jax.lax.fori_loop": 2,
    "lax.cond": (1, 2), "jax.lax.cond": (1, 2),
    "jax.vmap": 0, "vmap": 0,
}
_BACKEND_QUERIES = {
    "jax.default_backend", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count",
}
_X64_NAMES = {
    "np.float64", "numpy.float64", "np.int64", "numpy.int64",
    "jnp.float64", "jnp.int64",
}


def _is_jit_decorator(dec: ast.expr) -> bool:
    name = dotted(dec)
    if name in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):
        cname = call_name(dec) or ""
        if cname in _JIT_NAMES:
            return True
        # functools.partial(jax.jit, static_argnames=...)
        if cname.split(".")[-1] == "partial" and dec.args:
            if dotted(dec.args[0]) in _JIT_NAMES:
                return True
    return False


def _func_ref_names(node: ast.expr) -> List[str]:
    """Local function names referenced by an argument expression."""
    out: List[str] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.append(sub.id)
    return out


def _collect_traced_roots(tree: ast.AST) -> Set[str]:
    """Names of module-level/local functions whose bodies are traced."""
    roots: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                roots.add(node.name)
        elif isinstance(node, ast.Call):
            cname = call_name(node) or ""
            if cname in _JIT_NAMES and node.args:
                name = dotted(node.args[0])
                if name:
                    roots.add(name.split(".")[-1])
            elif cname.split(".")[-1] == "partial" and node.args:
                if dotted(node.args[0]) in _JIT_NAMES:
                    for arg in node.args[1:]:
                        name = dotted(arg)
                        if name:
                            roots.add(name.split(".")[-1])
            elif cname in _PALLAS_NAMES and node.args:
                roots.update(_func_ref_names(node.args[0]))
            elif cname in _TRACED_HOFS:
                pos = _TRACED_HOFS[cname]
                positions = pos if isinstance(pos, tuple) else (pos,)
                for p in positions:
                    if p < len(node.args):
                        roots.update(_func_ref_names(node.args[p]))
    return roots


def _function_table(tree: ast.AST) -> Dict[str, FuncDef]:
    out: Dict[str, FuncDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # first definition wins; shadowing is rare in these modules
            out.setdefault(node.name, node)
    return out


def _closure(roots: Set[str], table: Dict[str, FuncDef]) -> Set[str]:
    """Fix-point: helpers called from traced bodies are traced too."""
    traced = set(roots)
    changed = True
    while changed:
        changed = False
        for name in list(traced):
            fn = table.get(name)
            if fn is None:
                continue
            for call in walk_calls(fn):
                cname = call_name(call)
                if cname and cname in table and cname not in traced:
                    traced.add(cname)
                    changed = True
    return traced


def _is_literal(node: ast.expr) -> bool:
    try:
        ast.literal_eval(node)
        return True
    except (ValueError, SyntaxError, TypeError):
        return False


def _body_findings(
    path: str, fn: FuncDef, check_x64: bool
) -> List[Finding]:
    out: List[Finding] = []
    # inner defs have their own entry in the traced set; skip their bodies
    inner = {
        n for sub in ast.walk(fn)
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
        and sub is not fn
        for n in [sub.name]
    }

    def nodes():
        skip: Set[int] = set()
        for sub in ast.walk(fn):
            if (
                isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                and sub is not fn and sub.name in inner
            ):
                skip.update(id(s) for s in ast.walk(sub) if s is not sub)
            if id(sub) not in skip:
                yield sub

    for node in nodes():
        if isinstance(node, ast.Call):
            cname = call_name(node) or ""
            if cname in _BACKEND_QUERIES:
                out.append(Finding(
                    rule=RULE, path=path, line=node.lineno, symbol=fn.name,
                    message=f"{cname}() inside traced function "
                            f"{fn.name!r} is evaluated at trace time and "
                            "baked into the compiled artifact",
                    hint="query the backend in the un-jitted wrapper and "
                         "pass the answer in via static_argnames",
                ))
            elif cname in {"bool", "float", "int"} and node.args and not (
                _is_literal(node.args[0])
            ):
                out.append(Finding(
                    rule=RULE, path=path, line=node.lineno, symbol=fn.name,
                    message=f"{cname}() on a traced value inside "
                            f"{fn.name!r} concretizes the tracer — "
                            "ConcretizationTypeError on first real trace",
                    hint="keep the value abstract (jnp.where/lax.cond) or "
                         "mark the argument static",
                ))
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
                and not node.args
            ):
                out.append(Finding(
                    rule=RULE, path=path, line=node.lineno, symbol=fn.name,
                    message=f".item() inside traced function {fn.name!r} "
                            "forces a device sync / concretization under "
                            "trace",
                    hint="return the array and call .item() outside the "
                         "jitted region",
                ))
        if check_x64:
            name = dotted(node) if isinstance(node, ast.Attribute) else None
            if name in _X64_NAMES:
                out.append(Finding(
                    rule=RULE, path=path, line=node.lineno, symbol=fn.name,
                    message=f"{name} inside traced function {fn.name!r}: "
                            "without enable_x64 JAX silently down-casts "
                            "to 32-bit",
                    hint="use 32-bit dtypes, or manage "
                         "jax.experimental.enable_x64 explicitly at "
                         "module level",
                ))
    return out


@register_rule(
    RULE,
    "no backend queries, tracer concretization, or unmanaged 64-bit "
    "literals inside jitted/pallas/scan bodies in kernels/ and jaxengine/",
)
def run(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for d in SCAN_DIRS:
        for path in ctx.py_files(d):
            tree = ctx.tree(path)
            if tree is None:
                continue
            src = ctx.source(path) or ""
            check_x64 = "enable_x64" not in src
            table = _function_table(tree)
            traced = _closure(_collect_traced_roots(tree), table)
            for name in sorted(traced):
                fn = table.get(name)
                if fn is not None:
                    findings += _body_findings(path, fn, check_x64)
    findings.sort(key=lambda f: (f.path, f.line, f.symbol))
    return findings

"""CLI: ``python -m repro.analysis [--format json|text] [--rules ...]``.

Exit codes: 0 — clean (every finding exempted, no stale exemptions);
1 — active findings or stale exemptions; 2 — configuration error
(unknown rule, malformed exemption file).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.core import RULES, rule_ids
from repro.analysis.exemptions import DEFAULT_EXEMPTIONS_FILE, ExemptionError
from repro.analysis.report import DEFAULT_REPORT_PATH
from repro.analysis.runner import run_analysis


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-aware static invariant checker: engine-parity, "
                    "determinism, tracing-hazard, silent-fallback, "
                    "spec-drift.",
    )
    parser.add_argument(
        "--root", default=".",
        help="repository root to analyze (default: cwd)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="stdout format (default: text)",
    )
    parser.add_argument(
        "--rules", nargs="+", metavar="RULE",
        help=f"subset of rules to run (default: all of {rule_ids()})",
    )
    parser.add_argument(
        "--exemptions", default=None, metavar="PATH",
        help="exemption file, repo-relative (default: "
             f"{DEFAULT_EXEMPTIONS_FILE} if present)",
    )
    parser.add_argument(
        "--out", default=DEFAULT_REPORT_PATH, metavar="PATH",
        help=f"where to write the JSON report (default: "
             f"{DEFAULT_REPORT_PATH}); use '-' to skip writing",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print registered rules and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid in rule_ids():
            print(f"{rid}: {RULES[rid].description}")
        return 0

    try:
        report = run_analysis(
            args.root, rules=args.rules, exemptions_path=args.exemptions
        )
    except (KeyError, ExemptionError) as e:
        print(f"repro.analysis: configuration error: {e}", file=sys.stderr)
        return 2

    if args.out != "-":
        import os

        path = args.out
        if not os.path.isabs(path):
            path = os.path.join(args.root, path)
        report.save(path)

    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=1))
    else:
        print(report.format_text())

    return 0 if report.ok and not report.unused_exemptions else 1


if __name__ == "__main__":
    sys.exit(main())

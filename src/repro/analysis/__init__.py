"""repro.analysis — repo-aware static invariant checker.

AST-driven lint framework encoding this repository's bug history as
enforceable rules: engine-parity (spec fields honored by every serving
engine), determinism (no repr/id/hash keys, unseeded RNGs, wall clocks,
or raw set iteration in the deterministic core), tracing-hazard (no
backend queries or tracer concretization inside jitted/pallas bodies),
silent-fallback (degraded paths must emit counters), and spec-drift
(every spec field loaded, built, and demonstrated in an example).

Run it: ``python -m repro.analysis [--format json|text] [--rules ...]``.
Findings are silenced only via ``analysis_exemptions.json`` entries with
mandatory justifications; the JSON report lands at
``artifacts/analysis/report.json`` (schema v1).
"""

from repro.analysis.core import (  # noqa: F401
    Finding,
    RepoContext,
    Rule,
    RULES,
    register_rule,
    rule_ids,
)
from repro.analysis.exemptions import (  # noqa: F401
    Exemption,
    ExemptionError,
    load_exemptions,
)
from repro.analysis.report import AnalysisReport, SCHEMA_VERSION  # noqa: F401
from repro.analysis.runner import run_analysis  # noqa: F401

__all__ = [
    "AnalysisReport",
    "Exemption",
    "ExemptionError",
    "Finding",
    "RepoContext",
    "Rule",
    "RULES",
    "SCHEMA_VERSION",
    "load_exemptions",
    "register_rule",
    "rule_ids",
    "run_analysis",
]

"""Core of the repo-aware static invariant checker.

This module defines the three primitives every lint pass is built on:

* :class:`Finding` — one violation, anchored to ``file:line`` with a rule
  id, a human message, a fix hint, and a stable ``symbol`` the exemption
  file can match on (e.g. ``"SimSpec.timeout_s"``);
* :class:`RepoContext` — a lazy, cached view of the repository (source
  text + parsed ASTs keyed by repo-relative posix paths), so a pass can
  run identically against the real tree or a tiny fixture tree in tests;
* the rule registry — each pass registers a ``(rule id, description,
  run(ctx) -> findings)`` triple via :func:`register_rule`; the runner
  and the CLI discover passes only through the registry, so disabling a
  rule is dropping its id from the selection.

Passes are pure functions of the AST/source — nothing here imports the
modules under analysis, so a syntax error in the repo is a finding
(``parse-error``), never a crash of the checker itself.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, List, Optional

__all__ = [
    "Finding",
    "RepoContext",
    "Rule",
    "RULES",
    "register_rule",
    "rule_ids",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation.

    ``symbol`` is the stable anchor exemptions match on (a dotted name
    like ``SimSpec.concurrency`` or a function name); it stays valid
    across unrelated line churn, unlike ``line``.
    """

    rule: str
    path: str          # repo-relative, posix separators
    line: int
    message: str
    hint: str = ""
    symbol: str = ""

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
            "hint": self.hint,
        }

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "Finding":
        return Finding(
            rule=str(d["rule"]),
            path=str(d["path"]),
            line=int(d["line"]),          # type: ignore[arg-type]
            message=str(d["message"]),
            hint=str(d.get("hint", "")),
            symbol=str(d.get("symbol", "")),
        )

    def location(self) -> str:
        return f"{self.path}:{self.line}"


class RepoContext:
    """Cached source/AST access rooted at a repository checkout.

    All paths in and out are repo-relative with ``/`` separators; a pass
    never touches the filesystem directly, which is what lets the test
    suite point the same pass at a synthetic fixture tree.
    """

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        self._source: Dict[str, Optional[str]] = {}
        self._tree: Dict[str, Optional[ast.AST]] = {}
        #: files that failed to parse: rel path -> (lineno, message)
        self.parse_errors: Dict[str, tuple] = {}

    # -- path helpers ---------------------------------------------------
    def abspath(self, rel: str) -> str:
        return os.path.join(self.root, *rel.split("/"))

    def exists(self, rel: str) -> bool:
        return os.path.isfile(self.abspath(rel))

    def py_files(self, rel_dir: str) -> List[str]:
        """Sorted repo-relative paths of every ``.py`` under ``rel_dir``."""
        base = self.abspath(rel_dir)
        out: List[str] = []
        if not os.path.isdir(base):
            return out
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames.sort()
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    full = os.path.join(dirpath, fn)
                    rel = os.path.relpath(full, self.root)
                    out.append(rel.replace(os.sep, "/"))
        return out

    def files(self, rel_dir: str, suffixes: tuple) -> List[str]:
        """Sorted repo-relative non-Python files (e.g. example YAMLs)."""
        base = self.abspath(rel_dir)
        out: List[str] = []
        if not os.path.isdir(base):
            return out
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames.sort()
            for fn in sorted(filenames):
                if fn.endswith(suffixes):
                    full = os.path.join(dirpath, fn)
                    rel = os.path.relpath(full, self.root)
                    out.append(rel.replace(os.sep, "/"))
        return out

    # -- content access -------------------------------------------------
    def source(self, rel: str) -> Optional[str]:
        if rel not in self._source:
            try:
                with open(self.abspath(rel), encoding="utf-8") as f:
                    self._source[rel] = f.read()
            except OSError:
                self._source[rel] = None
        return self._source[rel]

    def tree(self, rel: str) -> Optional[ast.AST]:
        """Parsed AST, or ``None`` (missing file / syntax error).

        A syntax error is recorded in :attr:`parse_errors`; the runner
        turns those into ``parse-error`` findings so a broken file fails
        the gate instead of silently shrinking every pass's scope.
        """
        if rel not in self._tree:
            src = self.source(rel)
            if src is None:
                self._tree[rel] = None
            else:
                try:
                    self._tree[rel] = ast.parse(src, filename=rel)
                except SyntaxError as e:
                    self._tree[rel] = None
                    self.parse_errors[rel] = (e.lineno or 1, e.msg or "")
        return self._tree[rel]


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    description: str
    run: Callable[[RepoContext], List[Finding]]


RULES: Dict[str, Rule] = {}


def register_rule(
    rule_id: str, description: str
) -> Callable[[Callable[[RepoContext], List[Finding]]],
              Callable[[RepoContext], List[Finding]]]:
    """Decorator: register ``fn(ctx) -> [Finding]`` under ``rule_id``."""

    def wrap(fn: Callable[[RepoContext], List[Finding]]):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(id=rule_id, description=description, run=fn)
        return fn

    return wrap


def rule_ids() -> List[str]:
    return sorted(RULES)

"""Versioned JSON report of one analysis run (schema v1).

The artifact lands at ``artifacts/analysis/report.json`` and is consumed
by CI (fail on ``n_active > 0``) and by humans reading a build.  The
report is deliberately timestamp-free and machine-independent: two runs
over the same tree produce byte-identical JSON — the checker holds
itself to its own determinism rule.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

from repro.analysis.core import Finding
from repro.analysis.exemptions import Exemption

__all__ = ["AnalysisReport", "ReportedFinding", "SCHEMA_VERSION"]

SCHEMA_VERSION = 1
DEFAULT_REPORT_PATH = os.path.join("artifacts", "analysis", "report.json")


@dataclasses.dataclass(frozen=True)
class ReportedFinding:
    """A finding plus its exemption status at report time."""

    finding: Finding
    exempted: bool = False
    justification: str = ""

    def to_dict(self) -> Dict[str, object]:
        out = self.finding.to_dict()
        out["exempted"] = self.exempted
        if self.exempted:
            out["justification"] = self.justification
        return out

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "ReportedFinding":
        return ReportedFinding(
            finding=Finding.from_dict(d),
            exempted=bool(d.get("exempted", False)),
            justification=str(d.get("justification", "")),
        )


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    rules: List[str]
    n_files_scanned: int
    findings: List[ReportedFinding]
    unused_exemptions: List[Exemption] = dataclasses.field(
        default_factory=list
    )

    # -- derived ----------------------------------------------------------
    @property
    def active(self) -> List[ReportedFinding]:
        return [f for f in self.findings if not f.exempted]

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_exempted(self) -> int:
        return len(self.findings) - self.n_active

    @property
    def ok(self) -> bool:
        return self.n_active == 0

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.finding.rule] = out.get(f.finding.rule, 0) + 1
        return dict(sorted(out.items()))

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        return {
            "schema": SCHEMA_VERSION,
            "tool": "repro.analysis",
            "rules": list(self.rules),
            "n_files_scanned": self.n_files_scanned,
            "n_findings": len(self.findings),
            "n_active": self.n_active,
            "n_exempted": self.n_exempted,
            "findings_by_rule": self.by_rule(),
            "findings": [f.to_dict() for f in self.findings],
            "unused_exemptions": [
                e.to_dict() for e in self.unused_exemptions
            ],
        }

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "AnalysisReport":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported analysis report schema {d.get('schema')!r}; "
                f"this reader understands {SCHEMA_VERSION}"
            )
        return AnalysisReport(
            rules=[str(r) for r in d.get("rules", [])],
            n_files_scanned=int(d.get("n_files_scanned", 0)),  # type: ignore
            findings=[
                ReportedFinding.from_dict(f)
                for f in d.get("findings", [])  # type: ignore[union-attr]
            ],
            unused_exemptions=[
                Exemption(
                    rule=str(e["rule"]), path=str(e["path"]),
                    justification=str(e["justification"]),
                    symbol=str(e.get("symbol", "")),
                )
                for e in d.get("unused_exemptions", [])  # type: ignore
            ],
        )

    def save(self, path: str = DEFAULT_REPORT_PATH) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=False)
            f.write("\n")
        return path

    @staticmethod
    def load(path: str) -> "AnalysisReport":
        with open(path, encoding="utf-8") as f:
            return AnalysisReport.from_dict(json.load(f))

    # -- display ----------------------------------------------------------
    def format_text(self) -> str:
        lines: List[str] = []
        for f in self.findings:
            tag = "EXEMPT" if f.exempted else "FAIL"
            lines.append(
                f"[{tag}] {f.finding.rule}: {f.finding.location()} "
                f"{('(' + f.finding.symbol + ') ') if f.finding.symbol else ''}"
                f"{f.finding.message}"
            )
            if f.exempted:
                lines.append(f"         exempted: {f.justification}")
            elif f.finding.hint:
                lines.append(f"         hint: {f.finding.hint}")
        for e in self.unused_exemptions:
            lines.append(
                f"[STALE] exemption matched nothing: {e.rule} @ {e.path}"
                f"{(' (' + e.symbol + ')') if e.symbol else ''}"
            )
        counts = ", ".join(
            f"{r}={n}" for r, n in self.by_rule().items()
        ) or "none"
        lines.append(
            f"{self.n_files_scanned} files scanned, rules "
            f"[{', '.join(self.rules)}]: {len(self.findings)} findings "
            f"({self.n_active} active, {self.n_exempted} exempted) "
            f"[{counts}]"
        )
        lines.append("analysis: " + ("OK" if self.ok else "FAILED"))
        return "\n".join(lines)

"""Small AST helpers shared by the lint passes."""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Union

FuncDef = Union[ast.FunctionDef, ast.AsyncFunctionDef]

__all__ = [
    "FuncDef",
    "dotted",
    "call_name",
    "walk_calls",
    "func_defs",
    "dataclass_fields",
    "consumed_names",
]


def dotted(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain, else ``None``.

    ``jax.random.default_rng`` -> ``"jax.random.default_rng"``;
    chains rooted in calls/subscripts return ``None`` (not a plain name).
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted(call.func)


def walk_calls(node: ast.AST) -> Iterator[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


def func_defs(tree: ast.AST) -> Iterator[FuncDef]:
    """All function definitions, including nested ones and methods."""
    for sub in ast.walk(tree):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield sub


def dataclass_fields(cls: ast.ClassDef) -> List[ast.AnnAssign]:
    """Annotated class-body assignments — the dataclass field set."""
    out: List[ast.AnnAssign] = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(
            stmt.target, ast.Name
        ):
            out.append(stmt)
    return out


def is_dataclass_def(cls: ast.ClassDef) -> bool:
    """True when the class carries a ``dataclass`` decorator."""
    for dec in cls.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(node) or ""
        if name.split(".")[-1] == "dataclass":
            return True
    return False


def consumed_names(tree: ast.AST) -> Set[str]:
    """Names a module plausibly *consumes* as configuration:

    attribute reads (``self.timeout_s``, ``spec.concurrency``), keyword
    arguments (``timeout_s=...``) and function parameters.  This is the
    name-level consumption signal the engine-parity pass compares across
    engines — deliberately syntactic, so it works on any engine style
    (object per request, NumPy arrays, lax.scan kernels) without
    importing the modules.
    """
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, ast.keyword) and node.arg is not None:
            out.add(node.arg)
        elif isinstance(node, ast.arg):
            out.add(node.arg)
    return out

"""Compile a :class:`ServiceSpec` into the runnable simulator stack.

``build_service`` is the only place in the repo that assembles the
trace × catalog × policy × autoscaler × LB × :class:`ServingSimulator`
pipeline; every driver (launch/serve, examples, benchmarks) goes through
it, so a new scenario is a spec file, not a new driver.

Overrides exist for the pieces an experiment may precompute: a trace
window sliced by hand (``trace=``), a shared request tape (``requests=``),
or a custom catalog.  Everything else is derived from the spec.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.cluster.catalog import Catalog, default_catalog
from repro.cluster.simulator import SimConfig
from repro.cluster.traces import SpotTrace, load_trace
from repro.configs import get_config
from repro.core.autoscaler import Autoscaler, ConstantTarget, LoadAutoscaler
from repro.core.policy import Policy, make_policy, policy_class
from repro.models.config import ModelConfig
from repro.obs.recorder import ObsRecorder
from repro.obs.registry import use_registry
from repro.obs.slo import SLOBurnConfig
from repro.serving.latency import make_latency_model
from repro.serving.load_balancer import (
    LeastLoadedBalancer,
    LoadBalancer,
    RoundRobinBalancer,
)
from repro.serving.token.config import TokenSchedulerConfig
from repro.serving.engine import VectorizedServingEngine
from repro.serving.sim import ServingSimulator
from repro.service.spec import ResourceSpec, ServiceSpec, SpecError
from repro.workloads import Request, make_workload

__all__ = [
    "ResolvedService",
    "build_requests",
    "build_service",
    "resolve_zones",
]


def resolve_zones(
    resources: ResourceSpec, trace: SpotTrace, catalog: Catalog
) -> List[str]:
    """Zones of ``trace`` that pass the ``any_of``/``exclude`` filter.

    Zones the catalog does not know are skipped (a trace file may carry
    zones outside the default universe); an empty result is a spec error.
    """
    out: List[str] = []
    for name in trace.zones:
        try:
            z = catalog.zone(name)
        except KeyError:
            continue
        if resources.allows(z.cloud, z.region, z.name):
            out.append(name)
    if not out:
        raise SpecError(
            f"resources filter matches no zone of trace "
            f"{trace.name!r} (trace zones: {list(trace.zones)}); "
            "loosen any_of / exclude_zones"
        )
    return out


def _build_policy(spec: ServiceSpec, trace: SpotTrace,
                  catalog: Catalog) -> Policy:
    name = spec.replica_policy.name
    kwargs = spec.replica_policy.policy_kwargs()
    # the forecast: section only applies to forecast-consuming policies
    # (uses_forecast flag); vanilla cells of a mixed sweep ignore it
    if spec.forecast is not None and getattr(
        policy_class(name), "uses_forecast", False
    ):
        kwargs.update(spec.forecast.policy_kwargs())
    try:
        policy = make_policy(name, **kwargs)
    except (TypeError, ValueError) as e:
        raise SpecError(
            f"replica_policy {name!r} rejected its knobs {kwargs}: {e}"
        ) from e
    if name == "omniscient":
        # the oracle needs the full trace ahead of time (offline ILP)
        from repro.core.omniscient import solve_omniscient

        itype = spec.resources.instance_type
        k = (
            catalog.od_price(itype, trace.zones[0])
            / catalog.spot_price(itype, trace.zones[0])
        )
        policy.attach_schedule(
            solve_omniscient(
                trace,
                n_target=spec.autoscaler.target,
                cold_start_s=spec.sim.cold_start_s,
                k_ratio=k,
                avail_target=0.99,
            )
        )
    return policy


def _build_autoscaler(spec: ServiceSpec) -> Autoscaler:
    a = spec.autoscaler
    if a.kind == "constant":
        return ConstantTarget(a.target)
    return LoadAutoscaler(
        a.qps_per_replica,
        window_s=a.window_s,
        upscale_delay_s=a.upscale_delay_s,
        downscale_delay_s=a.downscale_delay_s,
        min_replicas=a.min_replicas,
        max_replicas=a.max_replicas,
        initial_target=a.target,
    )


def _build_lb(spec: ServiceSpec) -> LoadBalancer:
    if spec.load_balancer == "round_robin":
        return RoundRobinBalancer()
    return LeastLoadedBalancer()


def build_requests(spec: ServiceSpec) -> List[Request]:
    """Generate the spec's request tape (empty for ``workload: none``).

    Exposed so experiment sweeps can generate one tape and replay it
    across several service variants (``Service(..., requests=tape)``)."""
    w = spec.workload
    if w.kind == "none":
        return []
    kw = dict(w.args)
    kw["seed"] = w.seed
    rate_key = "rate_per_s" if w.kind == "poisson" else "base_rate_per_s"
    kw.setdefault(rate_key, w.rate_per_s)
    horizon = spec.sim.duration_s - spec.sim.drain_s
    if horizon <= 0:
        raise SpecError(
            f"sim.duration_hours ({spec.sim.duration_hours:g}h = "
            f"{spec.sim.duration_s:g}s) must exceed sim.drain_s "
            f"({spec.sim.drain_s:g}s) to leave room for arrivals; "
            "lengthen the run or shrink drain_s"
        )
    return make_workload(w.kind, **kw).generate(horizon)


@dataclasses.dataclass
class ResolvedService:
    """Everything ``build_service`` wired together, inspectable."""

    spec: ServiceSpec
    trace: SpotTrace
    catalog: Catalog
    model_config: ModelConfig
    zones: List[str]
    policy: Policy
    autoscaler: Autoscaler
    load_balancer: LoadBalancer
    requests: List[Request]
    # ServingSimulator, VectorizedServingEngine or JaxServingEngine,
    # per spec.sim.engine
    simulator: "ServingSimulator | VectorizedServingEngine"
    # the run's shared event recorder + metrics registry, built from the
    # spec's observability: section (detail / window_s)
    obs: Optional[ObsRecorder] = None


def build_service(
    spec: ServiceSpec,
    *,
    trace: Optional[SpotTrace] = None,
    catalog: Optional[Catalog] = None,
    requests: Optional[Sequence[Request]] = None,
) -> ResolvedService:
    """Spec -> resolved, runnable service (fresh simulator each call)."""
    catalog = catalog or default_catalog()
    trace = trace if trace is not None else load_trace(spec.trace)
    zones = resolve_zones(spec.resources, trace, catalog)
    if tuple(zones) != tuple(trace.zones):
        trace = trace.slice_zones(zones)
    if spec.sim.preemption_warning_s is not None:
        # copy — named traces are process-global cached and must never
        # be mutated in place
        trace = dataclasses.replace(
            trace, preemption_warning_s=spec.sim.preemption_warning_s
        )

    policy = _build_policy(spec, trace, catalog)
    autoscaler = _build_autoscaler(spec)
    lb = _build_lb(spec)
    reqs = list(requests) if requests is not None else build_requests(spec)

    sim_spec = spec.sim
    # with no request path there is nothing to do between control ticks —
    # step the request loop at the control cadence instead of 1 Hz
    sub_step = (
        max(sim_spec.sub_step_s, sim_spec.control_interval_s)
        if spec.workload.kind == "none" and requests is None
        else sim_spec.sub_step_s
    )
    if sim_spec.engine == "legacy":
        engine_cls = ServingSimulator
    elif sim_spec.engine == "jax":
        # lazy: only sim.engine: "jax" runs pay the jax import
        from repro.serving.jaxengine import JaxServingEngine

        engine_cls = JaxServingEngine
    else:
        engine_cls = VectorizedServingEngine
    burn = spec.observability.slo_burn
    obs = ObsRecorder(
        detail=spec.observability.detail,
        window_s=spec.observability.window_s,
        trace_sample=spec.observability.trace_sample,
        slo_burn=SLOBurnConfig(
            target=burn.target,
            fast_window_s=burn.fast_window_s,
            slow_window_s=burn.slow_window_s,
            fast_threshold=burn.fast_threshold,
            slow_threshold=burn.slow_threshold,
        ),
    )
    model_cfg = get_config(spec.model)
    # run-scope the registry so factory-level counters (e.g. the
    # profile-fallback) land on this run's obs, not a process global
    with use_registry(obs.registry):
        latency_model = make_latency_model(
            model_cfg,
            catalog.instance_type(spec.resources.instance_type),
            model_id=spec.model,
            source=spec.latency.source,
            profile=spec.latency.profile,
        )
    serving = spec.serving
    # migration only exists at token granularity; request-model cells of
    # a mixed replica_models sweep run without it (the status quo)
    migration = (
        spec.migration if sim_spec.replica_model == "token" else None
    )
    token_knobs = None
    if sim_spec.replica_model == "token":
        token_knobs = TokenSchedulerConfig(
            slo_ttft_s=serving.slo.ttft_s,
            slo_tpot_s=serving.slo.tpot_s,
            prefill_chunk_tokens=serving.prefill_chunk_tokens,
            max_batch=serving.max_batch,
            kv_budget_tokens=serving.kv_budget_tokens,
            iter_overhead_s=serving.iter_overhead_s,
            goodput_window_s=serving.goodput_window_s,
        )
    try:
        simulator = engine_cls(
            trace,
            policy,
            reqs,
            model_cfg,
            itype=spec.resources.instance_type,
            catalog=catalog,
            autoscaler=autoscaler,
            lb=lb,
            sim_config=SimConfig(
                itype=spec.resources.instance_type,
                cold_start_s=sim_spec.cold_start_s,
                control_interval_s=sim_spec.control_interval_s,
                warning_enabled=sim_spec.warning_enabled,
                seed=sim_spec.seed,
                record_series=sim_spec.record_series,
            ),
            timeout_s=sim_spec.timeout_s,
            sub_step_s=sub_step,
            workload_name=spec.workload.kind,
            concurrency=sim_spec.concurrency,
            concurrency_cap=serving.concurrency_cap,
            latency_model=latency_model,
            replica_model=sim_spec.replica_model,
            token_scheduler=token_knobs,
            migration=migration,
            obs=obs,
        )
    except TypeError as e:
        # the array engines reject configurations they cannot simulate
        # exactly (e.g. custom balancer subclasses); surface that as a
        # spec problem with the engine that would accept it
        raise SpecError(
            f"sim.engine {sim_spec.engine!r} rejected this spec: {e}"
        ) from e
    return ResolvedService(
        spec=spec,
        trace=trace,
        catalog=catalog,
        model_config=simulator.cfg,
        zones=zones,
        policy=policy,
        autoscaler=autoscaler,
        load_balancer=lb,
        requests=reqs,
        simulator=simulator,
        obs=obs,
    )

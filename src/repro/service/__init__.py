"""Declarative service API — the repo's single front door.

``ServiceSpec`` (frozen dataclasses mirroring the paper's Listing 1) declares
*what* to serve; ``Service`` compiles and runs it:

    from repro.service import Service

    Service.from_yaml("service.yaml").run().summary()

Layers: ``spec`` (typed schema) -> ``loader`` (dict/JSON/YAML + validation)
-> ``builder`` (spec -> trace/policy/autoscaler/LB/simulator) -> ``service``
(the run/status facade).
"""

from repro.service.builder import (
    ResolvedService,
    build_requests,
    build_service,
    resolve_zones,
)
from repro.service.loader import (
    load_spec,
    spec_from_dict,
    spec_from_json,
    spec_from_yaml,
)
from repro.service.service import Service
from repro.service.spec import (
    AutoscalerSpec,
    ForecastSpec,
    LatencySpec,
    MigrationSpec,
    PlacementFilter,
    ReplicaPolicySpec,
    ResourceSpec,
    ServiceSpec,
    ServingSpec,
    SimSpec,
    SLOSpec,
    SpecError,
    SweepSpec,
    WorkloadSpec,
)

__all__ = [
    "AutoscalerSpec",
    "ForecastSpec",
    "LatencySpec",
    "MigrationSpec",
    "PlacementFilter",
    "ReplicaPolicySpec",
    "ResolvedService",
    "ResourceSpec",
    "Service",
    "ServiceSpec",
    "ServingSpec",
    "SimSpec",
    "SLOSpec",
    "SpecError",
    "SweepSpec",
    "WorkloadSpec",
    "build_requests",
    "build_service",
    "load_spec",
    "resolve_zones",
    "spec_from_dict",
    "spec_from_json",
    "spec_from_yaml",
]

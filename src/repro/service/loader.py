"""Build :class:`ServiceSpec` objects from dicts, JSON, or YAML.

The loader is strict: unknown keys, wrong section types, and out-of-range
values all raise :class:`SpecError` with the offending field named, so a
typo in a service file fails at load time, not three hours into a replay.

YAML support uses PyYAML when present; without it, JSON files and dicts
still work (``SpecError`` explains the gap if a ``.yaml`` file is passed).
The top-level ``service:`` wrapper key is optional, mirroring the paper's
Listing 1 layout.
"""

from __future__ import annotations

import json
from typing import Any, Mapping, Optional

from repro.service.spec import (
    AutoscalerSpec,
    ForecastSpec,
    LatencySpec,
    MigrationSpec,
    ObservabilitySpec,
    PlacementFilter,
    ReplicaPolicySpec,
    ResourceSpec,
    ServiceSpec,
    ServingSpec,
    SimSpec,
    SLOBurnSpec,
    SLOSpec,
    SpecError,
    SweepSpec,
    WorkloadSpec,
)

try:  # optional dependency — gate, never require
    import yaml as _yaml
except ImportError:  # pragma: no cover - environment-dependent
    _yaml = None

__all__ = ["spec_from_dict", "spec_from_json", "spec_from_yaml", "load_spec"]


def _read_spec_file(path: str) -> str:
    try:
        with open(path) as f:
            return f.read()
    except OSError as e:
        raise SpecError(f"cannot read service spec file {path!r}: {e}") from e


def _section(d: Mapping[str, Any], key: str) -> Mapping[str, Any]:
    sub = d.get(key, {})
    if not isinstance(sub, Mapping):
        raise SpecError(
            f"section {key!r} must be a mapping, got {type(sub).__name__}"
        )
    return sub


def _check_keys(d: Mapping[str, Any], allowed: tuple, where: str) -> None:
    unknown = set(d) - set(allowed)
    if unknown:
        raise SpecError(
            f"{where} has unknown keys {sorted(unknown)}; "
            f"allowed: {sorted(allowed)}"
        )


def _pick(d: Mapping[str, Any], cls, where: str) -> dict:
    """kwargs for a spec dataclass from a section dict, key-checked."""
    import dataclasses

    fields = tuple(f.name for f in dataclasses.fields(cls))
    _check_keys(d, fields, where)
    return dict(d)


def _resources_from_dict(d: Mapping[str, Any]) -> ResourceSpec:
    _check_keys(
        d, ("instance_type", "any_of", "exclude_zones"), "resources"
    )
    kw: dict = {}
    if "instance_type" in d:
        kw["instance_type"] = d["instance_type"]
    if "exclude_zones" in d:
        kw["exclude_zones"] = tuple(d["exclude_zones"])
    any_of = d.get("any_of")
    if any_of is not None:
        if not isinstance(any_of, (list, tuple)):
            raise SpecError(
                "resources.any_of must be a list of "
                "{cloud|region|zone} filters"
            )
        kw["any_of"] = tuple(
            PlacementFilter.from_dict(e if isinstance(e, Mapping) else
                                      _bad_any_of(e))
            for e in any_of
        )
    return ResourceSpec(**kw)


def _bad_any_of(entry: Any) -> Mapping[str, Any]:
    raise SpecError(
        f"resources.any_of entries must be mappings, got {entry!r}"
    )


def _sweep_policy(entry: Any) -> ReplicaPolicySpec:
    """A sweep policy is a bare name or a full replica_policy mapping."""
    if isinstance(entry, str):
        return ReplicaPolicySpec(name=entry)
    if isinstance(entry, Mapping):
        return ReplicaPolicySpec(
            **_pick(entry, ReplicaPolicySpec, "sweep.policies entry")
        )
    raise SpecError(
        f"sweep.policies entries must be policy names or mappings, "
        f"got {entry!r}"
    )


def _sweep_workload(entry: Any) -> WorkloadSpec:
    """A sweep workload is a bare kind or a full workload mapping."""
    if isinstance(entry, str):
        return WorkloadSpec(kind=entry)
    if isinstance(entry, Mapping):
        return WorkloadSpec(
            **_pick(entry, WorkloadSpec, "sweep.workloads entry")
        )
    raise SpecError(
        f"sweep.workloads entries must be workload kinds or mappings, "
        f"got {entry!r}"
    )


def _migration_from_dict(d: Mapping[str, Any], where: str) -> MigrationSpec:
    """Build a MigrationSpec section; its own ValueErrors (bad compression
    mode, negative thresholds) surface as SpecErrors naming the section."""
    kw = _pick(d, MigrationSpec, where)
    try:
        return MigrationSpec(**kw)
    except SpecError:
        raise
    except ValueError as e:
        raise SpecError(f"{where}: {e}") from e


def _sweep_migration(entry: Any) -> "bool | MigrationSpec":
    """A sweep migration entry is a bool toggle or a full mapping."""
    if isinstance(entry, bool):
        return entry
    if isinstance(entry, Mapping):
        return _migration_from_dict(entry, "sweep.migration entry")
    raise SpecError(
        f"sweep.migration entries must be booleans or migration "
        f"mappings, got {entry!r}"
    )


def _sweep_from_dict(d: Mapping[str, Any]) -> SweepSpec:
    keys = (
        "policies", "traces", "workloads", "seeds", "forecasters",
        "replica_models", "migration",
    )
    _check_keys(d, keys, "sweep")
    for key in keys:
        if key in d and not isinstance(d[key], (list, tuple)):
            raise SpecError(
                f"sweep.{key} must be a list, got {type(d[key]).__name__}"
            )
    traces = tuple(d.get("traces", ()))
    for tr in traces:
        if not isinstance(tr, str):
            raise SpecError(
                f"sweep.traces entries must be strings, got {tr!r}"
            )
    forecasters = tuple(d.get("forecasters", ()))
    for fc in forecasters:
        if not isinstance(fc, str):
            raise SpecError(
                f"sweep.forecasters entries must be strings, got {fc!r}"
            )
    replica_models = tuple(d.get("replica_models", ()))
    for rm in replica_models:
        if not isinstance(rm, str):
            raise SpecError(
                f"sweep.replica_models entries must be strings, got {rm!r}"
            )
    return SweepSpec(
        policies=tuple(_sweep_policy(e) for e in d.get("policies", ())),
        traces=traces,
        workloads=tuple(_sweep_workload(e) for e in d.get("workloads", ())),
        seeds=tuple(d.get("seeds", ())),
        forecasters=forecasters,
        replica_models=replica_models,
        migration=tuple(
            _sweep_migration(e) for e in d.get("migration", ())
        ),
    )


def _serving_from_dict(d: Mapping[str, Any]) -> "tuple[ServingSpec, Any]":
    """Build the serving section; also returns the ``replica_model``
    sugar key (canonical home: ``sim.replica_model``)."""
    _check_keys(
        d,
        ("replica_model", "slo", "concurrency_cap", "prefill_chunk_tokens",
         "max_batch", "kv_budget_tokens", "iter_overhead_s",
         "goodput_window_s"),
        "serving",
    )
    kw: dict = {
        k: d[k] for k in d if k not in ("replica_model", "slo")
    }
    slo = d.get("slo")
    if slo is not None:
        if not isinstance(slo, Mapping):
            raise SpecError(
                f"serving.slo must be a mapping, got {type(slo).__name__}"
            )
        kw["slo"] = SLOSpec(**_pick(slo, SLOSpec, "serving.slo"))
    return ServingSpec(**kw), d.get("replica_model")


def _observability_from_dict(d: Mapping[str, Any]) -> ObservabilitySpec:
    """Build the observability section: detail / out_dir / jsonl /
    chrome_trace / window_s / trace_sample plus the nested ``slo_burn``
    mapping (target / fast_window_s / slow_window_s / fast_threshold /
    slow_threshold — see :class:`SLOBurnSpec`)."""
    kw: dict = dict(
        _pick(d, ObservabilitySpec, "observability")
    )
    burn = kw.pop("slo_burn", None)
    if burn is not None:
        if not isinstance(burn, Mapping):
            raise SpecError(
                f"observability.slo_burn must be a mapping, "
                f"got {type(burn).__name__}"
            )
        kw["slo_burn"] = SLOBurnSpec(
            **_pick(burn, SLOBurnSpec, "observability.slo_burn")
        )
    return ObservabilitySpec(**kw)


def spec_from_dict(d: Mapping[str, Any]) -> ServiceSpec:
    """Build and validate a :class:`ServiceSpec` from a plain dict."""
    if not isinstance(d, Mapping):
        raise SpecError(
            f"service spec must be a mapping, got {type(d).__name__}"
        )
    if "service" in d and isinstance(d["service"], Mapping):
        d = d["service"]
    _check_keys(
        d,
        ("name", "model", "trace", "resources", "replica_policy",
         "autoscaler", "workload", "latency", "forecast", "serving",
         "observability", "migration", "sim", "load_balancer", "sweep"),
        "service spec",
    )
    try:
        # only keys present in the dict are passed on, so the dataclass
        # defaults stay the single source of truth
        kw: dict = {k: d[k] for k in ("name", "model", "trace",
                                      "load_balancer") if k in d}
        kw["resources"] = _resources_from_dict(_section(d, "resources"))
        kw["replica_policy"] = ReplicaPolicySpec(
            **_pick(_section(d, "replica_policy"), ReplicaPolicySpec,
                    "replica_policy")
        )
        kw["autoscaler"] = AutoscalerSpec(
            **_pick(_section(d, "autoscaler"), AutoscalerSpec, "autoscaler")
        )
        kw["workload"] = WorkloadSpec(
            **_pick(_section(d, "workload"), WorkloadSpec, "workload")
        )
        kw["latency"] = LatencySpec(
            **_pick(_section(d, "latency"), LatencySpec, "latency")
        )
        if d.get("forecast") is not None:
            kw["forecast"] = ForecastSpec(
                **_pick(_section(d, "forecast"), ForecastSpec, "forecast")
            )
        kw["serving"], serving_rm = _serving_from_dict(
            _section(d, "serving")
        )
        if d.get("observability") is not None:
            kw["observability"] = _observability_from_dict(
                _section(d, "observability")
            )
        if d.get("migration") is not None:
            kw["migration"] = _migration_from_dict(
                _section(d, "migration"), "migration"
            )
        sim_kw = _pick(_section(d, "sim"), SimSpec, "sim")
        if serving_rm is not None:
            # serving.replica_model is YAML sugar for sim.replica_model;
            # a conflicting explicit sim value is a spec error
            if sim_kw.get("replica_model", serving_rm) != serving_rm:
                raise SpecError(
                    f"serving.replica_model ({serving_rm!r}) conflicts "
                    f"with sim.replica_model "
                    f"({sim_kw['replica_model']!r}); set one"
                )
            sim_kw["replica_model"] = serving_rm
        kw["sim"] = SimSpec(**sim_kw)
        if d.get("sweep") is not None:
            kw["sweep"] = _sweep_from_dict(_section(d, "sweep"))
        spec = ServiceSpec(**kw)
    except TypeError as e:
        # e.g. a list where a scalar belongs — surface as a spec error
        raise SpecError(f"malformed service spec: {e}") from e
    return spec.validate()


def spec_from_json(path_or_text: str) -> ServiceSpec:
    """Load a spec from a JSON file path or a JSON document string."""
    text = path_or_text
    if not path_or_text.lstrip().startswith("{"):
        text = _read_spec_file(path_or_text)
    try:
        d = json.loads(text)
    except json.JSONDecodeError as e:
        raise SpecError(f"invalid JSON service spec: {e}") from e
    return spec_from_dict(d)


def spec_from_yaml(path_or_text: str) -> ServiceSpec:
    """Load a spec from a YAML file path or a YAML document string."""
    if _yaml is None:  # pragma: no cover - environment-dependent
        raise SpecError(
            "PyYAML is not installed; install the 'yaml' extra or use a "
            "JSON spec (spec_from_json / a .json file)"
        )
    text = path_or_text
    if "\n" not in path_or_text and not path_or_text.lstrip().startswith(
        ("{", "service:")
    ):
        text = _read_spec_file(path_or_text)
    try:
        d = _yaml.safe_load(text)
    except _yaml.YAMLError as e:
        raise SpecError(f"invalid YAML service spec: {e}") from e
    if d is None:
        raise SpecError("empty YAML service spec")
    return spec_from_dict(d)


def load_spec(source: Any) -> ServiceSpec:
    """Polymorphic entry: ServiceSpec | dict | path (.yaml/.yml/.json)."""
    if isinstance(source, ServiceSpec):
        return source.validate()
    if isinstance(source, Mapping):
        return spec_from_dict(source)
    if isinstance(source, str):
        if source.endswith((".yaml", ".yml")):
            return spec_from_yaml(source)
        if source.endswith(".json"):
            return spec_from_json(source)
        raise SpecError(
            f"cannot infer spec format of {source!r}; expected a dict, a "
            "ServiceSpec, or a path ending in .yaml/.yml/.json"
        )
    raise SpecError(
        f"cannot build a ServiceSpec from {type(source).__name__}"
    )

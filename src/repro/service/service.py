"""The user-facing facade: a declared service you can run and inspect.

    from repro.service import Service

    svc = Service.from_yaml("service.yaml")
    result = svc.run()                  # ServingResult
    print(result.summary())
    print(svc.status())

A :class:`Service` owns a validated spec plus optional resolved overrides
(a hand-sliced trace window, a shared request tape).  ``run()`` compiles
the spec through ``build_service`` — a fresh simulator per run, so the
same Service can be run repeatedly (simulators are single-shot).
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence

from repro.cluster.catalog import Catalog
from repro.cluster.traces import SpotTrace
from repro.serving.sim import ServingResult
from repro.service.builder import ResolvedService, build_service
from repro.service.loader import load_spec
from repro.service.spec import ServiceSpec
from repro.workloads import Request

__all__ = ["Service"]


class Service:
    """One declared service: spec in, :class:`ServingResult` out."""

    def __init__(
        self,
        spec: "ServiceSpec | Mapping[str, Any] | str",
        *,
        trace: Optional[SpotTrace] = None,
        catalog: Optional[Catalog] = None,
        requests: Optional[Sequence[Request]] = None,
    ) -> None:
        self.spec = load_spec(spec)
        self._trace_override = trace
        self._catalog_override = catalog
        self._requests_override = requests
        self._resolved: Optional[ResolvedService] = None
        self._resolved_unused = False   # resolved but not yet run
        self.result: Optional[ServingResult] = None
        # artifact paths written by the last run (detail "full" only)
        self.artifacts: Dict[str, str] = {}

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_dict(cls, d: Mapping[str, Any], **overrides: Any) -> "Service":
        return cls(dict(d), **overrides)

    @classmethod
    def from_yaml(cls, path_or_text: str, **overrides: Any) -> "Service":
        from repro.service.loader import spec_from_yaml

        return cls(spec_from_yaml(path_or_text), **overrides)

    @classmethod
    def from_json(cls, path_or_text: str, **overrides: Any) -> "Service":
        from repro.service.loader import spec_from_json

        return cls(spec_from_json(path_or_text), **overrides)

    # -- execution ---------------------------------------------------------
    def resolve(self) -> ResolvedService:
        """Compile the spec (fresh policy/autoscaler/simulator)."""
        self._resolved = build_service(
            self.spec,
            trace=self._trace_override,
            catalog=self._catalog_override,
            requests=self._requests_override,
        )
        self._resolved_unused = True
        return self._resolved

    def run(self, duration_s: Optional[float] = None) -> ServingResult:
        """Run the service over its horizon; returns the ServingResult.

        Reuses a freshly ``resolve()``-d stack if one is pending;
        otherwise compiles a new one (simulators are single-shot)."""
        if self._resolved is not None and self._resolved_unused:
            resolved = self._resolved
        else:
            resolved = self.resolve()
        self._resolved_unused = False
        self.result = resolved.simulator.run(
            duration_s if duration_s is not None else self.spec.sim.duration_s
        )
        self._export_obs(resolved)
        return self.result

    def _export_obs(self, resolved: ResolvedService) -> None:
        """At observability detail ``full``, write the run's artifacts
        (event JSONL and/or Chrome trace) under ``out_dir``."""
        spec = self.spec.observability
        obs = resolved.obs
        if obs is None or obs.detail != "full":
            return
        if not (spec.jsonl or spec.chrome_trace):
            return
        import os

        from repro.obs.export import write_chrome_trace, write_jsonl

        os.makedirs(spec.out_dir, exist_ok=True)
        stem = os.path.join(spec.out_dir, self.spec.name)
        records = obs.records()
        spans = obs.span_records()
        tok = self.result.token if self.result is not None else None
        self.artifacts = {}
        if spec.jsonl:
            self.artifacts["events"] = write_jsonl(
                records, stem + ".events.jsonl"
            )
            if spans:
                self.artifacts["spans"] = write_jsonl(
                    spans, stem + ".spans.jsonl"
                )
        if spec.chrome_trace:
            self.artifacts["trace"] = write_chrome_trace(
                records, stem + ".trace.json",
                spans=spans or None,
                token_windows=tok.windows if tok is not None else None,
            )

    # -- introspection -----------------------------------------------------
    def status(self) -> Dict[str, Any]:
        """Resolved state (and metrics after a run), JSON-friendly."""
        resolved = self._resolved
        out: Dict[str, Any] = {
            "name": self.spec.name,
            "model": self.spec.model,
            "trace": self.spec.trace,
            "policy": self.spec.replica_policy.name,
            "instance_type": self.spec.resources.instance_type,
            "state": "declared",
        }
        if resolved is not None:
            cluster = resolved.simulator.cluster
            out.update(
                state="resolved",
                zones=list(resolved.zones),
                n_requests=len(resolved.requests),
                duration_hours=self.spec.sim.duration_hours,
                n_events=len(cluster.events),
                n_preemptions=cluster.n_preemptions,
                n_launch_failures=cluster.n_launch_failures,
            )
        if self.result is not None:
            r = self.result
            out.update(
                state="finished",
                availability=r.availability,
                cost_vs_ondemand=r.cost_vs_ondemand,
                total_cost=r.total_cost,
                failure_rate=r.failure_rate,
                n_completed=r.n_completed,
                p50_s=r.pct(50),
                p99_s=r.pct(99),
            )
            if r.obs is not None:
                out["obs_event_counts"] = r.obs.event_counts()
            if self.artifacts:
                out["obs_artifacts"] = dict(self.artifacts)
        return out

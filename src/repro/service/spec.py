"""Declarative service specification — the paper's Listing 1 as typed data.

A :class:`ServiceSpec` is the single front door to this repro: it names the
model, the spot trace, the ``any_of`` resource filter, the replica policy
(SpotHedge or a baseline) with its knobs, the autoscaler, the request
workload and the simulation horizon.  ``repro.service.builder`` compiles a
spec into the resolved Catalog/SpotTrace/Policy/Autoscaler/LoadBalancer/
ServingSimulator stack; ``repro.service.Service`` runs it.

All specs are frozen dataclasses with ``to_dict`` round-trips, so a spec is
equally a Python literal, a JSON object, or a YAML file:

    service:
      name: chat
      model: command-r-35b
      trace: aws-3
      resources:
        instance_type: g5.48xlarge
        any_of:
          - region: us-west-2
          - region: us-east-1
      replica_policy:
        name: spothedge
        overprovision: 2
      autoscaler:
        kind: load
        target: 4
        qps_per_replica: 0.8

Local shape/positivity validation lives in ``__post_init__``; cross-registry
checks (is the policy registered? does the trace exist?) live in
``ServiceSpec.validate`` so module import stays cheap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple, Union

from repro.migration.config import MigrationSpec

__all__ = [
    "SpecError",
    "PlacementFilter",
    "ResourceSpec",
    "ReplicaPolicySpec",
    "AutoscalerSpec",
    "WorkloadSpec",
    "LatencySpec",
    "ForecastSpec",
    "SLOSpec",
    "ServingSpec",
    "SLOBurnSpec",
    "ObservabilitySpec",
    "MigrationSpec",
    "SimSpec",
    "SweepSpec",
    "ServiceSpec",
]


class SpecError(ValueError):
    """A service spec is malformed; the message says which field and why."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


def _clean(d: Dict[str, Any]) -> Dict[str, Any]:
    """Drop ``None`` values so to_dict output stays minimal and re-loadable."""
    return {k: v for k, v in d.items() if v is not None}


# ---------------------------------------------------------------------------
# Resources (Listing 1: resources + any_of)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacementFilter:
    """One ``any_of`` entry: a zone matches if every set field matches.

    An entry with no fields set matches everything (Listing 1 uses bare
    ``cloud: aws`` entries; ``{}`` would mean "anywhere").
    """

    cloud: Optional[str] = None
    region: Optional[str] = None
    zone: Optional[str] = None

    def matches(self, cloud: str, region: str, zone: str) -> bool:
        return (
            (self.cloud is None or self.cloud == cloud)
            and (self.region is None or self.region == region)
            and (self.zone is None or self.zone == zone)
        )

    def to_dict(self) -> Dict[str, Any]:
        return _clean(dataclasses.asdict(self))

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "PlacementFilter":
        unknown = set(d) - {"cloud", "region", "zone"}
        _require(
            not unknown,
            f"any_of entry has unknown keys {sorted(unknown)}; "
            "allowed: cloud, region, zone",
        )
        return PlacementFilter(
            cloud=d.get("cloud"), region=d.get("region"), zone=d.get("zone")
        )


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """What to run on, and where placement is allowed.

    ``any_of=None`` (the default) leaves every zone of the trace enabled;
    an explicit empty tuple is rejected — it would match nothing.
    """

    instance_type: str = "p3.2xlarge"
    any_of: Optional[Tuple[PlacementFilter, ...]] = None
    exclude_zones: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        _require(
            bool(self.instance_type),
            "resources.instance_type must be a non-empty string",
        )
        if self.any_of is not None:
            _require(
                len(self.any_of) > 0,
                "resources.any_of is empty — it would match no zones; "
                "omit the field to allow every zone of the trace, or add "
                "at least one {cloud|region|zone} filter",
            )

    def allows(self, cloud: str, region: str, zone: str) -> bool:
        if zone in self.exclude_zones:
            return False
        if self.any_of is None:
            return True
        return any(f.matches(cloud, region, zone) for f in self.any_of)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"instance_type": self.instance_type}
        if self.any_of is not None:
            out["any_of"] = [f.to_dict() for f in self.any_of]
        if self.exclude_zones:
            out["exclude_zones"] = list(self.exclude_zones)
        return out


# ---------------------------------------------------------------------------
# Replica policy (SpotHedge + baselines)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicaPolicySpec:
    """Which placement policy manages the fleet, and its knobs.

    ``overprovision`` / ``dynamic_fallback`` / ``min_ondemand`` are the
    paper's §3 knobs (``N_Extra``, Dynamic Fallback, the §4 custom-policy
    on-demand floor); they map onto SpotHedge-family constructor args.
    ``args`` passes any further keyword verbatim to the policy constructor
    (e.g. ``od_fraction`` for ``static_mixture``).
    """

    name: str = "spothedge"
    overprovision: Optional[int] = None
    dynamic_fallback: Optional[bool] = None
    min_ondemand: Optional[int] = None
    args: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        _require(bool(self.name), "replica_policy.name must be set")
        if self.overprovision is not None:
            _require(
                self.overprovision >= 0,
                f"replica_policy.overprovision must be >= 0, "
                f"got {self.overprovision}",
            )
        if self.min_ondemand is not None:
            _require(
                self.min_ondemand >= 0,
                f"replica_policy.min_ondemand must be >= 0, "
                f"got {self.min_ondemand}",
            )

    def policy_kwargs(self) -> Dict[str, Any]:
        """Constructor kwargs for ``make_policy`` (set fields only)."""
        kw: Dict[str, Any] = dict(self.args)
        if self.overprovision is not None:
            kw["num_overprovision"] = self.overprovision
        if self.dynamic_fallback is not None:
            kw["dynamic_ondemand_fallback"] = self.dynamic_fallback
        if self.min_ondemand is not None:
            kw["min_ondemand"] = self.min_ondemand
        return kw

    def to_dict(self) -> Dict[str, Any]:
        out = _clean(
            {
                "name": self.name,
                "overprovision": self.overprovision,
                "dynamic_fallback": self.dynamic_fallback,
                "min_ondemand": self.min_ondemand,
            }
        )
        if self.args:
            out["args"] = dict(self.args)
        return out


# ---------------------------------------------------------------------------
# Autoscaler
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AutoscalerSpec:
    """``kind="constant"`` pins N_Tar to ``target``; ``kind="load"`` is the
    paper's QPS autoscaler with hysteresis, with ``target`` as the initial
    N_Tar."""

    kind: str = "constant"
    target: int = 4
    qps_per_replica: float = 0.8
    min_replicas: int = 1
    max_replicas: int = 12
    window_s: float = 60.0
    upscale_delay_s: float = 300.0
    downscale_delay_s: float = 1200.0

    def __post_init__(self) -> None:
        _require(
            self.kind in ("constant", "load"),
            f"autoscaler.kind must be 'constant' or 'load', "
            f"got {self.kind!r}",
        )
        _require(
            self.target >= 0,
            f"autoscaler.target must be >= 0, got {self.target}",
        )
        _require(
            self.qps_per_replica > 0,
            f"autoscaler.qps_per_replica must be positive, "
            f"got {self.qps_per_replica}",
        )
        _require(
            0 < self.min_replicas <= self.max_replicas,
            f"autoscaler replica bounds invalid: need "
            f"0 < min_replicas <= max_replicas, got "
            f"[{self.min_replicas}, {self.max_replicas}]",
        )
        if self.kind == "load":
            _require(
                self.min_replicas <= self.target <= self.max_replicas,
                f"autoscaler.target (initial N_Tar) must lie within "
                f"[min_replicas, max_replicas] = "
                f"[{self.min_replicas}, {self.max_replicas}] for "
                f"kind='load', got {self.target}",
            )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------


WORKLOAD_KINDS = ("poisson", "arena", "maf", "none")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Request arrival process.  ``kind="none"`` runs the control plane
    against the trace with no request path (availability/cost only — the
    Fig. 14 setting)."""

    kind: str = "poisson"
    rate_per_s: float = 0.5
    seed: int = 0
    args: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        _require(
            self.kind in WORKLOAD_KINDS,
            f"workload.kind must be one of {list(WORKLOAD_KINDS)}, "
            f"got {self.kind!r}",
        )
        _require(
            self.rate_per_s > 0,
            f"workload.rate_per_s must be positive, got {self.rate_per_s}",
        )

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "kind": self.kind,
            "rate_per_s": self.rate_per_s,
            "seed": self.seed,
        }
        if self.args:
            out["args"] = dict(self.args)
        return out


# ---------------------------------------------------------------------------
# Latency source (roofline vs. measured kernel profiles)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencySpec:
    """Where replica service times come from.

    ``source="roofline"`` (default) prices requests with the analytic
    hardware model — the historical behaviour, byte-identical golden
    metrics.  ``source="profile"`` loads a ``repro.profiles`` step-time
    table and uses the kernel-measured MFU/MBU for this (model,
    accelerator) pair; when no matching profile entry exists the run
    warns and falls back to the roofline, so specs stay portable.
    ``profile`` points at a table JSON or a directory of them
    (default: ``artifacts/profiles/``).
    """

    source: str = "roofline"
    profile: Optional[str] = None

    def __post_init__(self) -> None:
        # single source of truth for valid sources is the serving layer
        # (deferred import keeps spec module import cheap)
        from repro.serving.latency import LATENCY_SOURCES

        _require(
            self.source in LATENCY_SOURCES,
            f"latency.source must be one of {list(LATENCY_SOURCES)}, "
            f"got {self.source!r}",
        )
        if self.profile is not None:
            _require(
                bool(self.profile),
                "latency.profile must be a non-empty path when set",
            )

    def to_dict(self) -> Dict[str, Any]:
        return _clean({"source": self.source, "profile": self.profile})


# ---------------------------------------------------------------------------
# Forecasting (spot-availability predictors, repro.forecast)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ForecastSpec:
    """Which spot-availability forecaster a risk-aware policy consults.

    The section configures forecast-consuming policies (those declaring
    ``uses_forecast``, e.g. ``risk_spothedge``); other policies ignore it,
    so a sweep can mix risk-aware and vanilla cells under one spec.

    ``name`` picks the estimator (``persistence`` / ``ewma`` /
    ``markov``); ``horizon_s`` is the look-ahead the policy prices risk
    over; ``risk_threshold`` / ``calm_threshold`` bound the surge and
    trim regimes of :class:`repro.core.risk_aware.RiskAwareSpotHedgePolicy`;
    ``args`` passes further keywords verbatim to the forecaster
    constructor (e.g. ``smoothing`` for ``markov``).
    """

    name: str = "markov"
    horizon_s: Optional[float] = None
    risk_threshold: Optional[float] = None
    calm_threshold: Optional[float] = None
    args: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        _require(bool(self.name), "forecast.name must be set")
        if self.horizon_s is not None:
            _require(
                self.horizon_s > 0,
                f"forecast.horizon_s must be positive, got {self.horizon_s}",
            )
        for field in ("risk_threshold", "calm_threshold"):
            v = getattr(self, field)
            if v is not None:
                _require(
                    0.0 <= v <= 1.0,
                    f"forecast.{field} must be a probability, got {v}",
                )

    def policy_kwargs(self) -> Dict[str, Any]:
        """Constructor kwargs for a forecast-consuming policy."""
        kw: Dict[str, Any] = {"forecaster": self.name}
        if self.args:
            kw["forecaster_args"] = dict(self.args)
        if self.horizon_s is not None:
            kw["horizon_s"] = self.horizon_s
        if self.risk_threshold is not None:
            kw["risk_threshold"] = self.risk_threshold
        if self.calm_threshold is not None:
            kw["calm_threshold"] = self.calm_threshold
        return kw

    def to_dict(self) -> Dict[str, Any]:
        out = _clean(
            {
                "name": self.name,
                "horizon_s": self.horizon_s,
                "risk_threshold": self.risk_threshold,
                "calm_threshold": self.calm_threshold,
            }
        )
        if self.args:
            out["args"] = dict(self.args)
        return out


# ---------------------------------------------------------------------------
# Serving data plane (token-level continuous batching, SLOs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Token-level service-level objectives: TTFT and TPOT targets.

    A request attains the SLO when its time-to-first-token and its mean
    time-per-output-token are both within target; goodput is the
    throughput of attaining requests (``repro.serving.token.metrics``).
    """

    ttft_s: float = 10.0
    tpot_s: float = 0.2

    def __post_init__(self) -> None:
        _require(
            self.ttft_s > 0,
            f"serving.slo.ttft_s must be positive, got {self.ttft_s}",
        )
        _require(
            self.tpot_s > 0,
            f"serving.slo.tpot_s must be positive, got {self.tpot_s}",
        )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Replica data-plane knobs (shared by both serving engines).

    ``concurrency_cap`` bounds the *request-level* model's model-derived
    concurrency default (``min(max_concurrency(), cap)`` when
    ``sim.concurrency`` is null) — historically a hardcoded 16.  The
    remaining fields configure the *token-level* engine selected by
    ``sim.replica_model: token``: the SLO targets, the per-iteration
    chunked-prefill budget, optional batch-size / KV-budget caps (the KV
    budget otherwise derives from the latency model's HBM arithmetic),
    a per-iteration scheduler overhead, and the goodput window.  In YAML
    the section also accepts ``replica_model`` as sugar for
    ``sim.replica_model``.
    """

    slo: SLOSpec = dataclasses.field(default_factory=SLOSpec)
    concurrency_cap: int = 16
    prefill_chunk_tokens: int = 512
    max_batch: Optional[int] = None
    kv_budget_tokens: Optional[int] = None
    iter_overhead_s: float = 0.0
    goodput_window_s: float = 60.0

    def __post_init__(self) -> None:
        _require(
            self.concurrency_cap >= 1,
            f"serving.concurrency_cap must be >= 1, "
            f"got {self.concurrency_cap}",
        )
        _require(
            self.prefill_chunk_tokens >= 1,
            f"serving.prefill_chunk_tokens must be >= 1, "
            f"got {self.prefill_chunk_tokens}",
        )
        if self.max_batch is not None:
            _require(
                self.max_batch >= 1,
                f"serving.max_batch must be >= 1, got {self.max_batch}",
            )
        if self.kv_budget_tokens is not None:
            _require(
                self.kv_budget_tokens >= 1,
                f"serving.kv_budget_tokens must be >= 1, "
                f"got {self.kv_budget_tokens}",
            )
        _require(
            self.iter_overhead_s >= 0,
            f"serving.iter_overhead_s must be >= 0, "
            f"got {self.iter_overhead_s}",
        )
        _require(
            self.goodput_window_s > 0,
            f"serving.goodput_window_s must be positive, "
            f"got {self.goodput_window_s}",
        )

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "slo": self.slo.to_dict(),
            "concurrency_cap": self.concurrency_cap,
            "prefill_chunk_tokens": self.prefill_chunk_tokens,
            "iter_overhead_s": self.iter_overhead_s,
            "goodput_window_s": self.goodput_window_s,
        }
        if self.max_batch is not None:
            out["max_batch"] = self.max_batch
        if self.kv_budget_tokens is not None:
            out["kv_budget_tokens"] = self.kv_budget_tokens
        return out


# ---------------------------------------------------------------------------
# Observability (repro.obs: event tracing, metrics, artifact export)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOBurnSpec:
    """Burn-rate alerting knobs (``observability.slo_burn``).

    ``target`` is the SLO attainment target whose error budget the
    burn rates are measured against; ``fast_window_s``/``slow_window_s``
    are the trailing horizons and ``fast_threshold``/``slow_threshold``
    the multi-window alert thresholds (SRE-workbook defaults: 5 min at
    14.4× plus 1 h at 6×).
    """

    target: float = 0.99
    fast_window_s: float = 300.0
    slow_window_s: float = 3600.0
    fast_threshold: float = 14.4
    slow_threshold: float = 6.0

    def __post_init__(self) -> None:
        _require(
            0.0 < self.target < 1.0,
            f"observability.slo_burn.target must be in (0, 1), "
            f"got {self.target}",
        )
        _require(
            0 < self.fast_window_s <= self.slow_window_s,
            "observability.slo_burn windows must be positive with "
            "fast_window_s <= slow_window_s",
        )
        _require(
            self.fast_threshold > 0 and self.slow_threshold > 0,
            "observability.slo_burn thresholds must be positive",
        )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ObservabilitySpec:
    """What the run records and exports (``repro.obs``).

    ``detail`` gates recording cost: ``off`` records nothing,
    ``decisions`` (default) records control-plane events (policy
    decisions with reasons, replica lifecycle, preemption warnings,
    migration plans) plus registry metrics and sampled request spans,
    and ``full`` adds windowed data-plane samples and SLO burn-rate
    events every ``window_s`` seconds and enables artifact export.  At
    detail ``full`` the :class:`repro.service.Service` facade writes a
    schema-v1 event log (``jsonl``), a span log
    (``<name>.spans.jsonl``) and a Perfetto-loadable timeline
    (``chrome_trace``) under ``out_dir``.  ``trace_sample`` is the
    deterministic per-request span sampling rate (keyed on the request
    run ordinal — no RNG, identical sampled sets in every engine);
    ``slo_burn`` configures the burn-rate monitor.  Recording never
    changes metrics — golden results are byte-identical at every
    detail level.
    """

    detail: str = "decisions"
    out_dir: str = "artifacts/obs"
    jsonl: bool = True
    chrome_trace: bool = True
    window_s: float = 60.0
    trace_sample: float = 0.01
    slo_burn: SLOBurnSpec = dataclasses.field(default_factory=SLOBurnSpec)

    def __post_init__(self) -> None:
        # single source of truth for valid levels is the obs layer
        # (deferred import keeps spec module import cheap)
        from repro.obs.recorder import DETAIL_LEVELS

        _require(
            self.detail in DETAIL_LEVELS,
            f"observability.detail must be one of {list(DETAIL_LEVELS)}, "
            f"got {self.detail!r}",
        )
        _require(
            bool(self.out_dir),
            "observability.out_dir must be a non-empty path",
        )
        _require(
            self.window_s > 0,
            f"observability.window_s must be positive, got {self.window_s}",
        )
        _require(
            0.0 <= self.trace_sample <= 1.0,
            f"observability.trace_sample must be in [0, 1], "
            f"got {self.trace_sample}",
        )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Simulation horizon / fabric knobs
# ---------------------------------------------------------------------------


ENGINE_NAMES = ("vector", "legacy", "jax")


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """Simulation fabric: horizon, cold start, control cadence, SLO.

    ``engine`` picks the serving hot path: ``"vector"`` (default) is the
    NumPy array engine in ``repro.serving.engine``; ``"legacy"`` is the
    per-request object simulator in ``repro.serving.sim``; ``"jax"`` is
    the two-phase jit/vmap engine in ``repro.serving.jaxengine`` that
    compiles the request-model data plane with ``lax.scan`` and batches
    whole scenario matrices with ``vmap`` (token-model cells fall back
    to the vector data plane).  All three are decision-for-decision
    equivalent (see ``tests/test_differential.py`` and
    ``tests/test_jax_engine.py``); they differ only in throughput.

    ``replica_model`` picks how a replica prices work: ``"request"``
    (default) is the M/G/c model with frozen per-request service times;
    ``"token"`` is the iteration-level continuous-batching model in
    ``repro.serving.token`` (KV-budget admission, chunked prefill,
    batch-dependent decode steps, TTFT/TPOT/goodput metrics).  Both
    engines support both models; token-mode knobs live in the
    ``serving:`` section.
    """

    duration_hours: float = 4.0
    cold_start_s: float = 183.0
    control_interval_s: float = 15.0
    timeout_s: float = 100.0
    sub_step_s: float = 1.0
    concurrency: Optional[int] = 4
    drain_s: float = 600.0        # stop generating arrivals this long
    # before the horizon so in-flight work can finish
    warning_enabled: bool = True
    # override the cloud's advance-warning lead time (s) for this run's
    # trace (None -> the catalog per-cloud default: 120 s AWS, 30 s GCP)
    preemption_warning_s: Optional[float] = None
    seed: int = 0
    record_series: bool = True
    engine: str = "vector"
    replica_model: str = "request"

    def __post_init__(self) -> None:
        _require(
            self.engine in ENGINE_NAMES,
            f"sim.engine must be one of {list(ENGINE_NAMES)}, "
            f"got {self.engine!r}",
        )
        # single source of truth for valid models is the serving layer
        # (deferred import keeps spec module import cheap)
        from repro.serving.sim import REPLICA_MODELS

        _require(
            self.replica_model in REPLICA_MODELS,
            f"sim.replica_model must be one of "
            f"{list(REPLICA_MODELS)}, got {self.replica_model!r}",
        )
        _require(
            self.duration_hours > 0,
            f"sim.duration_hours must be positive, got {self.duration_hours}",
        )
        _require(
            self.cold_start_s >= 0,
            f"sim.cold_start_s must be >= 0, got {self.cold_start_s}",
        )
        _require(
            self.control_interval_s > 0,
            f"sim.control_interval_s must be positive, "
            f"got {self.control_interval_s}",
        )
        _require(
            self.timeout_s > 0,
            f"sim.timeout_s must be positive, got {self.timeout_s}",
        )
        _require(
            self.sub_step_s > 0,
            f"sim.sub_step_s must be positive, got {self.sub_step_s}",
        )
        _require(
            self.drain_s >= 0,
            f"sim.drain_s must be >= 0, got {self.drain_s}",
        )
        if self.concurrency is not None:
            _require(
                self.concurrency > 0,
                f"sim.concurrency must be positive, got {self.concurrency}",
            )
        if self.preemption_warning_s is not None:
            _require(
                self.preemption_warning_s >= 0,
                f"sim.preemption_warning_s must be >= 0, "
                f"got {self.preemption_warning_s}",
            )

    @property
    def duration_s(self) -> float:
        return self.duration_hours * 3600.0

    def to_dict(self) -> Dict[str, Any]:
        # keep explicit None (concurrency: null == model-derived) so the
        # dict round-trips exactly
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Sweep (scenario grid) — consumed by repro.experiments.ScenarioSuite
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A scenario grid: ``policies × traces × workloads × seeds``
    (× ``forecasters`` / ``replica_models`` when those axes are set).

    Every axis left empty falls back to the base spec's single value, so a
    spec with ``sweep: {}`` expands to exactly one scenario.  Seeds
    override ``workload.seed`` per cell — the standard way to get
    replicated measurements of one configuration.  Forecaster entries
    override ``forecast.name`` per cell (vanilla policies in the same
    grid ignore the section, so predictor × policy backtests compose).
    Replica-model entries override ``sim.replica_model`` per cell, so a
    request-level vs token-level comparison replays one request tape.

        sweep:
          policies: [spothedge, risk_spothedge, ondemand_only]
          traces: [aws-1, gcp-1]
          workloads: [poisson, arena]
          seeds: [0, 1, 2]
          forecasters: [persistence, markov]
          replica_models: [request, token]
    """

    policies: Tuple[ReplicaPolicySpec, ...] = ()
    traces: Tuple[str, ...] = ()
    workloads: Tuple["WorkloadSpec", ...] = ()
    seeds: Tuple[int, ...] = ()
    forecasters: Tuple[str, ...] = ()
    replica_models: Tuple[str, ...] = ()
    # migration axis: each entry is a bool (toggle the base spec's
    # migration section on/off) or a full MigrationSpec override — the
    # A/B axis behind benchmarks/migration.py
    migration: Tuple[Union[bool, MigrationSpec], ...] = ()

    def __post_init__(self) -> None:
        for m in self.migration:
            _require(
                isinstance(m, (bool, MigrationSpec)),
                "sweep.migration entries must be booleans or migration "
                f"mappings, got {m!r}",
            )
        for tr in self.traces:
            _require(
                bool(tr), "sweep.traces entries must be non-empty strings"
            )
        for s in self.seeds:
            _require(
                isinstance(s, int) and not isinstance(s, bool),
                f"sweep.seeds entries must be ints, got {s!r}",
            )
        for fc in self.forecasters:
            _require(
                bool(fc),
                "sweep.forecasters entries must be non-empty strings",
            )
        if self.replica_models:
            from repro.serving.sim import REPLICA_MODELS

            for rm in self.replica_models:
                _require(
                    rm in REPLICA_MODELS,
                    f"sweep.replica_models entries must be one of "
                    f"{list(REPLICA_MODELS)}, got {rm!r}",
                )

    @property
    def size(self) -> int:
        """Number of scenarios the grid expands to (axes default to 1)."""
        return (
            max(len(self.policies), 1)
            * max(len(self.traces), 1)
            * max(len(self.workloads), 1)
            * max(len(self.seeds), 1)
            * max(len(self.forecasters), 1)
            * max(len(self.replica_models), 1)
            * max(len(self.migration), 1)
        )

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        if self.policies:
            out["policies"] = [p.to_dict() for p in self.policies]
        if self.traces:
            out["traces"] = list(self.traces)
        if self.workloads:
            out["workloads"] = [w.to_dict() for w in self.workloads]
        if self.seeds:
            out["seeds"] = list(self.seeds)
        if self.forecasters:
            out["forecasters"] = list(self.forecasters)
        if self.replica_models:
            out["replica_models"] = list(self.replica_models)
        if self.migration:
            out["migration"] = [
                m if isinstance(m, bool) else m.to_dict()
                for m in self.migration
            ]
        return out


# ---------------------------------------------------------------------------
# The service spec
# ---------------------------------------------------------------------------


LB_NAMES = ("least_loaded", "round_robin")


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """The complete declarative description of one service run."""

    name: str = "service"
    model: str = "llama3.2-1b"
    trace: str = "aws-3"
    resources: ResourceSpec = dataclasses.field(default_factory=ResourceSpec)
    replica_policy: ReplicaPolicySpec = dataclasses.field(
        default_factory=ReplicaPolicySpec
    )
    autoscaler: AutoscalerSpec = dataclasses.field(
        default_factory=AutoscalerSpec
    )
    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    latency: LatencySpec = dataclasses.field(default_factory=LatencySpec)
    forecast: Optional[ForecastSpec] = None
    serving: ServingSpec = dataclasses.field(default_factory=ServingSpec)
    observability: ObservabilitySpec = dataclasses.field(
        default_factory=ObservabilitySpec
    )
    migration: Optional[MigrationSpec] = None
    sim: SimSpec = dataclasses.field(default_factory=SimSpec)
    load_balancer: str = "least_loaded"
    sweep: Optional[SweepSpec] = None

    def __post_init__(self) -> None:
        _require(bool(self.name), "service.name must be set")
        _require(bool(self.model), "service.model must be set")
        _require(bool(self.trace), "service.trace must be set")
        _require(
            self.load_balancer in LB_NAMES,
            f"service.load_balancer must be one of {list(LB_NAMES)}, "
            f"got {self.load_balancer!r}",
        )
        if self.migration is not None and self.migration.enabled:
            token_ok = self.sim.replica_model == "token" or (
                self.sweep is not None
                and "token" in self.sweep.replica_models
            )
            _require(
                token_ok,
                "migration.enabled requires the token-level engine: set "
                "sim.replica_model: token (or sweep over replica_models "
                "including 'token') — the request-level model has no KV "
                "state to migrate",
            )

    # -- cross-registry validation (deferred imports keep this cheap) -----
    def validate(self) -> "ServiceSpec":
        """Check fields against the live registries (policies, archs,
        instance types, named traces).  Returns self for chaining."""
        from repro.cluster.catalog import default_catalog
        from repro.cluster.traces import TraceLibrary
        from repro.configs import ARCH_IDS
        from repro.core.policy import registered_policies
        from repro.forecast.base import registered_forecasters

        policies = registered_policies()
        _require(
            self.replica_policy.name in policies,
            f"unknown replica_policy.name {self.replica_policy.name!r}; "
            f"registered policies: {policies}",
        )
        forecasters = registered_forecasters()
        if self.forecast is not None:
            _require(
                self.forecast.name in forecasters,
                f"unknown forecast.name {self.forecast.name!r}; "
                f"registered forecasters: {forecasters}",
            )
        if self.sweep is not None:
            for p in self.sweep.policies:
                _require(
                    p.name in policies,
                    f"unknown sweep policy {p.name!r}; "
                    f"registered policies: {policies}",
                )
            for fc in self.sweep.forecasters:
                _require(
                    fc in forecasters,
                    f"unknown sweep forecaster {fc!r}; "
                    f"registered forecasters: {forecasters}",
                )
            names = TraceLibrary().names()
            for tr in self.sweep.traces:
                _require(
                    tr in names or tr.endswith((".json", ".npz")),
                    f"unknown sweep trace {tr!r}; named datasets: {names} "
                    "(or pass a .json/.npz trace file path)",
                )
        _require(
            self.model in ARCH_IDS,
            f"unknown model {self.model!r}; available: {ARCH_IDS}",
        )
        catalog = default_catalog()
        try:
            catalog.instance_type(self.resources.instance_type)
        except KeyError:
            known = sorted(t.name for t in catalog.instance_types)
            raise SpecError(
                f"unknown resources.instance_type "
                f"{self.resources.instance_type!r}; catalog has {known}"
            ) from None
        is_file = self.trace.endswith((".json", ".npz"))
        if not is_file:
            names = TraceLibrary().names()
            _require(
                self.trace in names,
                f"unknown trace {self.trace!r}; named datasets: {names} "
                "(or pass a .json/.npz trace file path)",
            )
        return self

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out = {
            "name": self.name,
            "model": self.model,
            "trace": self.trace,
            "resources": self.resources.to_dict(),
            "replica_policy": self.replica_policy.to_dict(),
            "autoscaler": self.autoscaler.to_dict(),
            "workload": self.workload.to_dict(),
            "latency": self.latency.to_dict(),
            "serving": self.serving.to_dict(),
            "observability": self.observability.to_dict(),
            "sim": self.sim.to_dict(),
            "load_balancer": self.load_balancer,
        }
        if self.forecast is not None:
            out["forecast"] = self.forecast.to_dict()
        if self.migration is not None:
            out["migration"] = self.migration.to_dict()
        if self.sweep is not None:
            out["sweep"] = self.sweep.to_dict()
        return out

"""repro — a production-grade JAX reproduction of SkyServe / SpotHedge.

    SkyServe: Serving AI Models across Regions and Clouds with Spot Instances
    (Mao, Xia, Wu, Chiang, Griggs, Bhardwaj, Yang, Shenker, Stoica — EuroSys'25)

Package layout
--------------
``repro.core``         SpotHedge policy (Alg. 1 + Dynamic Fallback), baselines,
                       the load-based autoscaler and the Omniscient ILP oracle.
``repro.cluster``      Multi-cloud substrate: zone/region/cloud catalog with
                       Table-1 pricing, spot-obtainability traces, instance
                       lifecycle FSM and the discrete-event simulator.
``repro.workloads``    Request arrival processes (Poisson / Arena / MAF).
``repro.models``       The 10 assigned architectures as composable JAX modules.
``repro.distributed``  Sharding rules, checkpointing, ZeRO-1, elastic re-mesh,
                       gradient compression.
``repro.serving``      The JAX data plane: inference engine, replicas, load
                       balancer, service controller.
``repro.service``      The declarative front door: ``ServiceSpec`` (paper
                       Listing 1) -> loader -> builder -> ``Service.run()``.
``repro.training``     Optimizer + train-step factory (remat, microbatching).
``repro.kernels``      Pallas TPU kernels (flash attention, flash decode,
                       selective scan, MoE grouped matmul) + jnp oracles.
``repro.configs``      One config per assigned architecture + shape suite.
``repro.launch``       Production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"

"""Train-step factory: value_and_grad + microbatched gradient accumulation +
optional int8 error-feedback gradient compression + AdamW.

``make_train_step`` returns a pure function suitable for ``jax.jit`` /
pjit with explicit in/out shardings (the launch layer supplies those).
Per-layer rematerialization is handled inside the models (``remat=True``
checkpoints each scanned block).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
)


def make_loss_fn(model, cfg: ModelConfig) -> Callable:
    """batch: {"tokens": (B,S), "labels": (B,S)[, "frames"/"patches"]}"""
    def loss_fn(params, batch):
        if cfg.is_encdec:
            return model.loss(
                params, batch["frames"], batch["tokens"], batch["labels"]
            )
        prefix = batch.get("patches")
        return model.loss(
            params, batch["tokens"], batch["labels"], prefix_embed=prefix
        )
    return loss_fn


def make_train_step(
    model,
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    compress_grads: bool = False,
    grad_specs: Any = None,   # PartitionSpec tree: ZeRO-2 grad accumulator
    batch_spec: Any = None,   # PartitionSpec of the batch axis (see below)
    grad_accum: str = "f32_sharded",   # or "bf16_local" (see §Perf 4)
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).

    With ``microbatches > 1`` the global batch is split along axis 0 and
    gradients are accumulated in fp32 through a ``lax.scan`` — per-step live
    activation memory scales with the microbatch, the standard trick for
    fitting train_4k's global_batch=256.

    With ``compress_grads`` the accumulated gradient is passed through the
    int8 error-feedback quantizer (``repro.distributed.compression``): on a
    multi-pod mesh XLA then moves int8, not fp32, across the pod axis for
    the gradient all-reduce; the quantization error is carried in opt_state
    and re-injected next step.
    """
    loss_fn = make_loss_fn(model, cfg)

    def compute_grads(params, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (
                f"batch {b} not divisible by microbatches {microbatches}"
            )
            y = x.reshape((microbatches, b // microbatches) + x.shape[1:])
            if batch_spec is not None:
                # keep the batch dim sharded through the reshape — without
                # this XLA's SPMD partitioner falls back to "involuntary
                # full rematerialization" (replicate + repartition) on the
                # microbatch dynamic-slice.  §Perf iteration 2.
                from jax.sharding import PartitionSpec as P

                spec = P(None, *tuple(batch_spec))
                y = jax.lax.with_sharding_constraint(
                    y, P(*(spec[: y.ndim]))
                )
            return y

        mb = jax.tree_util.tree_map(split, batch)

        def constrain(tree):
            if grad_specs is None:
                return tree
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, tree, grad_specs
            )

        # Two accumulation strategies (§Perf iterations 2/4):
        #   f32_sharded — the accumulator is fp32 and ZeRO-2-sharded over
        #     ('data', TP-axes); each microbatch reduce-scatters into the
        #     shard.  Minimal memory, µb× collective traffic.
        #   bf16_local — the accumulator is bf16 and left unconstrained;
        #     XLA defers the data-axis reduction across the whole scan
        #     (gradient linearity), paying ONE all-reduce/reduce-scatter
        #     per step.  ~2× accumulator memory vs f32_sharded, ~µb× less
        #     collective traffic.  Pick per-cell by its dominant term.
        acc_dtype = (
            jnp.bfloat16 if grad_accum == "bf16_local" else jnp.float32
        )
        step_constrain = (
            (lambda t: t) if grad_accum == "bf16_local" else constrain
        )

        def acc_step(carry, mbatch):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
            grad_acc = step_constrain(jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dtype), grad_acc, grads
            ))
            return (loss_acc + loss, grad_acc), None

        zero_grads = step_constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params
        ))
        (loss_sum, grad_sum), _ = jax.lax.scan(
            acc_step, (jnp.zeros((), jnp.float32), zero_grads), mb
        )
        inv = 1.0 / microbatches
        grad_sum = constrain(jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * inv, grad_sum
        ))
        return loss_sum * inv, grad_sum

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if compress_grads:
            from repro.distributed.compression import ef_quantize_tree

            grads, new_err = ef_quantize_tree(
                grads, opt_state.get("ef_error")
            )
        new_params, new_opt = adamw_update(opt_cfg, grads, opt_state, params)
        if compress_grads:
            new_opt["ef_error"] = new_err
        metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "step": new_opt["step"],
        }
        return new_params, new_opt, metrics

    return train_step

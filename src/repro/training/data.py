"""Synthetic sharded data pipeline.

Deterministic token streams keyed by (seed, step, host) — every host
generates only its shard of the global batch, so the pipeline needs no
cross-host I/O and scales to any pod count.  Real deployments swap
``synthetic_batches`` for a tokenized corpus reader with the same contract.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def make_batch(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    step: int = 0,
    dtype=jnp.bfloat16,
) -> Dict[str, Any]:
    """One synthetic batch with next-token labels (and frontend stubs)."""
    rng = np.random.default_rng((seed, step))
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq + 1),
                        dtype=np.int32)
    out: Dict[str, Any] = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.frontend_seq, cfg.d_model),
                                dtype=np.float32),
            dtype=dtype,
        )
    elif cfg.frontend:  # vision stub
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.frontend_seq, cfg.d_model),
                                dtype=np.float32),
            dtype=dtype,
        )
    return out


def synthetic_batches(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    start_step: int = 0,
) -> Iterator[Dict[str, Any]]:
    step = start_step
    while True:
        yield make_batch(cfg, batch, seq, seed=seed, step=step)
        step += 1


def abstract_batch(
    cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for dry-run lowering."""
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), dtype
        )
    elif cfg.frontend:
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), dtype
        )
    return out

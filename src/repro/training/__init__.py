"""Training substrate: AdamW + ZeRO-1, train-step factory, data pipeline."""

from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_loop import make_train_step
from repro.training.data import synthetic_batches

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "make_train_step",
    "synthetic_batches",
]

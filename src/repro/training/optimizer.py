"""AdamW in pure JAX with ZeRO-1 optimizer-state sharding.

The optimizer state (m, v) mirrors the parameter pytree.  ZeRO-1: each
(m, v) leaf additionally shards its *first replicated* dimension over the
``data`` mesh axis when divisible — parameters stay TP-sharded/replicated
for the forward pass while optimizer memory scales down with DP size.
``zero1_logical`` rewrites a parameter's logical axes into the optimizer
state's logical axes; the launch layer feeds both through the same rule
table.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Any) -> Any:
    """opt_state = {m, v, step}."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (s - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    opt_state: Any,
    params: Any,
) -> Tuple[Any, Any]:
    """Returns (new_params, new_opt_state).  Gradients are clipped by global
    norm; weight decay is decoupled."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# ZeRO-1 logical axes
# ---------------------------------------------------------------------------


def zero1_logical(logical: Sequence[Optional[str]],
                  shape: Sequence[int],
                  data_size: int) -> Tuple[Optional[str], ...]:
    """Optimizer-state logical axes for a parameter.

    The first dimension that is (a) not already sharded by a TP rule under
    the standard tables ('heads', 'mlp', 'vocab', 'experts', 'ssm_inner',
    'kv_heads') and (b) divisible by the data-axis size gets the 'zero'
    logical axis (mapped to 'data' by the rule table)."""
    tp_axes = {"heads", "kv_heads", "mlp", "vocab", "experts", "ssm_inner"}
    out = list(logical)
    for i, (name, dim) in enumerate(zip(logical, shape)):
        if name in tp_axes:
            continue
        if dim % max(data_size, 1) == 0 and dim >= data_size > 1:
            out[i] = "zero"
            break
    return tuple(out)


def zero1_logical_tree(logical_tree: Any, abstract_tree: Any,
                       data_size: int) -> Any:
    is_logical = lambda x: (  # noqa: E731
        isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x)
    )
    return jax.tree_util.tree_map(
        lambda logical, ab: zero1_logical(logical, ab.shape, data_size),
        logical_tree,
        abstract_tree,
        is_leaf=is_logical,
    )

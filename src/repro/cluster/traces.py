"""Spot-obtainability traces: replay format + correlated synthetic generator.

The paper's §5.2 replays *real* spot traces (AWS 1/2/3, GCP 1 from [71]):
each timestamp records, per zone, whether spot capacity was obtainable while
maintaining a desired number of instances.  We encode a trace as an integer
capacity matrix ``cap[T, Z]`` — the number of spot instances launchable in
zone ``z`` during step ``t`` — with a step duration ``dt`` in seconds.

Because the original trace files are not redistributable here, we provide a
**statistically faithful synthetic generator** that reproduces the paper's
documented structure:

* Fig. 3: preemptions are *correlated within a region* (Pearson r >= 0.3 for
  sibling zones) and nearly independent across regions.  We generate a
  region-level 2-state Markov process (available / crunch) and modulate
  per-zone Markov chains by the regional state.
* Fig. 4: spot GPUs are far more volatile (16.7–90.4% available) than spot
  CPUs (95.6–99.9%).
* §2.2: whole-region dropouts happen (AWS 2 sees 33.1% of time with *all*
  zones of one region unobtainable; us-west-2 21% in §5.1).

Each named dataset (``aws-1`` … ``gcp-1``) is produced with a fixed seed, so
every benchmark run replays the same "recorded" trace — exactly how the
paper's artifact replays its pickled traces.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Trace container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpotTrace:
    """Per-zone spot capacity over time.

    cap[t, z]  — integer launchable spot capacity in zone ``zones[z]``
                 during step ``t``  (0 == unobtainable; preempt running spot).
    dt         — seconds per step.
    """

    zones: Tuple[str, ...]
    cap: np.ndarray           # int32 [T, Z]
    dt: float
    name: str = "trace"
    # Optional override of the *cloud's* advance-preemption-warning lead
    # time for runs replaying this trace (None -> use the cloud default).
    # Real trace datasets sometimes come with their own observed lead.
    preemption_warning_s: Optional[float] = None

    def __post_init__(self) -> None:
        self.cap = np.asarray(self.cap, dtype=np.int32)
        if self.cap.ndim != 2 or self.cap.shape[1] != len(self.zones):
            raise ValueError(
                f"cap shape {self.cap.shape} inconsistent with "
                f"{len(self.zones)} zones"
            )
        # zone -> column index; capacity()/capacity_row() sit on the
        # simulator hot path, where a linear zones.index() per call adds up
        self._zone_idx: Dict[str, int] = {
            z: j for j, z in enumerate(self.zones)
        }
        # memoized dense per-tick tensors (dense_ticks); traces are
        # immutable by convention so cached views never go stale
        self._dense_cache: Dict[Tuple, np.ndarray] = {}
        if self.preemption_warning_s is not None:
            w = float(self.preemption_warning_s)
            if not (w >= 0.0):
                raise ValueError(
                    f"preemption_warning_s must be >= 0, got {w!r}"
                )
            self.preemption_warning_s = w

    def zone_index(self, zone: str) -> int:
        try:
            return self._zone_idx[zone]
        except KeyError:
            raise ValueError(
                f"zone {zone!r} not in trace {self.name!r} "
                f"(zones: {list(self.zones)})"
            ) from None

    # -- basic accessors -------------------------------------------------
    @property
    def steps(self) -> int:
        return int(self.cap.shape[0])

    @property
    def duration_s(self) -> float:
        return self.steps * self.dt

    def step_of(self, t: float) -> int:
        return min(int(t / self.dt), self.steps - 1)

    def capacity(self, zone: str, t: float) -> int:
        """Launchable spot capacity C(z, t)."""
        return int(self.cap[self.step_of(t), self._zone_idx[zone]])

    def capacity_row(self, t: float) -> Dict[str, int]:
        row = self.cap[self.step_of(t)]
        return {z: int(c) for z, c in zip(self.zones, row)}

    def dense_ticks(
        self,
        dt: float,
        ticks: int,
        zones: Optional[Sequence[str]] = None,
        offset_s: float = 0.0,
    ) -> np.ndarray:
        """Dense per-tick capacity tensor for a fixed control interval.

        ``out[k, j]`` equals ``capacity(zones[j], k*dt + offset_s)`` for
        every tick ``k < ticks`` — same clamped ``step_of`` indexing and
        the same float arithmetic (``k*dt`` then ``+ offset``) as the
        scalar accessors, so replacing per-tick ``capacity_row`` calls
        with one precomputed tensor is bit-exact.  The simulator run loop
        and the JAX scenario engine both consume these; results are
        memoized (read-only views) since suites replay one trace across
        many cells.
        """
        key = (
            float(dt), int(ticks),
            tuple(zones) if zones is not None else None,
            float(offset_s),
        )
        out = self._dense_cache.get(key)
        if out is None:
            t = np.arange(int(ticks), dtype=np.float64) * float(dt) \
                + float(offset_s)
            idx = np.minimum(
                (t / self.dt).astype(np.int64), self.steps - 1
            )
            cols = (
                np.arange(len(self.zones))
                if zones is None
                else np.array([self.zone_index(z) for z in zones])
            )
            out = self.cap[np.ix_(idx, cols)]
            out.setflags(write=False)
            self._dense_cache[key] = out
        return out

    # -- statistics (used by the Fig. 3 / Fig. 5 benchmarks) -------------
    def availability(self, zone: str) -> float:
        """Fraction of time the zone has any spot capacity."""
        return float((self.cap[:, self.zone_index(zone)] > 0).mean())

    def preemption_indicator(self) -> np.ndarray:
        """bool [T, Z]: step where capacity *dropped* (a preemption event)."""
        drops = np.zeros_like(self.cap, dtype=bool)
        drops[1:] = self.cap[1:] < self.cap[:-1]
        return drops

    def zone_correlation(self, bin_steps: int = 5) -> np.ndarray:
        """Pearson correlation of per-zone preemption indicators (Fig. 3c).

        Indicators are aggregated over ``bin_steps`` windows before
        correlating — the paper's own correlated-preemption statistic is
        "at least one more follows within 5 minutes", i.e. same-window, not
        same-instant (§2.2).
        """
        ind = self.preemption_indicator().astype(np.float64)
        if bin_steps > 1:
            T = (ind.shape[0] // bin_steps) * bin_steps
            ind = (
                ind[:T]
                .reshape(-1, bin_steps, ind.shape[1])
                .max(axis=1)
            )
        Z = ind.shape[1]
        out = np.eye(Z)
        for i in range(Z):
            for j in range(i + 1, Z):
                a, b = ind[:, i], ind[:, j]
                sa, sb = a.std(), b.std()
                if sa == 0 or sb == 0:
                    r = 0.0
                else:
                    r = float(np.corrcoef(a, b)[0, 1])
                out[i, j] = out[j, i] = r
        return out

    def slice_zones(self, zones: Sequence[str]) -> "SpotTrace":
        idx = [self.zone_index(z) for z in zones]
        return SpotTrace(
            zones=tuple(zones),
            cap=self.cap[:, idx].copy(),
            dt=self.dt,
            name=self.name,
            preemption_warning_s=self.preemption_warning_s,
        )

    # -- (de)serialization -------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            cap=self.cap,
            dt=np.float64(self.dt),
            zones=np.array(self.zones, dtype=object),
            name=np.array(self.name, dtype=object),
            # nan encodes "no override" (npz has no native None)
            preemption_warning_s=np.float64(
                np.nan
                if self.preemption_warning_s is None
                else self.preemption_warning_s
            ),
        )

    @staticmethod
    def load(path: str) -> "SpotTrace":
        with np.load(path, allow_pickle=True) as f:
            warn: Optional[float] = None
            if "preemption_warning_s" in f:
                w = float(f["preemption_warning_s"])
                warn = None if np.isnan(w) else w
            return SpotTrace(
                zones=tuple(str(z) for z in f["zones"]),
                cap=f["cap"],
                dt=float(f["dt"]),
                name=str(f["name"]),
                preemption_warning_s=warn,
            )

    @staticmethod
    def from_json(path: str) -> "SpotTrace":
        """Load the simple JSON interchange format.

        {"dt": 60, "zones": ["us-east-1a", ...],
         "cap": [[4, 4, 0], [4, 3, 0], ...]}
        """
        with open(path) as f:
            d = json.load(f)
        warn = d.get("preemption_warning_s")
        return SpotTrace(
            zones=tuple(d["zones"]),
            cap=np.asarray(d["cap"], dtype=np.int32),
            dt=float(d["dt"]),
            name=d.get("name", os.path.basename(path)),
            preemption_warning_s=None if warn is None else float(warn),
        )


def infer_region(zone: str) -> str:
    """Heuristic zone -> region mapping when no catalog is available.

    AWS zones end in a bare letter (``us-west-2a`` -> ``us-west-2``);
    GCP zones end in ``-<letter>`` (``us-central1-a`` -> ``us-central1``).
    Unrecognized names map to themselves (their own failure domain).
    """
    if len(zone) >= 3 and zone[-2] == "-" and zone[-1].isalpha():
        return zone.rsplit("-", 1)[0]
    if len(zone) >= 2 and zone[-1].isalpha() and zone[-2].isdigit():
        return zone[:-1]
    return zone


def trace_stats(trace: SpotTrace) -> Dict[str, object]:
    """The per-zone quantities forecasters and backtests consume.

    For each zone: availability fraction (any capacity), preemption rate
    (capacity-drop events per day), and mean preemption correlation with
    *sibling* zones of the same region (the Fig. 3 statistic).  Computed
    here once instead of being re-derived ad hoc by every benchmark.
    """
    corr = trace.zone_correlation()
    drops = trace.preemption_indicator()
    days = trace.duration_s / 86400.0
    regions = {z: infer_region(z) for z in trace.zones}
    zones: Dict[str, Dict[str, float]] = {}
    for j, z in enumerate(trace.zones):
        sib = [
            i
            for i, other in enumerate(trace.zones)
            if other != z and regions[other] == regions[z]
        ]
        zones[z] = {
            "region": regions[z],
            "availability": round(float(trace.availability(z)), 6),
            "preemptions_per_day": round(
                float(drops[:, j].sum()) / max(days, 1e-9), 4
            ),
            "mean_sibling_corr": round(
                float(np.mean([corr[j, i] for i in sib])) if sib else 0.0, 4
            ),
        }
    return {
        "name": trace.name,
        "steps": trace.steps,
        "dt_s": trace.dt,
        "duration_days": round(days, 3),
        "mean_availability": round(
            float(np.mean([s["availability"] for s in zones.values()])), 6
        ),
        "zones": zones,
    }


# ---------------------------------------------------------------------------
# Synthetic correlated generator
# ---------------------------------------------------------------------------


def _two_state_markov(
    rng: np.random.Generator,
    steps: int,
    p_up_down: float,
    p_down_up: float,
    start_up: bool = True,
) -> np.ndarray:
    """Sample a 2-state Markov chain (1=up, 0=down) of length ``steps``."""
    # Vectorized: draw all uniforms, then scan.  The scan is cheap in numpy
    # for the trace lengths we use (<= ~100k steps).
    u = rng.random(steps)
    out = np.empty(steps, dtype=np.int8)
    s = 1 if start_up else 0
    for t in range(steps):
        if s == 1 and u[t] < p_up_down:
            s = 0
        elif s == 0 and u[t] < p_down_up:
            s = 1
        out[t] = s
    return out


def synth_correlated_trace(
    zones: Sequence[str],
    zone_region: Mapping[str, str],
    *,
    steps: int,
    dt: float = 60.0,
    max_capacity: int = 4,
    # regional crunch process: expected crunch every ~mean_up steps lasting
    # ~mean_down steps.  These defaults give region availability ~70-90%.
    region_mean_up_steps: float = 700.0,
    region_mean_down_steps: float = 120.0,
    # zone-local volatility on top of the regional state
    zone_mean_up_steps: float = 900.0,
    zone_mean_down_steps: float = 45.0,
    region_availability: Optional[Mapping[str, float]] = None,
    # a zone joins a regional crunch with this probability (correlation is
    # strong but not perfect — Fig. 3c reports r ~ 0.3-0.6, not 1.0) ...
    crunch_participation: float = 0.85,
    # ... and with a random onset lag (paper: follow-on preemptions arrive
    # within ~minutes of the first, not the same instant)
    crunch_max_lag_steps: int = 5,
    seed: int = 0,
    name: str = "synthetic",
) -> SpotTrace:
    """Generate a trace with intra-region correlated preemptions (Fig. 3).

    Mechanism: each *region* has a hidden 2-state Markov "capacity crunch"
    process.  When a region is in crunch, all its zones lose capacity
    (simultaneous preemption — the §2.2 correlated-preemption signature).
    Each zone additionally has an independent local Markov process, so zones
    also preempt on their own.  Cross-region correlation is ~0 because the
    regional processes are independent.

    ``region_availability`` optionally biases specific regions (e.g. the
    paper's us-west-2 at ~79% availability).
    """
    rng = np.random.default_rng(seed)
    regions = sorted({zone_region[z] for z in zones})

    region_state: Dict[str, np.ndarray] = {}
    for r in regions:
        avail = (region_availability or {}).get(r)
        if avail is None:
            up, down = region_mean_up_steps, region_mean_down_steps
        else:
            # choose mean sojourn times that hit the requested availability
            # while keeping the crunch length realistic (~2h at dt=60)
            down = region_mean_down_steps
            avail = min(max(avail, 0.01), 0.995)
            up = down * avail / (1.0 - avail)
        region_state[r] = _two_state_markov(
            rng, steps, p_up_down=1.0 / up, p_down_up=1.0 / down
        )

    def _zone_view_of_region(region_up: np.ndarray) -> np.ndarray:
        """Per-zone copy of the regional crunch: each crunch segment is
        joined with prob ``crunch_participation`` and a small onset lag."""
        view = np.ones(steps, dtype=np.int8)
        t = 0
        while t < steps:
            if region_up[t] == 0:
                # find the crunch segment [t, e)
                e = t
                while e < steps and region_up[e] == 0:
                    e += 1
                if rng.random() < crunch_participation:
                    lag = int(rng.integers(0, crunch_max_lag_steps + 1))
                    view[min(t + lag, steps) : e] = 0
                t = e
            else:
                t += 1
        return view

    cap = np.zeros((steps, len(zones)), dtype=np.int32)
    for j, z in enumerate(zones):
        local = _two_state_markov(
            rng,
            steps,
            p_up_down=1.0 / zone_mean_up_steps,
            p_down_up=1.0 / zone_mean_down_steps,
        )
        # Partial-capacity wobble: when up, zones occasionally serve fewer
        # than max_capacity instances (quota / partial crunch).  Piecewise
        # constant over multi-hour segments — capacity changes are rare
        # events, not per-minute noise.
        seg_len = max(1, int(6 * 3600 / dt))
        n_seg = steps // seg_len + 1
        seg_vals = rng.integers(
            low=max(1, max_capacity - 1), high=max_capacity + 1, size=n_seg
        )
        partial = np.repeat(seg_vals, seg_len)[:steps]
        zone_region_up = _zone_view_of_region(region_state[zone_region[z]])
        up = (zone_region_up & local).astype(np.int32)
        cap[:, j] = up * np.minimum(max_capacity, partial)
    return SpotTrace(zones=tuple(zones), cap=cap, dt=dt, name=name)


# ---------------------------------------------------------------------------
# The paper's four datasets (synthetic stand-ins, fixed seeds)
# ---------------------------------------------------------------------------

_DAY = 24 * 3600.0


def _aws_zone_map(zs: Sequence[str]) -> Dict[str, str]:
    return {z: z[:-1] for z in zs}  # "us-east-1a" -> "us-east-1"


def _dataset_aws1() -> SpotTrace:
    """AWS 1: 2-week trace, 4 p3.2xlarge, 3 zones (one region)."""
    zones = ["us-west-2a", "us-west-2b", "us-west-2c"]
    return synth_correlated_trace(
        zones,
        _aws_zone_map(zones),
        steps=int(14 * _DAY / 60),
        dt=60.0,
        max_capacity=4,
        region_availability={"us-west-2": 0.79},  # §5.1: unavailable 21% of time
        zone_mean_up_steps=800.0,
        zone_mean_down_steps=50.0,
        seed=101,
        name="aws-1",
    )


def _dataset_aws2() -> SpotTrace:
    """AWS 2: 3-week trace, 16 p3.2xlarge, 3 zones; 33.1% all-zone dropout."""
    zones = ["us-east-1a", "us-east-1c", "us-east-1f"]
    return synth_correlated_trace(
        zones,
        _aws_zone_map(zones),
        steps=int(21 * _DAY / 60),
        dt=60.0,
        max_capacity=16,
        region_availability={"us-east-1": 0.67},  # -> ~33% region dropout
        zone_mean_up_steps=700.0,
        zone_mean_down_steps=60.0,
        crunch_participation=0.97,  # deep region-wide outages (§2.2)
        seed=202,
        name="aws-2",
    )


def _dataset_aws3() -> SpotTrace:
    """AWS 3: 2-month trace, p3.2xlarge, 9 zones across 3 regions."""
    zones = [
        "us-east-1a", "us-east-1c", "us-east-1f",
        "us-east-2a", "us-east-2b",
        "us-west-2a", "us-west-2b", "us-west-2c",
        "eu-central-1a",
    ]
    return synth_correlated_trace(
        zones,
        _aws_zone_map(zones),
        steps=int(60 * _DAY / 300),
        dt=300.0,
        max_capacity=4,
        region_availability={
            "us-east-1": 0.80,
            "us-east-2": 0.88,
            "us-west-2": 0.75,
            "eu-central-1": 0.93,
        },
        zone_mean_up_steps=260.0,
        zone_mean_down_steps=12.0,
        crunch_max_lag_steps=1,   # dt=300s: one step already ~= the paper's
                                  # minutes-scale preemption stagger
        seed=303,
        name="aws-3",
    )


def _dataset_gcp1() -> SpotTrace:
    """GCP 1: 3-day trace, 4 a2-ultragpu-4g, 6 zones (A100 — scarce)."""
    zones = [
        "us-central1-a", "us-central1-b", "us-central1-c",
        "us-west1-a", "us-west1-b",
        "europe-west4-a",
    ]
    zmap = {z: z.rsplit("-", 1)[0] for z in zones}
    return synth_correlated_trace(
        zones,
        zmap,
        steps=int(3 * _DAY / 60),
        dt=60.0,
        max_capacity=4,
        region_availability={
            "us-central1": 0.60,   # A100s: very volatile (Fig. 4)
            "us-west1": 0.50,
            "europe-west4": 0.75,
        },
        zone_mean_up_steps=420.0,
        zone_mean_down_steps=40.0,
        seed=404,
        name="gcp-1",
    )


def _dataset_cpu() -> SpotTrace:
    """Spot *CPU* reference trace (Fig. 4b: 95.6-99.9% available)."""
    zones = ["us-east-1a", "us-east-1c", "us-east-1f"]
    return synth_correlated_trace(
        zones,
        _aws_zone_map(zones),
        steps=int(14 * _DAY / 60),
        dt=60.0,
        max_capacity=16,
        region_availability={"us-east-1": 0.999},
        zone_mean_up_steps=4000.0,
        zone_mean_down_steps=8.0,
        seed=505,
        name="cpu-ref",
    )


_DATASETS = {
    "aws-1": _dataset_aws1,
    "aws-2": _dataset_aws2,
    "aws-3": _dataset_aws3,
    "gcp-1": _dataset_gcp1,
    "cpu-ref": _dataset_cpu,
}


_TRACE_CACHE: Dict[str, SpotTrace] = {}


class TraceLibrary:
    """Named access to the benchmark trace datasets (memoized).

    The cache is process-global: the synthetic generators walk a Markov
    chain over every trace step, so regenerating a multi-week dataset per
    ``TraceLibrary()`` instantiation (one per scenario cell) would dwarf
    the simulation itself.  Traces are treated as immutable by all
    consumers (slicing copies).
    """

    def __init__(self) -> None:
        self._cache: Dict[str, SpotTrace] = _TRACE_CACHE

    def names(self) -> List[str]:
        return sorted(_DATASETS)

    def get(self, name: str) -> SpotTrace:
        if name not in self._cache:
            if name not in _DATASETS:
                raise KeyError(
                    f"unknown trace {name!r}; have {sorted(_DATASETS)}"
                )
            self._cache[name] = _DATASETS[name]()
        return self._cache[name]


def load_trace(name_or_path: str) -> SpotTrace:
    """Load a trace by dataset name, .npz path, or .json path."""
    if name_or_path in _DATASETS:
        return TraceLibrary().get(name_or_path)
    if name_or_path.endswith(".json"):
        return SpotTrace.from_json(name_or_path)
    return SpotTrace.load(name_or_path)


# ---------------------------------------------------------------------------
# CLI: python -m repro.cluster.traces [name ...]
# ---------------------------------------------------------------------------


def _print_stats(stats: Dict[str, object]) -> None:
    print(
        f"{stats['name']}: {stats['steps']} steps x {stats['dt_s']:g}s "
        f"({stats['duration_days']:g} days), "
        f"mean availability {stats['mean_availability']:.2%}"
    )
    print(
        f"  {'zone':<16s} {'region':<14s} {'avail':>7s} "
        f"{'preempt/day':>12s} {'sibling r':>10s}"
    )
    for z, s in stats["zones"].items():  # type: ignore[union-attr]
        print(
            f"  {z:<16s} {s['region']:<14s} {s['availability']:7.2%} "
            f"{s['preemptions_per_day']:12.2f} {s['mean_sibling_corr']:10.3f}"
        )


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Per-zone availability / preemption-rate / "
        "sibling-correlation stats of the benchmark traces"
    )
    ap.add_argument(
        "traces", nargs="*",
        help="named datasets or .json/.npz trace paths "
        "(default: every named dataset)",
    )
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON document instead of tables")
    args = ap.parse_args(argv)

    names = args.traces or TraceLibrary().names()
    all_stats = [trace_stats(load_trace(n)) for n in names]
    if args.json:
        print(json.dumps(all_stats, indent=1))
    else:
        for stats in all_stats:
            _print_stats(stats)
    return 0


if __name__ == "__main__":
    import sys

    # ``python -m repro.cluster.traces`` re-executes this file as
    # ``__main__`` after the package __init__ already imported the
    # canonical module; delegate so the CLI runs with the canonical
    # SpotTrace / TraceLibrary (one cache, one class identity), not
    # this duplicate copy.
    from repro.cluster.traces import main as _canonical_main

    sys.exit(_canonical_main())

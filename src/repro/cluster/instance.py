"""Instance lifecycle FSM.

A replica in the paper is one or more cloud instances running an inference
engine.  We model the instance lifecycle exactly as the controller observes
it (§2.3, §4):

    REQUESTED --launch ok--> PROVISIONING --cold start d--> READY
        |                        |                             |
        +--capacity miss--> FAILED                             |
                                 +------- preempted ----------+--> PREEMPTED
                                               (spot only)
                                 +------ terminate (policy) ------> TERMINATED

Billing: clouds bill from successful launch, *including* the provisioning /
cold-start period (§2.3: "users are still billed during the cold start
period").  Failed launch attempts cost nothing but consume controller time.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional


class InstanceKind(enum.Enum):
    SPOT = "spot"
    ON_DEMAND = "on_demand"


class InstanceState(enum.Enum):
    REQUESTED = "requested"
    PROVISIONING = "provisioning"
    READY = "ready"
    PREEMPTED = "preempted"
    TERMINATED = "terminated"
    FAILED = "failed"          # launch failed (no capacity)


_ACTIVE = (InstanceState.PROVISIONING, InstanceState.READY)

_id_counter = itertools.count()


def _next_id() -> int:
    return next(_id_counter)


@dataclasses.dataclass
class Instance:
    """One cloud instance and its billing record."""

    zone: str
    region: str
    cloud: str
    kind: InstanceKind
    itype: str                     # instance type name
    hourly_price: float            # $ / hour at launch time
    launched_at: float             # sim time of successful launch
    cold_start_s: float            # provisioning + model load delay d
    state: InstanceState = InstanceState.PROVISIONING
    ended_at: Optional[float] = None
    id: int = dataclasses.field(default_factory=_next_id)
    # preemption warning delivered at this sim time (None: not warned)
    warned_at: Optional[float] = None

    # ------------------------------------------------------------------
    @property
    def ready_at(self) -> float:
        return self.launched_at + self.cold_start_s

    def is_active(self) -> bool:
        return self.state in _ACTIVE

    def is_ready(self) -> bool:
        return self.state is InstanceState.READY

    def is_spot(self) -> bool:
        return self.kind is InstanceKind.SPOT

    # ------------------------------------------------------------------
    def step_to(self, now: float) -> None:
        """Advance PROVISIONING -> READY when the cold start has elapsed."""
        if self.state is InstanceState.PROVISIONING and now >= self.ready_at:
            self.state = InstanceState.READY

    def preempt(self, now: float) -> None:
        if not self.is_active():
            raise ValueError(f"preempting non-active instance {self.id}")
        if not self.is_spot():
            raise ValueError("on-demand instances are never preempted")
        self.state = InstanceState.PREEMPTED
        self.ended_at = now

    def terminate(self, now: float) -> None:
        if not self.is_active():
            raise ValueError(f"terminating non-active instance {self.id}")
        self.state = InstanceState.TERMINATED
        self.ended_at = now

    # ------------------------------------------------------------------
    def billed_hours(self, now: float) -> float:
        """Hours billed so far (per-second granularity, incl. cold start)."""
        end = self.ended_at if self.ended_at is not None else now
        return max(0.0, end - self.launched_at) / 3600.0

    def cost(self, now: float) -> float:
        return self.billed_hours(now) * self.hourly_price

"""Multi-cloud substrate: catalog, spot traces, instance FSM, simulator."""

from repro.cluster.catalog import (
    Catalog,
    CloudSpec,
    InstanceType,
    Zone,
    default_catalog,
)
from repro.cluster.instance import Instance, InstanceKind, InstanceState
from repro.cluster.simulator import ClusterSimulator, SimConfig, SimResult
from repro.cluster.traces import (
    SpotTrace,
    TraceLibrary,
    load_trace,
    synth_correlated_trace,
)

__all__ = [
    "Catalog",
    "CloudSpec",
    "InstanceType",
    "Zone",
    "default_catalog",
    "Instance",
    "InstanceKind",
    "InstanceState",
    "ClusterSimulator",
    "SimConfig",
    "SimResult",
    "SpotTrace",
    "TraceLibrary",
    "load_trace",
    "synth_correlated_trace",
]

"""Discrete-event cluster simulator — the §5.2 methodology.

Replays a spot obtainability trace against a policy: at each control tick,

1. **trace transitions** — if a zone's spot capacity drops below the number
   of active spot instances, the excess instances are preempted (newest
   first, matching the observed behaviour that fresh instances are evicted
   first in a crunch).  Policies receive best-effort preemption warnings
   ``warning_s`` ahead when the trace already shows the upcoming drop (real
   clouds warn 30-120 s; delivery is probabilistic — §2.3).
2. **instance FSM steps** — provisioning instances become ready after the
   cold start delay ``d``; policies get ``on_ready`` (Alg. 1 HANDLE-LAUNCH).
3. **policy tick** — ``policy.decide(obs)`` returns launch/terminate
   actions.  Spot launches succeed iff the zone has remaining capacity;
   a failed launch fires ``on_launch_failure`` and costs nothing.
4. **metrics** — availability (ready >= N_Tar), ready-count time series and
   per-second billing (including the provisioning period, §2.3).

The serving-quality simulator (``repro.serving.sim``) composes this class
with the request/LB layer; this module is policy-vs-trace only, which is all
Fig. 14a/14b need.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.catalog import Catalog, Zone, default_catalog
from repro.cluster.instance import Instance, InstanceKind, InstanceState
from repro.cluster.traces import SpotTrace
from repro.core.autoscaler import Autoscaler, ConstantTarget
from repro.core.policy import (
    ControllerEvent,
    EventKind,
    LaunchOnDemand,
    LaunchSpot,
    Observation,
    Policy,
    Terminate,
)
from repro.obs.events import (
    AutoscalerTargetEvent,
    LaunchFailureEvent,
    PolicyDecisionEvent,
    PreemptionWarningEvent,
    ReplicaLifecycleEvent,
)
from repro.obs.recorder import ObsRecorder


@dataclasses.dataclass
class SimConfig:
    itype: str = "p3.2xlarge"
    cold_start_s: float = 183.0      # §2.3: measured Llama-2-7B/vLLM deploy
    control_interval_s: float = 30.0
    warning_enabled: bool = True
    seed: int = 0
    # terminate-before-preempt grace: when a warning arrives, policies may
    # proactively launch; the simulator itself takes no action.
    record_series: bool = True


@dataclasses.dataclass
class SimResult:
    """Aggregated metrics of one simulated run."""

    policy: str
    trace: str
    duration_s: float
    availability: float              # fraction of ticks with ready >= N_Tar
    total_cost: float                # $ (absolute, catalog prices)
    spot_cost: float
    od_cost: float
    cost_vs_ondemand: float          # total cost / cost of N_Tar OD replicas
    n_preemptions: int
    n_launch_failures: int
    n_spot_launches: int
    n_od_launches: int
    # time series sampled each tick (empty when record_series=False)
    t: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0)
    )
    ready_spot: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=int)
    )
    ready_od: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=int)
    )
    n_target_series: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=int)
    )

    def summary(self) -> str:
        return (
            f"{self.policy:>16s} @ {self.trace:<8s} "
            f"avail={self.availability:6.2%} "
            f"cost={self.cost_vs_ondemand:6.2%} of OD "
            f"preempt={self.n_preemptions:4d} "
            f"launch_fail={self.n_launch_failures:4d}"
        )


class ClusterSimulator:
    """Run one policy against one trace."""

    def __init__(
        self,
        trace: SpotTrace,
        policy: Policy,
        *,
        catalog: Optional[Catalog] = None,
        autoscaler: Optional[Autoscaler] = None,
        config: Optional[SimConfig] = None,
        zones: Optional[Sequence[str]] = None,
        # hook called each tick AFTER state transitions, BEFORE policy
        # decisions — the serving simulator uses it to pump requests.
        tick_hook: Optional[Callable[[float, "ClusterSimulator"], None]] = None,
        # observability recorder; all engines tap the control plane here,
        # which is what makes their event streams byte-identical.  A bare
        # cluster run defaults to a disabled recorder.
        obs: Optional[ObsRecorder] = None,
    ) -> None:
        self.trace = trace
        self.policy = policy
        self.catalog = catalog or default_catalog()
        self.autoscaler = autoscaler or ConstantTarget(4)
        self.config = config or SimConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.tick_hook = tick_hook
        self.obs = obs if obs is not None else ObsRecorder(detail="off")

        zone_names = list(zones) if zones is not None else list(trace.zones)
        missing = [z for z in zone_names if z not in trace.zones]
        if missing:
            raise ValueError(f"zones {missing} not present in trace")
        self.zones: List[Zone] = [self.catalog.zone(z) for z in zone_names]
        self.zone_names = zone_names

        self.instances: List[Instance] = []   # active only (dead pruned)
        self._dead_spot_cost = 0.0
        self._dead_od_cost = 0.0
        self.now = 0.0
        self.n_preemptions = 0
        self.n_launch_failures = 0
        self.n_spot_launches = 0
        self.n_od_launches = 0
        self._series_t: List[float] = []
        self._series_rs: List[int] = []
        self._series_ro: List[int] = []
        self._series_nt: List[int] = []
        self._warn_info: Optional[Dict[str, Tuple[float, float]]] = None
        # dense per-tick views precomputed by run() (pure perf: bit-exact
        # with the scalar trace accessors, see SpotTrace.dense_ticks)
        self._zcol: Dict[str, int] = {
            z: j for j, z in enumerate(zone_names)
        }
        self._tick_rows: Optional[List[List[int]]] = None
        self._warn_cols: Optional[List[List[int]]] = None
        self._k: Optional[int] = None
        self._preempt_listeners: List[Callable[[Instance, float], None]] = []
        self._terminate_listeners: List[Callable[[Instance, float], None]] = []
        self._ready_listeners: List[Callable[[Instance, float], None]] = []
        #: structured transition log (kept when record_series is on; the
        #: serving facade surfaces it through Service.status()).
        self.events: List[ControllerEvent] = []

        self.policy.reset(self.zones, self.catalog, self.config.itype)

    # -- event delivery ---------------------------------------------------
    def _emit(
        self,
        kind: EventKind,
        zone: str,
        instance_id: Optional[int] = None,
    ) -> ControllerEvent:
        """Deliver one structured transition to the policy (and log it)."""
        event = ControllerEvent(
            kind=kind, zone=zone, now=self.now, instance_id=instance_id
        )
        if self.config.record_series:
            self.events.append(event)
        self.policy.on_event(event)
        return event

    # -- listener registration (serving layer) --------------------------
    def add_preempt_listener(
        self, fn: Callable[[Instance, float], None]
    ) -> None:
        self._preempt_listeners.append(fn)

    def add_terminate_listener(
        self, fn: Callable[[Instance, float], None]
    ) -> None:
        """Called when the policy/autoscaler terminates an instance.

        Terminated instances are retired from ``self.instances``
        immediately, so without this hook the serving layer would never
        observe the death and its replica would keep serving as a zombie.
        """
        self._terminate_listeners.append(fn)

    def add_ready_listener(
        self, fn: Callable[[Instance, float], None]
    ) -> None:
        self._ready_listeners.append(fn)

    # -- state views -----------------------------------------------------
    def active_spot(self, zone: Optional[str] = None) -> List[Instance]:
        return [
            i
            for i in self.instances
            if i.is_spot()
            and i.is_active()
            and (zone is None or i.zone == zone)
        ]

    def ready_instances(self) -> List[Instance]:
        return [i for i in self.instances if i.is_ready()]

    def _observation(self, n_target: int) -> Observation:
        spot_ready, spot_prov, od_ready, od_prov = [], [], [], []
        for i in self.instances:
            if not i.is_active():
                continue
            if i.is_spot():
                (spot_ready if i.is_ready() else spot_prov).append(i)
            else:
                (od_ready if i.is_ready() else od_prov).append(i)
        return Observation(
            now=self.now,
            n_target=n_target,
            spot_ready=spot_ready,
            spot_provisioning=spot_prov,
            od_ready=od_ready,
            od_provisioning=od_prov,
        )

    # -- mechanics -------------------------------------------------------
    def _launch(self, kind: InstanceKind, zone_name: str) -> Optional[Instance]:
        zone = self.catalog.zone(zone_name)
        if kind is InstanceKind.SPOT:
            if self._tick_rows is not None and self._k is not None \
                    and zone_name in self._zcol:
                cap = self._tick_rows[self._k][self._zcol[zone_name]]
            else:
                cap = self.trace.capacity(zone_name, self.now)
            in_use = len(self.active_spot(zone_name))
            if in_use + 1 > cap:
                self.n_launch_failures += 1
                self._emit(EventKind.LAUNCH_FAILURE, zone_name)
                if self.obs.enabled:
                    self.obs.emit(LaunchFailureEvent(
                        t=self.now, zone=zone_name, kind="spot"
                    ))
                return None
            price = self.catalog.spot_price(self.config.itype, zone_name)
            self.n_spot_launches += 1
        else:
            # On-demand is modelled as always obtainable (§5.1 Discussion:
            # "on-demand instances are typically obtainable across regions").
            price = self.catalog.od_price(self.config.itype, zone_name)
            self.n_od_launches += 1
        inst = Instance(
            zone=zone_name,
            region=zone.region,
            cloud=zone.cloud,
            kind=kind,
            itype=self.config.itype,
            hourly_price=price,
            launched_at=self.now,
            cold_start_s=self.config.cold_start_s,
        )
        self.instances.append(inst)
        if self.obs.enabled:
            self.obs.emit(ReplicaLifecycleEvent(
                t=self.now,
                phase="provision",
                instance_id=self.obs.replica_ordinal(inst.id),
                zone=zone_name,
                kind="spot" if kind is InstanceKind.SPOT else "ondemand",
                hourly_price=price,
            ))
        return inst

    def _apply_trace(self, k: Optional[int] = None) -> None:
        """Preempt spot instances in zones whose capacity dropped."""
        if k is not None and self._tick_rows is not None:
            row = self._tick_rows[k]
        else:
            d = self.trace.capacity_row(self.now)
            row = [d[z] for z in self.zone_names]
        # one pass over instances instead of one scan per zone; zones
        # without active spot can never have excess > 0, so skip them
        by_zone: Dict[str, List[Instance]] = {}
        zcol = self._zcol
        for i in self.instances:
            if i.is_spot() and i.is_active() and i.zone in zcol:
                by_zone.setdefault(i.zone, []).append(i)
        if not by_zone:
            return
        for zone_name, active in (
            (z, by_zone.get(z)) for z in self.zone_names
        ):
            if not active:
                continue
            excess = len(active) - row[zcol[zone_name]]
            if excess <= 0:
                continue
            # newest first: fresh instances are evicted first in a crunch
            active.sort(key=lambda i: -i.launched_at)
            for inst in active[:excess]:
                inst.preempt(self.now)
                self.n_preemptions += 1
                self._emit(EventKind.PREEMPTION, zone_name, inst.id)
                # preempt listeners may emit migration events for the
                # grace window that just ended, so the "dead" record
                # comes after them in the log
                for fn in self._preempt_listeners:
                    fn(inst, self.now)
                if self.obs.enabled:
                    self.obs.emit(ReplicaLifecycleEvent(
                        t=self.now,
                        phase="dead",
                        instance_id=self.obs.replica_ordinal(inst.id),
                        zone=zone_name,
                        cause="preemption",
                    ))
                self._retire(inst)

    def _resolve_warn_info(self) -> Dict[str, Tuple[float, float]]:
        if self._warn_info is None:
            # zone -> (warning lead, delivery prob), resolved once; a trace
            # may carry its own observed lead, overriding the cloud default
            self._warn_info = {
                z: (
                    max(
                        (
                            self.trace.preemption_warning_s
                            if self.trace.preemption_warning_s is not None
                            else self.catalog.cloud(
                                self.catalog.zone(z).cloud
                            ).preemption_warning_s
                        ),
                        self.trace.dt,
                    ),
                    self.catalog.cloud(
                        self.catalog.zone(z).cloud
                    ).warning_delivery_prob,
                )
                for z in self.zone_names
            }
        return self._warn_info

    def _deliver_warnings(self, k: Optional[int] = None) -> None:
        """Best-effort preemption warnings (§2.3): look ahead by the cloud's
        advertised warning lead (120 s AWS, 30 s GCP/Azure); if capacity will
        drop, warn (probabilistically — warnings are best-effort)."""
        if not self.config.warning_enabled:
            return
        warn_info = self._resolve_warn_info()
        if k is not None and self._warn_cols is not None:
            # precomputed path: same drops, same guard, and crucially the
            # same rng draw count/order (one draw per dropping zone, in
            # zone_names order) as the scalar path below
            cols = self._warn_cols[k]
            if not cols:
                return
            for j in cols:
                zone_name = self.zone_names[j]
                if self.rng.random() < warn_info[zone_name][1]:
                    for inst in self.active_spot(zone_name):
                        if inst.warned_at is None:
                            inst.warned_at = self.now
                    self._emit(EventKind.WARNING, zone_name)
                    if self.obs.enabled:
                        self.obs.emit(PreemptionWarningEvent(
                            t=self.now, zone=zone_name
                        ))
            return
        now_row = self.trace.capacity_row(self.now)
        for zone_name in self.zone_names:
            lead, prob = warn_info[zone_name]
            horizon = self.now + lead
            if horizon >= self.trace.duration_s:
                continue
            if self.trace.capacity(zone_name, horizon) < now_row[zone_name]:
                if self.rng.random() < prob:
                    for inst in self.active_spot(zone_name):
                        if inst.warned_at is None:
                            inst.warned_at = self.now
                    self._emit(EventKind.WARNING, zone_name)
                    if self.obs.enabled:
                        self.obs.emit(PreemptionWarningEvent(
                            t=self.now, zone=zone_name
                        ))

    def _retire(self, inst: Instance) -> None:
        """Move a dead instance out of the scan list; bank its cost."""
        cost = inst.cost(self.now)
        if inst.is_spot():
            self._dead_spot_cost += cost
        else:
            self._dead_od_cost += cost
        try:
            self.instances.remove(inst)
        except ValueError:  # pragma: no cover - already pruned
            pass

    def _step_instances(self) -> None:
        for inst in self.instances:
            if inst.state is InstanceState.PROVISIONING:
                was_ready = inst.is_ready()
                inst.step_to(self.now)
                if inst.is_ready() and not was_ready:
                    if inst.is_spot():
                        self._emit(EventKind.READY, inst.zone, inst.id)
                    if self.obs.enabled:
                        self.obs.emit(ReplicaLifecycleEvent(
                            t=self.now,
                            phase="ready",
                            instance_id=self.obs.replica_ordinal(inst.id),
                            zone=inst.zone,
                        ))
                    for fn in self._ready_listeners:
                        fn(inst, self.now)

    def _execute(self, actions) -> None:
        by_id = {i.id: i for i in self.instances}
        # the policy's per-action reasons pair with actions by index
        # (policies that note nothing yield an empty list -> all None)
        reasons = self.policy.take_reasons()
        obs_on = self.obs.enabled
        for idx, act in enumerate(actions):
            reason = reasons[idx] if idx < len(reasons) else None
            if isinstance(act, LaunchSpot):
                inst = self._launch(InstanceKind.SPOT, act.zone)
                if obs_on:
                    self.obs.emit(PolicyDecisionEvent(
                        t=self.now,
                        action="launch_spot",
                        zone=act.zone,
                        instance_id=(
                            None if inst is None
                            else self.obs.replica_ordinal(inst.id)
                        ),
                        reason=reason,
                    ))
            elif isinstance(act, LaunchOnDemand):
                inst = self._launch(InstanceKind.ON_DEMAND, act.zone)
                if obs_on:
                    self.obs.emit(PolicyDecisionEvent(
                        t=self.now,
                        action="launch_ondemand",
                        zone=act.zone,
                        instance_id=(
                            None if inst is None
                            else self.obs.replica_ordinal(inst.id)
                        ),
                        reason=reason,
                    ))
            elif isinstance(act, Terminate):
                inst = by_id.get(act.instance_id)
                if obs_on:
                    self.obs.emit(PolicyDecisionEvent(
                        t=self.now,
                        action="terminate",
                        zone=None if inst is None else inst.zone,
                        instance_id=self.obs.replica_ordinal(
                            act.instance_id
                        ),
                        reason=reason,
                    ))
                if inst is not None and inst.is_active():
                    inst.terminate(self.now)
                    if obs_on:
                        self.obs.emit(ReplicaLifecycleEvent(
                            t=self.now,
                            phase="dead",
                            instance_id=self.obs.replica_ordinal(inst.id),
                            zone=inst.zone,
                            cause="terminate",
                        ))
                    for fn in self._terminate_listeners:
                        fn(inst, self.now)
                    self._retire(inst)
            else:  # pragma: no cover - defensive
                raise TypeError(f"unknown action {act!r}")

    def _precompute(self, dt: float, ticks: int) -> None:
        """Dense per-tick trace views for the run loop.

        Bit-exact with the scalar accessors (same clamped indexing, same
        float arithmetic — see :meth:`SpotTrace.dense_ticks`); replaces
        the per-tick ``capacity_row`` dict builds and lookahead
        ``capacity`` calls that dominated the control-plane profile.
        """
        tr = self.trace
        cap = tr.dense_ticks(dt, ticks, self.zone_names)
        self._tick_rows = cap.tolist()
        if self.config.warning_enabled:
            warn_info = self._resolve_warn_info()
            t = np.arange(ticks, dtype=np.float64) * dt
            drop = np.zeros((ticks, len(self.zone_names)), dtype=bool)
            for j, z in enumerate(self.zone_names):
                lead = warn_info[z][0]
                ahead = tr.dense_ticks(dt, ticks, [z], offset_s=lead)[:, 0]
                drop[:, j] = (ahead < cap[:, j]) & (t + lead < tr.duration_s)
            self._warn_cols = [np.flatnonzero(r).tolist() for r in drop]

    # -- main loop ---------------------------------------------------------
    def run(self, duration_s: Optional[float] = None) -> SimResult:
        dur = float(duration_s or self.trace.duration_s)
        dt = self.config.control_interval_s
        ticks = int(dur / dt)
        ok_ticks = 0
        self._precompute(dt, ticks)

        prev_target: Optional[int] = None
        for k in range(ticks):
            self.now = k * dt
            self._k = k
            self._apply_trace(k)
            self._step_instances()
            self._deliver_warnings(k)
            if self.tick_hook is not None:
                self.tick_hook(self.now, self)
            n_target = self.autoscaler.target(self.now)
            if self.obs.enabled and n_target != prev_target:
                self.obs.emit(AutoscalerTargetEvent(
                    t=self.now, target=n_target, prev_target=prev_target
                ))
            prev_target = n_target
            obs = self._observation(n_target)
            self._execute(self.policy.decide(obs))
            # metrics AFTER actions so cold starts are charged immediately
            n_ready_spot = n_ready_od = 0
            for i in self.instances:
                if i.state is InstanceState.READY:
                    if i.kind is InstanceKind.SPOT:
                        n_ready_spot += 1
                    else:
                        n_ready_od += 1
            if n_ready_spot + n_ready_od >= n_target:
                ok_ticks += 1
            if self.config.record_series:
                self._series_t.append(self.now)
                self._series_rs.append(n_ready_spot)
                self._series_ro.append(n_ready_od)
                self._series_nt.append(n_target)

        self.now = ticks * dt
        return self._result(dur, ok_ticks, ticks)

    # -- results ----------------------------------------------------------
    def _result(self, dur: float, ok_ticks: int, ticks: int) -> SimResult:
        spot_cost = self._dead_spot_cost + sum(
            i.cost(self.now) for i in self.instances if i.is_spot()
        )
        od_cost = self._dead_od_cost + sum(
            i.cost(self.now) for i in self.instances if not i.is_spot()
        )
        # denominator: keeping N_Tar on-demand replicas in the cheapest zone
        # for the whole run (the paper's "relative to OD" normalization).
        od_zone = min(
            self.zone_names,
            key=lambda z: self.catalog.od_price(self.config.itype, z),
        )
        mean_target = (
            float(np.mean(self._series_nt))
            if self._series_nt
            else float(self.autoscaler.target(0.0))
        )
        od_ref = (
            self.catalog.od_price(self.config.itype, od_zone)
            * mean_target
            * dur
            / 3600.0
        )
        return SimResult(
            policy=self.policy.name,
            trace=self.trace.name,
            duration_s=dur,
            availability=ok_ticks / max(ticks, 1),
            total_cost=spot_cost + od_cost,
            spot_cost=spot_cost,
            od_cost=od_cost,
            cost_vs_ondemand=(spot_cost + od_cost) / max(od_ref, 1e-9),
            n_preemptions=self.n_preemptions,
            n_launch_failures=self.n_launch_failures,
            n_spot_launches=self.n_spot_launches,
            n_od_launches=self.n_od_launches,
            t=np.asarray(self._series_t),
            ready_spot=np.asarray(self._series_rs, dtype=int),
            ready_od=np.asarray(self._series_ro, dtype=int),
            n_target_series=np.asarray(self._series_nt, dtype=int),
        )


def run_policy_on_trace(
    policy_name: str,
    trace: SpotTrace,
    *,
    n_target: int = 4,
    itype: str = "p3.2xlarge",
    cold_start_s: float = 183.0,
    control_interval_s: float = 30.0,
    duration_s: Optional[float] = None,
    seed: int = 0,
    policy_kwargs: Optional[dict] = None,
) -> SimResult:
    """Convenience one-shot runner used by benchmarks and tests."""
    from repro.core.policy import make_policy

    policy = make_policy(policy_name, **(policy_kwargs or {}))
    if policy_name == "omniscient":
        from repro.core.omniscient import solve_omniscient

        cat = default_catalog()
        k = (
            cat.od_price(itype, trace.zones[0])
            / cat.spot_price(itype, trace.zones[0])
        )
        schedule = solve_omniscient(
            trace,
            n_target=n_target,
            cold_start_s=cold_start_s,
            k_ratio=k,
            avail_target=0.99,
        )
        policy.attach_schedule(schedule)
    sim = ClusterSimulator(
        trace,
        policy,
        autoscaler=ConstantTarget(n_target),
        config=SimConfig(
            itype=itype,
            cold_start_s=cold_start_s,
            control_interval_s=control_interval_s,
            seed=seed,
        ),
    )
    return sim.run(duration_s)

"""Cloud / region / zone / instance-type catalog with paper-faithful pricing.

The paper's Table 1 gives spot price as a *fraction of on-demand* per
(cloud, GPU) pair.  We encode those ratios verbatim and attach representative
absolute on-demand prices (the paper quotes g5.48xlarge at $16.3/h on-demand
and $4.9/h spot, which we reproduce exactly).  The catalog also carries the
TPU v5e SKUs used by the hardware-adaptation layer: on GCP, v5e pod slices are
offered both on-demand and preemptible, so SpotHedge transfers unchanged.

Zones follow the AWS/GCP naming convention (``us-east-1a``).  A ``Zone`` is
the paper's failure domain unit: preemptions correlate *within* a region's
zones and are nearly independent *across* regions (Fig. 3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Instance types
# ---------------------------------------------------------------------------

# Published peak HBM bandwidth per accelerator, bytes/s.  This is the
# single source the roofline latency model draws from (decode is
# HBM-bound: weights re-read per token), so an accelerator missing here
# is a hard error at InstanceType construction — not a silent 0.8 TB/s
# guess three layers down in ``serving/latency.py``.
ACCEL_HBM_BYTES_PER_S: Mapping[str, float] = {
    "A100": 2.0e12,
    "V100": 0.9e12,
    "T4": 0.3e12,
    "A10G": 0.6e12,
    "K80": 0.24e12,
    "TPUv5e": 0.819e12,
}


def hbm_bandwidth(accelerator: str) -> float:
    """Peak HBM bytes/s for a known accelerator name; raises otherwise."""
    try:
        return ACCEL_HBM_BYTES_PER_S[accelerator]
    except KeyError:
        known = sorted(ACCEL_HBM_BYTES_PER_S)
        raise KeyError(
            f"unknown accelerator {accelerator!r}: no HBM bandwidth on "
            f"record (known: {known}); add it to "
            "cluster.catalog.ACCEL_HBM_BYTES_PER_S or construct the "
            "InstanceType with an explicit hbm_bytes_per_s"
        ) from None


@dataclasses.dataclass(frozen=True)
class InstanceType:
    """A purchasable machine shape.

    ``spot_ratio`` is Table 1's spot/on-demand price ratio for the cloud the
    instance belongs to; per-zone price wobble is added by the catalog (the
    paper notes spot prices are stable in time but differ slightly across
    zones/regions).

    ``hbm_bytes_per_s`` (peak, per accelerator) resolves from
    :data:`ACCEL_HBM_BYTES_PER_S` by accelerator name when not given;
    an unknown accelerator with no explicit value raises at construction.
    """

    name: str
    cloud: str
    accelerator: str            # e.g. "A10G", "V100", "T4", "TPUv5e-8"
    accel_count: int
    od_price: float             # $/hour, on-demand
    spot_ratio: float           # spot price as fraction of on-demand
    hbm_gib_per_accel: float = 16.0
    peak_bf16_tflops: float = 197.0  # per accelerator (v5e default)
    hbm_bytes_per_s: Optional[float] = None  # per accelerator, peak

    def __post_init__(self) -> None:
        if self.hbm_bytes_per_s is None:
            object.__setattr__(
                self, "hbm_bytes_per_s", hbm_bandwidth(self.accelerator)
            )

    @property
    def spot_price(self) -> float:
        return self.od_price * self.spot_ratio


# Table 1 (paper, Oct 2024): spot cost as % of on-demand, per cloud × GPU.
# Ranges in the table are encoded as their midpoint.
_TABLE1: Mapping[Tuple[str, str], float] = {
    ("aws", "A100"): 0.10,
    ("aws", "V100"): 0.165,   # 8–25%
    ("aws", "T4"): 0.15,      # 13–17%
    ("aws", "K80"): 0.19,     # 13–25%
    ("azure", "A100"): 0.50,
    ("azure", "V100"): 0.25,
    ("azure", "T4"): 0.10,
    ("azure", "K80"): 0.10,
    ("gcp", "A100"): 0.33,
    ("gcp", "V100"): 0.33,
    ("gcp", "T4"): 0.17,      # 14–20%
    ("gcp", "K80"): 0.10,
    # TPU v5e preemptible pricing on GCP is ~1/3 of on-demand — same bracket
    # as GCP GPU spot, which is what makes the policy transfer economically.
    ("gcp", "TPUv5e"): 0.33,
}


def _itype(
    name: str,
    cloud: str,
    accel: str,
    count: int,
    od: float,
    *,
    table_key: Optional[str] = None,
    hbm: float = 16.0,
    tflops: float = 197.0,
) -> InstanceType:
    ratio = _TABLE1[(cloud, table_key or accel)]
    return InstanceType(
        name=name,
        cloud=cloud,
        accelerator=accel,
        accel_count=count,
        od_price=od,
        spot_ratio=ratio,
        hbm_gib_per_accel=hbm,
        peak_bf16_tflops=tflops,
    )


# The instance types used in the paper's evaluation plus the TPU SKUs used by
# our data plane.  Absolute prices are representative of Oct-2024 list prices;
# the two quoted in the paper (g5.48xlarge OD $16.3 / spot $4.9) are exact.
DEFAULT_INSTANCE_TYPES: Tuple[InstanceType, ...] = (
    # paper §5.1 run 1: Llama-2-70B on 8×A10G
    InstanceType("g5.48xlarge", "aws", "A10G", 8, 16.3, 4.9 / 16.3, 24.0, 70.0),
    # paper §5.1 run 2: OPT-6.7B on 4×T4
    _itype("g4dn.12xlarge", "aws", "T4", 4, 3.912, hbm=16.0, tflops=65.0),
    # paper §5.2 traces
    _itype("p3.2xlarge", "aws", "V100", 1, 3.06, hbm=16.0, tflops=112.0),
    _itype("a2-ultragpu-4g", "gcp", "A100", 4, 20.55, hbm=80.0, tflops=312.0),
    _itype("p4d.24xlarge", "aws", "A100", 8, 32.77, hbm=40.0, tflops=312.0),
    _itype("Standard_NC24ads_A100_v4", "azure", "A100", 1, 3.67, hbm=80.0,
           tflops=312.0),
    # TPU v5e slices (GCP): the unit our JAX replicas actually run on.
    _itype("v5e-8", "gcp", "TPUv5e", 8, 9.60, table_key="TPUv5e",
           hbm=16.0, tflops=197.0),
    _itype("v5e-16", "gcp", "TPUv5e", 16, 19.20, table_key="TPUv5e",
           hbm=16.0, tflops=197.0),
    _itype("v5e-256", "gcp", "TPUv5e", 256, 307.20, table_key="TPUv5e",
           hbm=16.0, tflops=197.0),
)


# ---------------------------------------------------------------------------
# Zones and regions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Zone:
    """A failure domain: (cloud, region, zone)."""

    name: str                   # e.g. "us-east-1a"
    region: str                 # e.g. "us-east-1"
    cloud: str                  # "aws" | "gcp" | "azure"
    # Multiplier on the instance type's base price in this zone (paper: spot
    # prices differ slightly across zones/regions).
    price_multiplier: float = 1.0

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.cloud}:{self.name}"


@dataclasses.dataclass(frozen=True)
class CloudSpec:
    """Cloud-level behaviour knobs (preemption warning; see §2.3)."""

    name: str
    preemption_warning_s: float     # best-effort warning before a preemption
    warning_delivery_prob: float    # warnings are best-effort


DEFAULT_CLOUDS: Tuple[CloudSpec, ...] = (
    CloudSpec("aws", preemption_warning_s=120.0, warning_delivery_prob=0.9),
    CloudSpec("gcp", preemption_warning_s=30.0, warning_delivery_prob=0.9),
    CloudSpec("azure", preemption_warning_s=30.0, warning_delivery_prob=0.9),
)


# Inter-region RTT model (§3.1, Fig. 6b): ~100 ms US<->EU round trip; small
# within-region latency.  Keys are region prefixes.
_REGION_GEO: Mapping[str, str] = {
    "us-east": "us-east",
    "us-west": "us-west",
    "eu": "eu",
    "asia": "asia",
}

_GEO_RTT_MS: Mapping[Tuple[str, str], float] = {
    ("us-east", "us-east"): 2.0,
    ("us-west", "us-west"): 2.0,
    ("eu", "eu"): 2.0,
    ("asia", "asia"): 2.0,
    ("us-east", "us-west"): 60.0,
    ("us-east", "eu"): 95.0,
    ("us-west", "eu"): 140.0,
    ("us-east", "asia"): 180.0,
    ("us-west", "asia"): 110.0,
    ("eu", "asia"): 240.0,
}


def _geo_of(region: str) -> str:
    for prefix, geo in _REGION_GEO.items():
        if region.startswith(prefix):
            return geo
    return "us-east"


def region_rtt_ms(region_a: str, region_b: str) -> float:
    """Round-trip latency between two regions (Fig. 6b model)."""
    ga, gb = _geo_of(region_a), _geo_of(region_b)
    if (ga, gb) in _GEO_RTT_MS:
        return _GEO_RTT_MS[(ga, gb)]
    return _GEO_RTT_MS[(gb, ga)]


# Effective point-to-point bandwidth between two instances, by locality
# tier (SpotServe §4: KV migration is bandwidth-bound).  Numbers are the
# per-flow rates a single TCP stream sustains in practice, not NIC line
# rate: same-zone placement gets the full intra-VPC fast path, peered
# regions of one cloud ride the provider backbone, and anything crossing
# a cloud boundary goes over the public internet.
INTRA_ZONE_GBPS = 25.0
INTRA_REGION_GBPS = 10.0
INTER_REGION_GBPS = 5.0          # same cloud, different region
INTER_CLOUD_GBPS = 1.0           # public internet


def link_bandwidth_gbps(
    cloud_a: str, region_a: str, zone_a: str,
    cloud_b: str, region_b: str, zone_b: str,
) -> float:
    """Locality-tiered bandwidth (Gbit/s) between two placements."""
    if cloud_a != cloud_b:
        return INTER_CLOUD_GBPS
    if region_a != region_b:
        return INTER_REGION_GBPS
    if zone_a != zone_b:
        return INTRA_REGION_GBPS
    return INTRA_ZONE_GBPS


def _mk_zones() -> Tuple[Zone, ...]:
    """The default zone universe, mirroring the zones of the paper's traces.

    AWS: us-east-1{a,c,f}, us-east-2{a,b}, us-west-2{a,b,c}, eu-central-1{a,b}
    GCP: us-central1{a,b,c}, us-west1{a,b}, europe-west4{a,b}
    Azure: eastus{1,2}, westeurope{1,2}
    """
    zones: List[Zone] = []

    def add(cloud: str, region: str, suffixes: Sequence[str],
            mult: float) -> None:
        for i, s in enumerate(suffixes):
            zones.append(
                Zone(
                    name=f"{region}{s}",
                    region=region,
                    cloud=cloud,
                    # deterministic small per-zone wobble
                    price_multiplier=mult * (1.0 + 0.015 * i),
                )
            )

    add("aws", "us-east-1", ["a", "c", "f"], 1.00)
    add("aws", "us-east-2", ["a", "b"], 0.97)
    add("aws", "us-west-2", ["a", "b", "c"], 0.95)
    add("aws", "eu-central-1", ["a", "b"], 1.08)
    add("gcp", "us-central1", ["-a", "-b", "-c"], 1.00)
    add("gcp", "us-west1", ["-a", "-b"], 0.98)
    add("gcp", "europe-west4", ["-a", "-b"], 1.06)
    add("azure", "eastus", ["-1", "-2"], 1.02)
    add("azure", "westeurope", ["-1", "-2"], 1.10)
    return tuple(zones)


DEFAULT_ZONES: Tuple[Zone, ...] = _mk_zones()


# ---------------------------------------------------------------------------
# Catalog
# ---------------------------------------------------------------------------


class Catalog:
    """Immutable lookup service over clouds, zones and instance types.

    The service controller polls this (the paper polls the cloud pricing API)
    when SELECT-NEXT-ZONE breaks ties by cost.
    """

    def __init__(
        self,
        zones: Sequence[Zone] = DEFAULT_ZONES,
        instance_types: Sequence[InstanceType] = DEFAULT_INSTANCE_TYPES,
        clouds: Sequence[CloudSpec] = DEFAULT_CLOUDS,
    ) -> None:
        self._zones: Dict[str, Zone] = {z.name: z for z in zones}
        self._itypes: Dict[str, InstanceType] = {
            t.name: t for t in instance_types
        }
        self._clouds: Dict[str, CloudSpec] = {c.name: c for c in clouds}

    # -- zones ---------------------------------------------------------
    @property
    def zones(self) -> List[Zone]:
        return list(self._zones.values())

    def zone(self, name: str) -> Zone:
        return self._zones[name]

    def zones_in_region(self, region: str) -> List[Zone]:
        return [z for z in self._zones.values() if z.region == region]

    def zones_in_cloud(self, cloud: str) -> List[Zone]:
        return [z for z in self._zones.values() if z.cloud == cloud]

    def regions(self) -> List[str]:
        return sorted({z.region for z in self._zones.values()})

    def filter_zones(
        self,
        *,
        clouds: Optional[Sequence[str]] = None,
        regions: Optional[Sequence[str]] = None,
        exclude_zones: Optional[Sequence[str]] = None,
    ) -> List[Zone]:
        """Apply the user's ``any_of`` resource filter (Listing 1)."""
        out = []
        excl = set(exclude_zones or ())
        for z in self._zones.values():
            if clouds and z.cloud not in clouds:
                continue
            if regions and z.region not in regions:
                continue
            if z.name in excl:
                continue
            out.append(z)
        return out

    # -- instance types -------------------------------------------------
    def instance_type(self, name: str) -> InstanceType:
        return self._itypes[name]

    @property
    def instance_types(self) -> List[InstanceType]:
        return list(self._itypes.values())

    # -- pricing ---------------------------------------------------------
    def spot_price(self, itype: str, zone: str) -> float:
        t, z = self._itypes[itype], self._zones[zone]
        return t.spot_price * z.price_multiplier

    def od_price(self, itype: str, zone: str) -> float:
        t, z = self._itypes[itype], self._zones[zone]
        return t.od_price * z.price_multiplier

    def cheapest_zone(
        self, itype: str, candidates: Sequence[str], *, spot: bool = True
    ) -> str:
        """MIN-COST from Alg. 1 (line 20/22)."""
        if not candidates:
            raise ValueError("cheapest_zone: empty candidate set")
        price = self.spot_price if spot else self.od_price
        return min(candidates, key=lambda z: (price(itype, z), z))

    # -- clouds ----------------------------------------------------------
    def cloud(self, name: str) -> CloudSpec:
        return self._clouds[name]

    def rtt_ms(self, region_a: str, region_b: str) -> float:
        return region_rtt_ms(region_a, region_b)

    def bandwidth_gbps(self, zone_a: str, zone_b: str) -> float:
        """Locality-tiered link bandwidth between two catalog zones."""
        za, zb = self._zones[zone_a], self._zones[zone_b]
        return link_bandwidth_gbps(
            za.cloud, za.region, za.name, zb.cloud, zb.region, zb.name
        )

    def bandwidth_bytes_per_s(self, zone_a: str, zone_b: str) -> float:
        return self.bandwidth_gbps(zone_a, zone_b) * 1e9 / 8.0


def default_catalog() -> Catalog:
    return Catalog()

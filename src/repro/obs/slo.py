"""SLO burn-rate monitoring (Google-SRE style multi-window alerts).

A *burn rate* is the ratio between the observed error fraction over a
trailing window and the SLO's error budget (``1 - target``): burn 1.0
consumes exactly the budget over the SLO period, burn 14.4 consumes a
30-day budget in ~2 days.  Following the multiwindow-multi-burn-rate
recipe, an SLO is *alerting* when both a fast (default 5 min) and a
slow (default 1 h) trailing window exceed their thresholds — the fast
window gives low detection latency, the slow window suppresses blips.

Three SLOs are tracked where signals exist:

* ``availability`` — failed / (completed + failed), both replica
  models;
* ``ttft`` / ``tpot`` — per-request violations of the serving SLO
  targets, token replica model only (request cells have no token
  timings).

The monitor is fed once per sample window from the engines' shared
``WindowSampler`` choke point with *order-independent* inputs (window
deltas of cumulative counters, violation counts over the window's new
token records), so the legacy and vectorized engines emit byte
-identical ``SLOBurnEvent`` streams.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.events import SLOBurnEvent

__all__ = [
    "SLOBurnConfig",
    "SLOBurnMonitor",
    "burn_summary",
    "burn_table",
]


@dataclasses.dataclass(frozen=True)
class SLOBurnConfig:
    """Burn-rate windows and alert thresholds.

    Defaults are the classic SRE-workbook pairing: a 5-minute fast
    window at 14.4× budget burn plus a 1-hour slow window at 6×.
    """

    target: float = 0.99
    fast_window_s: float = 300.0
    slow_window_s: float = 3600.0
    fast_threshold: float = 14.4
    slow_threshold: float = 6.0

    def __post_init__(self) -> None:
        if not 0.0 < self.target < 1.0:
            raise ValueError(
                f"slo_burn.target must be in (0, 1), got {self.target}"
            )
        if self.fast_window_s <= 0 or self.slow_window_s <= 0:
            raise ValueError("slo_burn windows must be positive")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError(
                "slo_burn.fast_window_s must not exceed slow_window_s"
            )
        if self.fast_threshold <= 0 or self.slow_threshold <= 0:
            raise ValueError("slo_burn thresholds must be positive")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


#: SLO names in emission order
SLO_NAMES = ("availability", "ttft", "tpot")


class SLOBurnMonitor:
    """Accumulates per-window error counts; emits one event per window.

    All inputs are integer counts, so trailing-window aggregation is
    order-independent and the derived burn rates are bit-identical
    across engines.
    """

    def __init__(
        self,
        cfg: SLOBurnConfig,
        slo_ttft_s: Optional[float] = None,
        slo_tpot_s: Optional[float] = None,
    ) -> None:
        self.cfg = cfg
        self.slo_ttft_s = slo_ttft_s
        self.slo_tpot_s = slo_tpot_s
        # (t_end, {name: (err, tot)})
        self._hist: List[Tuple[float, Dict[str, Tuple[int, int]]]] = []

    def _burn(self, name: str, now: float, horizon: float):
        err = tot = 0
        t0 = now - horizon
        for t_end, counts in self._hist:
            if t_end <= t0:
                continue
            e, n = counts.get(name, (0, 0))
            err += e
            tot += n
        if tot == 0:
            return None
        return (err / tot) / self.cfg.budget

    def observe(
        self,
        now: float,
        *,
        d_completed: int,
        d_failed: int,
        new_records: Optional[Sequence] = None,
    ) -> SLOBurnEvent:
        """Fold one sample window in; return the window's burn event."""
        counts: Dict[str, Tuple[int, int]] = {
            "availability": (int(d_failed), int(d_completed + d_failed)),
        }
        if new_records is not None:
            if self.slo_ttft_s is not None:
                counts["ttft"] = (
                    sum(1 for r in new_records
                        if r.ttft_s > self.slo_ttft_s),
                    len(new_records),
                )
            if self.slo_tpot_s is not None:
                counts["tpot"] = (
                    sum(1 for r in new_records
                        if r.tpot_s > self.slo_tpot_s),
                    len(new_records),
                )
        self._hist.append((now, counts))

        cfg = self.cfg
        fields: Dict[str, Optional[float]] = {}
        alerting = []
        for name in SLO_NAMES:
            if name != "availability" and name not in counts:
                continue
            fast = self._burn(name, now, cfg.fast_window_s)
            slow = self._burn(name, now, cfg.slow_window_s)
            fields[f"{name}_fast"] = fast
            fields[f"{name}_slow"] = slow
            if (
                fast is not None
                and slow is not None
                and fast > cfg.fast_threshold
                and slow > cfg.slow_threshold
            ):
                alerting.append(name)
        return SLOBurnEvent(
            t=now,
            alerting=tuple(alerting) if alerting else None,
            **fields,
        )


def burn_summary(records: Sequence[dict]) -> Optional[dict]:
    """Aggregate ``slo_burn`` records into a per-cell summary.

    ``records`` is any event-record stream (dicts); non-burn records
    are ignored.  Returns ``None`` when the stream has no burn windows
    (e.g. detail below ``full``).
    """
    burns = [r for r in records if r.get("event") == "slo_burn"]
    if not burns:
        return None
    by_slo: Dict[str, int] = {}
    alert_windows = 0
    t_prev: Optional[float] = None
    alert_s = 0.0
    window_s = 0.0
    for r in burns:
        t = float(r["t"])
        dt = (t - t_prev) if t_prev is not None else 0.0
        if dt > 0:
            window_s = dt
        t_prev = t
        names = r.get("alerting") or []
        if names:
            alert_windows += 1
            alert_s += window_s
            for n in names:
                by_slo[n] = by_slo.get(n, 0) + 1
    return {
        "windows": len(burns),
        "alert_windows": alert_windows,
        "alert_minutes": round(alert_s / 60.0, 6),
        "by_slo": {k: by_slo[k] for k in sorted(by_slo)},
    }


def burn_table(records: Sequence[dict]) -> str:
    """Render burn-rate windows as an aligned text table (CLI ``slo``)."""
    burns = [r for r in records if r.get("event") == "slo_burn"]
    if not burns:
        return "no slo_burn events (observability detail must be 'full')"
    cols = ["t"]
    for name in SLO_NAMES:
        for spd in ("fast", "slow"):
            key = f"{name}_{spd}"
            if any(key in r for r in burns):
                cols.append(key)
    cols.append("alerting")
    rows = [cols]
    for r in burns:
        row = [f"{float(r['t']):.0f}"]
        for key in cols[1:-1]:
            v = r.get(key)
            row.append("-" if v is None else f"{v:.3f}")
        row.append(",".join(r.get("alerting") or []) or "-")
        rows.append(row)
    widths = [max(len(row[i]) for row in rows) for i in range(len(cols))]
    lines = [
        "  ".join(c.rjust(w) for c, w in zip(row, widths)) for row in rows
    ]
    summ = burn_summary(records) or {}
    lines.append(
        f"windows={summ.get('windows', 0)} "
        f"alert_windows={summ.get('alert_windows', 0)} "
        f"alert_minutes={summ.get('alert_minutes', 0.0)}"
    )
    return "\n".join(lines)

"""Typed, schema-versioned observability events.

Every event is a frozen dataclass with a ``KIND`` tag and a
``to_record()`` that renders a plain JSON-able dict (``None`` fields
omitted, ``schema`` and ``event`` keys added).  Records are the exchange
format: the JSONL exporter, the Chrome-trace converter, the attribution
report and the CLI all consume records, so a run can be analyzed either
live (event objects) or from its log file (dicts) with the same code.

Determinism contract: events carry *simulation* time only — no wall
clocks, no ids derived from memory addresses — so two decision-identical
engines produce byte-identical logs (the differential test in
tests/test_obs.py holds legacy == vector on the serialized bytes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "SCHEMA_VERSION",
    "Event",
    "PolicyDecisionEvent",
    "ReplicaLifecycleEvent",
    "MigrationPlanEvent",
    "PreemptionWarningEvent",
    "LaunchFailureEvent",
    "WindowSampleEvent",
    "SLOBurnEvent",
    "AutoscalerTargetEvent",
    "LIFECYCLE_PHASES",
    "control_plane_records",
]

#: bump when a field changes meaning; consumers gate on this
SCHEMA_VERSION = 1

#: the replica lifecycle state machine the timeline renders
LIFECYCLE_PHASES = (
    "provision", "ready", "draining", "migrating", "dead",
)


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: a tagged record at simulation time ``t`` (seconds)."""

    t: float

    KIND = "event"

    def to_record(self) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"schema": SCHEMA_VERSION, "event": self.KIND}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is None:
                continue
            if isinstance(v, Mapping):
                v = dict(v)
            rec[f.name] = v
        return rec


@dataclasses.dataclass(frozen=True)
class PolicyDecisionEvent(Event):
    """One executed policy action, with the policy's machine-readable
    *reason* (zone rank, forecast risk, buffer targets, ...) attached.

    ``instance_id`` links a launch decision to the replica it produced —
    the attribution report charges that replica's cost to this event.
    """

    action: str = ""                    # launch_spot|launch_ondemand|terminate
    zone: Optional[str] = None
    instance_id: Optional[int] = None
    reason: Optional[Dict[str, Any]] = None

    KIND = "decision"


@dataclasses.dataclass(frozen=True)
class ReplicaLifecycleEvent(Event):
    """A replica crossing a lifecycle phase boundary.

    ``provision`` carries the billing context (kind/zone/hourly price);
    ``dead`` carries the ``cause`` (``preemption`` | ``terminate``);
    ``draining``/``migrating`` come from the migration runtime during a
    grace window.
    """

    phase: str = ""                     # one of LIFECYCLE_PHASES
    instance_id: int = -1
    zone: Optional[str] = None
    kind: Optional[str] = None          # spot | ondemand
    hourly_price: Optional[float] = None
    cause: Optional[str] = None

    KIND = "lifecycle"


@dataclasses.dataclass(frozen=True)
class MigrationPlanEvent(Event):
    """The drain/migrate/kill plan executed for one warned preemption."""

    instance_id: int = -1
    n_drained: int = 0
    n_migrated: int = 0
    n_killed: int = 0
    migrated_kv_tokens: int = 0
    transfer_s: float = 0.0
    grace_s: float = 0.0

    KIND = "migration_plan"


@dataclasses.dataclass(frozen=True)
class PreemptionWarningEvent(Event):
    """An advance preemption warning delivered to a replica."""

    zone: str = ""
    instance_id: Optional[int] = None

    KIND = "warning"


@dataclasses.dataclass(frozen=True)
class LaunchFailureEvent(Event):
    """A launch attempt that found no spot capacity in the zone."""

    zone: str = ""
    kind: str = "spot"

    KIND = "launch_failure"


@dataclasses.dataclass(frozen=True)
class WindowSampleEvent(Event):
    """A windowed data-plane sample (detail level ``full`` only).

    Every field is defined order-independently (cumulative counters and
    instantaneous cluster state at the window boundary), so decision-
    identical engines emit identical samples even when their intra-tick
    processing order differs.
    """

    queue_depth: int = 0                # arrived − completed − failed
    n_ready: int = 0
    n_spot: int = 0                     # ready spot replicas
    n_od: int = 0                       # ready on-demand replicas
    cost_per_h: float = 0.0             # Σ hourly_price over live replicas
    n_completed: int = 0                # cumulative
    n_failed: int = 0                   # cumulative
    goodput_rps: float = 0.0            # completions this window / window_s
    ttft_p50_s: Optional[float] = None  # token mode: window TTFT median

    KIND = "window"


@dataclasses.dataclass(frozen=True)
class SLOBurnEvent(Event):
    """Multi-window SLO burn rates at one sample-window boundary.

    Burn = (trailing-window error fraction) / (1 − SLO target); one
    event per data-plane sample window (detail level ``full``).  A
    ``None`` burn means no traffic in that trailing window (omitted
    from the record); ``ttft``/``tpot`` exist only for token-model
    cells.  ``alerting`` lists SLOs whose fast *and* slow burns both
    exceed their thresholds (see :class:`repro.obs.slo.SLOBurnConfig`).
    """

    availability_fast: Optional[float] = None
    availability_slow: Optional[float] = None
    ttft_fast: Optional[float] = None
    ttft_slow: Optional[float] = None
    tpot_fast: Optional[float] = None
    tpot_slow: Optional[float] = None
    alerting: Optional[Tuple[str, ...]] = None

    KIND = "slo_burn"

    def to_record(self) -> Dict[str, Any]:
        rec = super().to_record()
        if self.alerting is not None:
            rec["alerting"] = list(self.alerting)
        return rec


@dataclasses.dataclass(frozen=True)
class AutoscalerTargetEvent(Event):
    """The autoscaler target changed (includes the initial value)."""

    target: int = 0
    prev_target: Optional[int] = None

    KIND = "autoscaler_target"


def control_plane_records(
    records: Iterable[Mapping[str, Any]]
) -> List[Dict[str, Any]]:
    """The control-plane subset of a record stream.

    Window samples, burn-rate windows and migration activity are
    data-plane products; the JAX engine's phase-A replay reproduces
    everything else exactly, so this is the stream its parity is
    tested on.
    """
    out: List[Dict[str, Any]] = []
    for r in records:
        if r.get("event") in ("window", "migration_plan", "slo_burn"):
            continue
        if r.get("event") == "lifecycle" and r.get("phase") in (
            "draining", "migrating"
        ):
            continue
        out.append(dict(r))
    return out

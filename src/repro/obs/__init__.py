"""repro.obs: unified event tracing, metrics and decision attribution.

The observability substrate every engine shares:

* :mod:`repro.obs.events` — typed, schema-versioned event dataclasses
  for control-plane decisions (with machine-readable *reasons*), replica
  lifecycle transitions, migration plans, preemption warnings and
  windowed data-plane samples.
* :mod:`repro.obs.registry` — a run-scoped metrics registry
  (counters / gauges / histograms with labels) replacing the old
  process-global ``FALLBACK_COUNTS`` module dicts.
* :mod:`repro.obs.recorder` — the per-run :class:`ObsRecorder` that the
  cluster simulator and serving engines emit into, with a ``detail``
  level knob (``off`` | ``decisions`` | ``full``).
* :mod:`repro.obs.export` — byte-deterministic JSONL event logs and a
  Chrome-trace-event (Perfetto-loadable) per-replica timeline.
* :mod:`repro.obs.attribution` — charges each dollar and each failed
  request back to the policy decision (or preemption) that produced it.
* ``python -m repro.obs`` — summarize a run, diff two runs, render the
  attribution report, convert a log to a Perfetto trace.

Events are emitted at the *shared* choke points (``ClusterSimulator``,
``MigrationRuntime``, the engine tick), so the legacy and vectorized
engines produce byte-identical JSONL on the same spec and the JAX engine
reproduces the control-plane stream through its phase-A replay —
differential-testable like every other engine surface in this repo
(tests/test_obs.py).
"""

from repro.obs.attribution import attribution_report
from repro.obs.events import (
    SCHEMA_VERSION,
    AutoscalerTargetEvent,
    Event,
    LaunchFailureEvent,
    MigrationPlanEvent,
    PolicyDecisionEvent,
    PreemptionWarningEvent,
    ReplicaLifecycleEvent,
    SLOBurnEvent,
    WindowSampleEvent,
    control_plane_records,
)
from repro.obs.export import (
    chrome_trace,
    diff_summaries,
    dumps_jsonl,
    read_jsonl,
    summarize,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.recorder import DETAIL_LEVELS, ObsRecorder
from repro.obs.registry import (
    MetricsRegistry,
    get_registry,
    use_registry,
)
from repro.obs.slo import (
    SLOBurnConfig,
    SLOBurnMonitor,
    burn_summary,
    burn_table,
)
from repro.obs.spans import SpanCollector, span_sampled

__all__ = [
    "SCHEMA_VERSION",
    "DETAIL_LEVELS",
    "Event",
    "PolicyDecisionEvent",
    "ReplicaLifecycleEvent",
    "MigrationPlanEvent",
    "PreemptionWarningEvent",
    "LaunchFailureEvent",
    "WindowSampleEvent",
    "SLOBurnEvent",
    "AutoscalerTargetEvent",
    "control_plane_records",
    "ObsRecorder",
    "SLOBurnConfig",
    "SLOBurnMonitor",
    "burn_summary",
    "burn_table",
    "SpanCollector",
    "span_sampled",
    "MetricsRegistry",
    "get_registry",
    "use_registry",
    "dumps_jsonl",
    "write_jsonl",
    "read_jsonl",
    "chrome_trace",
    "write_chrome_trace",
    "summarize",
    "diff_summaries",
    "attribution_report",
]

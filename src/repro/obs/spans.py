"""Per-request data-plane spans (deterministic, run-ordinal keyed).

A *span* is the full life of one sampled request — queue wait, dispatch
(LB choice), replica queue / continuous-batch admission, prefill chunks,
decode, migration hops (drain / transfer / resume, linked to the
migration plan event), preemption retries and the final
completion / timeout / rejection — recorded as one schema-v1 JSON
record with contiguous, time-ordered segments.

Design constraints (mirrors ``repro.obs.events``):

* **byte-identical across engines** — the legacy ``ServingSimulator``
  and the ``VectorizedServingEngine`` tap the collector with the same
  float values at the same simulated instants, and records serialize
  sorted by ordinal, so the JSONL streams match byte for byte
  regardless of internal iteration order;
* **deterministic sampling without an RNG** — whether a request is
  traced depends only on its run ordinal (position in the stable
  arrival-time sort of the request tape) and the configured rate, via a
  Knuth multiplicative hash.  No RNG state, no seed plumbing, and every
  engine (including the JAX phase-B reconstruction) agrees on the
  sampled set by construction;
* **cheap when off** — engines bind ``want_l`` / ``want_ids`` locally
  and skip all collector calls for unsampled ordinals, so the default
  1% rate stays inside the observability overhead budget.

Per-request call-sequence contract (what byte-identity actually
requires): for any single ordinal, both engines issue the same
collector calls with the same arguments in the same order.  Cross
-request interleaving is free to differ — records are keyed and sorted
by ordinal.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.obs.events import SCHEMA_VERSION

__all__ = ["span_sampled", "SpanCollector"]

#: Knuth multiplicative hash constant (2^32 / phi)
_HASH_MULT = 2654435761
_HASH_ADD = 12345
_HASH_MOD = 1 << 32


def span_sampled(ordinal: int, rate: float) -> bool:
    """Deterministic, seedless per-ordinal sampling decision."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    h = (ordinal * _HASH_MULT + _HASH_ADD) & 0xFFFFFFFF
    return h < int(rate * _HASH_MOD)


class _Trace:
    __slots__ = (
        "arrival",
        "rtt",
        "attempts",
        "outcome",
        "finish",
        "e2e",
        "first",
        "segs",
        "open",
    )

    def __init__(self, arrival: float) -> None:
        self.arrival = float(arrival)
        self.rtt: Optional[float] = None
        self.attempts = 1
        self.outcome: Optional[str] = None
        self.finish: Optional[float] = None
        self.e2e: Optional[float] = None
        self.first: Optional[float] = None
        self.segs: List[dict] = []
        self.open: Optional[dict] = None


class SpanCollector:
    """Collects per-request span traces for the sampled ordinal set.

    ``requests`` is the raw request tape; ordinals are positions in the
    stable sort by ``arrival_s`` — exactly the tape order both serving
    engines compile, so the vector engine's tape index *is* the
    ordinal and the legacy engine maps ``request.id`` through
    ``want_ids``.
    """

    def __init__(self, rate: float, requests: Sequence) -> None:
        self.rate = float(rate)
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        self.n = len(reqs)
        #: per-ordinal sampled flag (vector engine: ordinal == index)
        self.want_l: List[bool] = [
            span_sampled(o, self.rate) for o in range(self.n)
        ]
        #: request id -> ordinal, sampled requests only (legacy engine)
        self.want_ids: Dict[int, int] = {
            r.id: o for o, r in enumerate(reqs) if self.want_l[o]
        }
        self._traces: Dict[int, _Trace] = {}

    # -- internals ----------------------------------------------------
    def _get(self, o: int, arrival: float) -> _Trace:
        tr = self._traces.get(o)
        if tr is None:
            tr = self._traces[o] = _Trace(arrival)
            tr.open = {"name": "queue", "t0_s": tr.arrival}
        return tr

    @staticmethod
    def _close(tr: _Trace, t: float, cut: Optional[str] = None) -> None:
        seg = tr.open
        if seg is None:
            return
        seg["t1_s"] = float(t)
        if cut is not None:
            seg["cut"] = cut
        tr.segs.append(seg)
        tr.open = None

    @staticmethod
    def _open(tr: _Trace, name: str, t: float, **kw) -> None:
        seg = {"name": name, "t0_s": float(t)}
        for k, v in kw.items():
            if v is not None:
                seg[k] = v
        tr.open = seg

    # -- request-model + shared taps ----------------------------------
    def dispatch(
        self, o: int, t: float, replica: int, rtt_s: float,
        arrival: float, token: bool = False,
    ) -> None:
        """LB routed the request to ``replica`` (dense run ordinal)."""
        tr = self._get(o, arrival)
        if tr.outcome is not None:
            return
        self._close(tr, t)
        tr.rtt = float(rtt_s)
        self._open(
            tr, "admit" if token else "rqueue", t, replica=int(replica)
        )

    def start(self, o: int, t: float) -> None:
        """Request left the replica queue and began service."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        rep = (tr.open or {}).get("replica")
        self._close(tr, t)
        self._open(tr, "service", t, replica=rep)

    def finish(self, o: int, t: float, outcome: str, e2e: float) -> None:
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        self._close(tr, t)
        tr.outcome = outcome
        tr.finish = float(t)
        tr.e2e = float(e2e)

    def expire(self, o: int, t: float, arrival: float) -> None:
        """Request timed out in the pending or replica queue."""
        tr = self._get(o, arrival)
        if tr.outcome is not None:     # e.g. already rejected
            return
        self._close(tr, t, cut="timeout")
        tr.outcome = "timeout"
        tr.finish = float(t)

    def reject(self, o: int, t: float) -> None:
        """KV-budget admission rejected the request outright."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        self._close(tr, t, cut="reject")
        tr.outcome = "rejected"
        tr.finish = float(t)

    def preempt(self, o: int, t: float) -> None:
        """Replica died; the request re-pends (KV/progress lost)."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        self._close(tr, t, cut="preempt")
        tr.attempts += 1
        self._open(tr, "queue", t)

    # -- token-model taps (continuous batching) -----------------------
    def token_join(self, o: int, t: float, prefilling: bool) -> None:
        """Sequence admitted into a running batch."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        rep = (tr.open or {}).get("replica")
        self._close(tr, t)
        if prefilling:
            self._open(
                tr, "prefill", t, replica=rep, chunks=0, tokens=0
            )
        else:
            self._open(tr, "decode", t, replica=rep)

    def token_chunk(self, o: int, tokens: int) -> None:
        """One chunked-prefill slice processed for this sequence."""
        tr = self._traces.get(o)
        if tr is None or tr.open is None or tr.outcome is not None:
            return
        seg = tr.open
        seg["chunks"] = seg.get("chunks", 0) + 1
        seg["tokens"] = seg.get("tokens", 0) + int(tokens)

    def token_prefill_done(self, o: int, t: float) -> None:
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        rep = (tr.open or {}).get("replica")
        self._close(tr, t)
        self._open(tr, "decode", t, replica=rep)

    def finish_token(
        self, o: int, first_s: float, finish_s: float,
        overhead_s: float, outcome: str, e2e: float,
    ) -> None:
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        end = finish_s - overhead_s
        rep = (tr.open or {}).get("replica")
        self._close(tr, end)
        if overhead_s > 0.0:
            self._open(tr, "overhead", end, replica=rep)
            self._close(tr, finish_s)
        tr.outcome = outcome
        tr.finish = float(finish_s)
        tr.e2e = float(e2e)
        if math.isfinite(first_s):
            tr.first = float(first_s)

    def migrate(
        self, o: int, t: float, to_replica: int,
        transfer_s: float, plan_t: float,
    ) -> None:
        """Preemption warning: KV state starts transferring."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        self._close(tr, t, cut="migrate")
        self._open(
            tr, "transfer", t,
            to=int(to_replica),
            transfer_s=float(transfer_s),
            plan_t_s=float(plan_t),
        )

    def migrate_arrive(self, o: int, t: float, replica: int) -> None:
        """Transfer complete; sequence waits to rejoin a batch."""
        tr = self._traces.get(o)
        if tr is None or tr.outcome is not None:
            return
        self._close(tr, t)
        self._open(tr, "admit", t, replica=int(replica))

    # -- finalization + export ----------------------------------------
    def finalize(self, horizon_s: float) -> None:
        """Close traces still open at the end-of-run drain."""
        for tr in self._traces.values():
            if tr.outcome is not None:
                continue
            if tr.open is not None:
                t1 = max(float(horizon_s), tr.open["t0_s"])
                self._close(tr, t1, cut="drain")
            tr.outcome = "unresolved"

    def records(self) -> List[dict]:
        """Schema-v1 span records, sorted by ordinal."""
        out = []
        for o in sorted(self._traces):
            tr = self._traces[o]
            rec = {
                "schema": SCHEMA_VERSION,
                "event": "span",
                "ordinal": o,
                "arrival_s": tr.arrival,
                "attempts": tr.attempts,
                "outcome": tr.outcome or "unresolved",
                "segments": list(tr.segs),
            }
            if tr.rtt is not None:
                rec["rtt_s"] = tr.rtt
            if tr.finish is not None:
                rec["finish_s"] = tr.finish
            if tr.e2e is not None:
                rec["e2e_s"] = tr.e2e
            if tr.first is not None:
                rec["first_token_s"] = tr.first
            out.append(rec)
        return out

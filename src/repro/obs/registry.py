"""Run-scoped metrics registry: counters, gauges, histograms with labels.

The old telemetry surface was two module-global ``FALLBACK_COUNTS``
dicts (``serving/latency.py``, ``distributed/sharding.py``): counts bled
across sweep cells and repeated ``Service.run()`` calls, and counts
incremented inside ``ProcessPoolExecutor`` workers vanished.  The
registry fixes both: each run owns a :class:`MetricsRegistry` (reachable
from library code via :func:`get_registry` inside a
:func:`use_registry` scope), its :meth:`~MetricsRegistry.snapshot` is a
plain JSON-able dict that pickles across process boundaries, and
snapshots :meth:`merge <MetricsRegistry.merge_snapshots>` associatively
so a scenario suite can aggregate its cells.

Label handling: metrics are keyed by ``name{k=v,...}`` with labels
sorted by key, so the snapshot's key order is deterministic and two
registries that saw the same increments serialize identically.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterable, Iterator, List, Mapping, Optional

__all__ = ["MetricsRegistry", "get_registry", "use_registry"]


def _series_key(name: str, labels: Mapping[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Counters / gauges / histograms for one run."""

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def inc(self, name: str, value: float = 1, **labels: Any) -> None:
        key = _series_key(name, labels)
        self._counters[key] = self._counters.get(key, 0) + value

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        self._gauges[_series_key(name, labels)] = value

    def observe(self, name: str, value: float, **labels: Any) -> None:
        key = _series_key(name, labels)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = {
                "count": 0, "sum": 0.0,
                "min": float("inf"), "max": float("-inf"),
            }
        h["count"] += 1
        h["sum"] += value
        h["min"] = min(h["min"], value)
        h["max"] = max(h["max"], value)

    # ------------------------------------------------------------------
    def counter(self, name: str, **labels: Any) -> float:
        return self._counters.get(_series_key(name, labels), 0)

    def __bool__(self) -> bool:
        return bool(self._counters or self._gauges or self._hists)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """A plain JSON-able, picklable view (sorted keys)."""
        out: Dict[str, Any] = {}
        if self._counters:
            out["counters"] = {
                k: self._counters[k] for k in sorted(self._counters)
            }
        if self._gauges:
            out["gauges"] = {k: self._gauges[k] for k in sorted(self._gauges)}
        if self._hists:
            out["histograms"] = {
                k: dict(self._hists[k]) for k in sorted(self._hists)
            }
        return out

    @staticmethod
    def merge_snapshots(
        snaps: Iterable[Optional[Mapping[str, Any]]]
    ) -> Dict[str, Any]:
        """Aggregate cell snapshots: counters/histogram moments add,
        gauges keep the last written value (cells are ordered)."""
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, float]] = {}
        for snap in snaps:
            if not snap:
                continue
            for k, v in snap.get("counters", {}).items():
                counters[k] = counters.get(k, 0) + v
            gauges.update(snap.get("gauges", {}))
            for k, h in snap.get("histograms", {}).items():
                m = hists.get(k)
                if m is None:
                    hists[k] = dict(h)
                else:
                    m["count"] += h["count"]
                    m["sum"] += h["sum"]
                    m["min"] = min(m["min"], h["min"])
                    m["max"] = max(m["max"], h["max"])
        out: Dict[str, Any] = {}
        if counters:
            out["counters"] = {k: counters[k] for k in sorted(counters)}
        if gauges:
            out["gauges"] = {k: gauges[k] for k in sorted(gauges)}
        if hists:
            out["histograms"] = {k: hists[k] for k in sorted(hists)}
        return out


# ----------------------------------------------------------------------
# active-registry scope: library code with no run handle (the latency
# model factory, the sharding helpers) records into whatever registry
# the enclosing run activated; outside any scope a process-default
# registry absorbs the counts so telemetry is never silently dropped.

_DEFAULT = MetricsRegistry()
_STACK: List[MetricsRegistry] = []


def get_registry() -> MetricsRegistry:
    """The innermost active registry, or the process default."""
    return _STACK[-1] if _STACK else _DEFAULT


@contextlib.contextmanager
def use_registry(registry: MetricsRegistry) -> Iterator[MetricsRegistry]:
    """Route :func:`get_registry` to ``registry`` within the scope."""
    _STACK.append(registry)
    try:
        yield registry
    finally:
        _STACK.pop()

"""Decision attribution: charge dollars and SLO damage to decisions.

SkyServe's wins come from control-plane *decisions*; an aggregate cost
number cannot say which decision earned or wasted it.  This module
replays an event log and produces the ledger:

* **Cost** — every ``provision`` lifecycle event opens a billing span
  (hourly price × lifetime to its ``dead`` event, or to the run horizon
  for replicas alive at the end), and the span is charged to the launch
  decision that produced the replica (launch decisions record the
  ``instance_id`` they created).  Spans no decision claims (e.g. logs
  truncated mid-run) fall into ``"unattributed"``.
* **Failures** — failed-request deltas between consecutive window
  samples are charged to the most recent preemption / launch-failure
  inside a lookback window, else to ``steady_state``; without window
  samples (detail < full) only the totals row is emitted.

The report is pure arithmetic over records — it works identically on
live events and on a JSONL file read back.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping, Optional, Union

from repro.obs.events import SCHEMA_VERSION, Event

__all__ = ["attribution_report"]

Recordish = Union[Event, Mapping[str, Any]]

#: a failure is blamed on a disruption at most this many seconds older
FAILURE_LOOKBACK_S = 600.0


def _records(events: Iterable[Recordish]) -> List[Dict[str, Any]]:
    out = []
    for e in events:
        out.append(e.to_record() if isinstance(e, Event) else dict(e))
    return out


#: span segments a request spends *waiting* in (vs being served)
_WAIT_SEGS = ("queue", "rqueue", "admit", "transfer")


def _span_section(spans: Iterable[Mapping[str, Any]]) -> Dict[str, Any]:
    """Aggregate request-span records into a where-time-goes ledger."""
    n = 0
    outcomes: Dict[str, int] = {}
    seg_s: Dict[str, float] = {}
    waits: List[float] = []
    retried = migrated = 0
    for rec in spans:
        n += 1
        oc = str(rec.get("outcome", "unresolved"))
        outcomes[oc] = outcomes.get(oc, 0) + 1
        if int(rec.get("attempts", 1)) > 1:
            retried += 1
        wait = 0.0
        hop = False
        for s in rec.get("segments") or []:
            dur = max(float(s["t1_s"]) - float(s["t0_s"]), 0.0)
            name = str(s["name"])
            seg_s[name] = seg_s.get(name, 0.0) + dur
            if name in _WAIT_SEGS:
                wait += dur
            hop = hop or name == "transfer"
        migrated += hop
        waits.append(wait)
    waits.sort()
    p95 = waits[min(int(0.95 * len(waits)), len(waits) - 1)] \
        if waits else None
    return {
        "n_spans": n,
        "outcomes": {k: outcomes[k] for k in sorted(outcomes)},
        "n_retried": retried,
        "n_migrated": migrated,
        "seconds_by_segment": {
            k: round(seg_s[k], 6) for k in sorted(seg_s)
        },
        "wait_mean_s": (
            round(sum(waits) / len(waits), 6) if waits else None
        ),
        "wait_p95_s": round(p95, 6) if p95 is not None else None,
    }


def attribution_report(
    events: Iterable[Recordish],
    *,
    horizon_s: Optional[float] = None,
    top: int = 10,
    spans: Optional[Iterable[Mapping[str, Any]]] = None,
) -> Dict[str, Any]:
    """Render the decision-attribution ledger for one event stream.

    Pass ``spans`` (schema-v1 request-span records) to extend the
    ledger with a ``request_spans`` section: per-outcome counts,
    seconds charged to each span segment (where sampled requests spend
    their time), and queueing-wait aggregates.
    """
    span_records = list(spans) if spans is not None else None
    records = _records(events)
    if horizon_s is None:
        horizon_s = max(
            (float(r.get("t", 0.0)) for r in records), default=0.0
        )

    # --- index decisions by the instance they launched ----------------
    launch_by_iid: Dict[int, Dict[str, Any]] = {}
    decisions: List[Dict[str, Any]] = []
    for r in records:
        if r.get("event") != "decision":
            continue
        d = {
            "t": float(r.get("t", 0.0)),
            "action": r.get("action"),
            "zone": r.get("zone"),
            "instance_id": r.get("instance_id"),
            "reason": r.get("reason"),
            "cost_usd": 0.0,
            "replica_lifetime_s": 0.0,
        }
        decisions.append(d)
        if d["instance_id"] is not None and str(
            d["action"] or ""
        ).startswith("launch"):
            launch_by_iid[int(d["instance_id"])] = d

    # --- billing spans from lifecycle events --------------------------
    provision: Dict[int, Dict[str, Any]] = {}
    spans: List[Dict[str, Any]] = []
    for r in records:
        if r.get("event") != "lifecycle":
            continue
        iid = int(r.get("instance_id", -1))
        phase = r.get("phase")
        if phase == "provision":
            provision[iid] = r
        elif phase == "dead":
            p = provision.pop(iid, None)
            if p is not None:
                spans.append({
                    "instance_id": iid,
                    "t0": float(p.get("t", 0.0)),
                    "t1": float(r.get("t", 0.0)),
                    "hourly_price": float(p.get("hourly_price", 0.0)),
                    "kind": p.get("kind"),
                    "zone": p.get("zone"),
                })
    for iid, p in sorted(provision.items()):     # alive at run end
        spans.append({
            "instance_id": iid,
            "t0": float(p.get("t", 0.0)),
            "t1": float(horizon_s),
            "hourly_price": float(p.get("hourly_price", 0.0)),
            "kind": p.get("kind"),
            "zone": p.get("zone"),
        })

    # --- charge spans to decisions ------------------------------------
    unattributed = 0.0
    by_action: Dict[str, Dict[str, float]] = {}
    for s in spans:
        lifetime = max(s["t1"] - s["t0"], 0.0)
        cost = s["hourly_price"] * lifetime / 3600.0
        d = launch_by_iid.get(s["instance_id"])
        if d is None:
            unattributed += cost
            bucket = "unattributed"
        else:
            d["cost_usd"] += cost
            d["replica_lifetime_s"] += lifetime
            bucket = str(d["action"])
        agg = by_action.setdefault(
            bucket, {"cost_usd": 0.0, "n_replicas": 0}
        )
        agg["cost_usd"] += cost
        agg["n_replicas"] += 1

    # --- failure attribution from window samples ----------------------
    disruptions: List[Dict[str, Any]] = [
        r for r in records
        if r.get("event") == "launch_failure"
        or (r.get("event") == "lifecycle"
            and r.get("phase") == "dead"
            and r.get("cause") == "preemption")
    ]
    failures = {"preemption": 0, "launch_failure": 0, "steady_state": 0}
    windows = [r for r in records if r.get("event") == "window"]
    prev_failed = 0
    for w in windows:
        t = float(w.get("t", 0.0))
        n_failed = int(w.get("n_failed", 0))
        delta = n_failed - prev_failed
        prev_failed = n_failed
        if delta <= 0:
            continue
        blame = "steady_state"
        best_t = None
        for d in disruptions:
            td = float(d.get("t", 0.0))
            if td <= t and t - td <= FAILURE_LOOKBACK_S:
                if best_t is None or td >= best_t:
                    best_t = td
                    blame = (
                        "launch_failure"
                        if d.get("event") == "launch_failure"
                        else "preemption"
                    )
        failures[blame] += delta

    total_failed = int(windows[-1].get("n_failed", 0)) if windows else None

    decisions.sort(key=lambda d: (-d["cost_usd"], d["t"]))
    total_cost = sum(s["hourly_price"] * max(s["t1"] - s["t0"], 0.0)
                     for s in spans) / 3600.0
    return {
        "schema": SCHEMA_VERSION,
        "horizon_s": float(horizon_s),
        "total_cost_usd": round(total_cost, 6),
        "unattributed_cost_usd": round(unattributed, 6),
        "n_decisions": len(decisions),
        "n_replicas": len(spans),
        "cost_by_action": {
            k: {
                "cost_usd": round(v["cost_usd"], 6),
                "n_replicas": int(v["n_replicas"]),
            }
            for k, v in sorted(by_action.items())
        },
        "top_decisions": [
            {
                "t": d["t"],
                "action": d["action"],
                "zone": d["zone"],
                "instance_id": d["instance_id"],
                "cost_usd": round(d["cost_usd"], 6),
                "replica_lifetime_s": round(d["replica_lifetime_s"], 6),
                "reason": d["reason"],
            }
            for d in decisions[: max(top, 0)]
        ],
        "failed_requests": {
            "total": total_failed,
            "by_cause": failures if windows else None,
            "note": (
                "per-cause attribution needs window samples "
                "(observability detail: full)"
                if not windows else None
            ),
        },
        **(
            {"request_spans": _span_section(span_records)}
            if span_records is not None else {}
        ),
    }

"""Exporters: byte-deterministic JSONL logs and Chrome-trace timelines.

JSONL is the canonical artifact (one event record per line, sorted keys,
compact separators, no wall-clock stamps) — two decision-identical runs
produce byte-identical files, which is what the differential tests pin.
The Chrome-trace converter renders the same records as a Perfetto /
``chrome://tracing`` loadable timeline: one track per replica with
provisioning/serving/grace spans, a policy track with instant decision
markers, and counter tracks from the window samples.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Mapping, Optional, Union

from repro.obs.events import SCHEMA_VERSION, Event

__all__ = [
    "dumps_jsonl",
    "write_jsonl",
    "read_jsonl",
    "chrome_trace",
    "write_chrome_trace",
    "summarize",
    "diff_summaries",
]

Recordish = Union[Event, Mapping[str, Any]]


def _as_records(events: Iterable[Recordish]) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    for e in events:
        out.append(e.to_record() if isinstance(e, Event) else dict(e))
    return out


def dumps_jsonl(events: Iterable[Recordish]) -> str:
    """Serialize events to JSONL text (deterministic bytes)."""
    lines = [
        json.dumps(r, sort_keys=True, separators=(",", ":"))
        for r in _as_records(events)
    ]
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(events: Iterable[Recordish], path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(dumps_jsonl(events))
    return path


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    records: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


# ----------------------------------------------------------------------
# Chrome trace events (Perfetto-loadable)

def _us(t: float) -> float:
    return round(t * 1e6, 3)


def chrome_trace(
    events: Iterable[Recordish],
    spans: Optional[Iterable[Mapping[str, Any]]] = None,
    token_windows: Optional[Iterable[Mapping[str, Any]]] = None,
) -> Dict[str, Any]:
    """Records -> a Chrome-trace-event JSON object.

    Load the written file in https://ui.perfetto.dev (or
    ``chrome://tracing``): replicas appear as one timeline row each
    (provisioning -> serving -> grace spans), policy decisions and
    preemption warnings as instant markers, queue depth and fleet $/h
    as counter tracks.

    ``spans`` takes schema-v1 request-span records
    (``SpanCollector.records()``): each sampled request renders as an
    outer slice with its segments nested inside, grouped per replica
    (run ordinal) in a second "requests (sampled)" process.
    ``token_windows`` takes ``TokenStats.windows`` entries and adds
    goodput / windowed-SLO-attainment counter tracks.
    """
    records = _as_records(events)
    trace: List[Dict[str, Any]] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": "repro.obs run"}},
        {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
         "args": {"name": "policy"}},
    ]
    # one thread per replica, tid assigned in order of first appearance
    tids: Dict[int, int] = {}

    def tid_of(instance_id: int) -> int:
        tid = tids.get(instance_id)
        if tid is None:
            tid = tids[instance_id] = len(tids) + 1
            trace.append({
                "ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                "args": {"name": f"replica {instance_id}"},
            })
        return tid

    # span assembly state per replica
    open_span: Dict[int, Dict[str, Any]] = {}
    horizon = 0.0
    for r in records:
        horizon = max(horizon, float(r.get("t", 0.0)))
    for r in records:
        kind = r.get("event")
        t = float(r.get("t", 0.0))
        if kind == "decision":
            trace.append({
                "ph": "i", "pid": 0, "tid": 0, "s": "t",
                "ts": _us(t), "name": r.get("action", "decision"),
                "args": {
                    k: r[k] for k in ("zone", "instance_id", "reason")
                    if k in r
                },
            })
        elif kind == "lifecycle":
            iid = int(r.get("instance_id", -1))
            tid = tid_of(iid)
            phase = r.get("phase")
            if phase == "provision":
                open_span[iid] = {
                    "t0": t, "name": "provisioning",
                    "args": {
                        k: r[k]
                        for k in ("zone", "kind", "hourly_price")
                        if k in r
                    },
                }
            elif phase == "ready":
                span = open_span.pop(iid, None)
                if span is not None:
                    trace.append({
                        "ph": "X", "pid": 0, "tid": tid,
                        "ts": _us(span["t0"]),
                        "dur": _us(t - span["t0"]),
                        "name": span["name"], "args": span["args"],
                    })
                open_span[iid] = {"t0": t, "name": "serving", "args": {}}
            elif phase in ("draining", "migrating"):
                trace.append({
                    "ph": "i", "pid": 0, "tid": tid, "s": "t",
                    "ts": _us(t), "name": phase, "args": {},
                })
            elif phase == "dead":
                span = open_span.pop(iid, None)
                if span is not None:
                    trace.append({
                        "ph": "X", "pid": 0, "tid": tid,
                        "ts": _us(span["t0"]),
                        "dur": _us(t - span["t0"]),
                        "name": span["name"], "args": span["args"],
                    })
                trace.append({
                    "ph": "i", "pid": 0, "tid": tid, "s": "t",
                    "ts": _us(t),
                    "name": f"dead ({r.get('cause', 'unknown')})",
                    "args": {},
                })
        elif kind == "warning":
            iid = r.get("instance_id")
            tid = tid_of(int(iid)) if iid is not None else 0
            trace.append({
                "ph": "i", "pid": 0, "tid": tid, "s": "t",
                "ts": _us(t), "name": "preemption warning",
                "args": {"zone": r.get("zone")},
            })
        elif kind == "launch_failure":
            trace.append({
                "ph": "i", "pid": 0, "tid": 0, "s": "t",
                "ts": _us(t), "name": "launch failure",
                "args": {"zone": r.get("zone"), "kind": r.get("kind")},
            })
        elif kind == "migration_plan":
            iid = int(r.get("instance_id", -1))
            trace.append({
                "ph": "i", "pid": 0, "tid": tid_of(iid), "s": "t",
                "ts": _us(t), "name": "migration plan",
                "args": {
                    k: r[k]
                    for k in ("n_drained", "n_migrated", "n_killed",
                              "migrated_kv_tokens", "transfer_s")
                    if k in r
                },
            })
        elif kind == "window":
            for counter, field in (
                ("queue depth", "queue_depth"),
                ("fleet $/h", "cost_per_h"),
                ("ready replicas", "n_ready"),
            ):
                if field in r:
                    trace.append({
                        "ph": "C", "pid": 0, "ts": _us(t),
                        "name": counter,
                        "args": {counter: r[field]},
                    })
    # close spans still open at the horizon (replicas alive at run end)
    for iid in sorted(open_span):
        span = open_span[iid]
        trace.append({
            "ph": "X", "pid": 0, "tid": tid_of(iid),
            "ts": _us(span["t0"]),
            "dur": _us(max(horizon - span["t0"], 0.0)),
            "name": span["name"], "args": span["args"],
        })
    if spans is not None:
        trace.extend(_span_slices(list(spans)))
    if token_windows is not None:
        for w in token_windows:
            if w.get("post_horizon"):
                continue      # drain bucket: no defined rate
            t0 = float(w["t0_s"])
            trace.append({
                "ph": "C", "pid": 0, "ts": _us(t0),
                "name": "goodput req/s",
                "args": {"goodput req/s": w["goodput_rps"]},
            })
            done = int(w.get("n_completed", 0))
            trace.append({
                "ph": "C", "pid": 0, "ts": _us(t0),
                "name": "window SLO attainment",
                "args": {"window SLO attainment": (
                    round(int(w.get("n_slo_ok", 0)) / done, 6)
                    if done else 0.0
                )},
            })
    return {
        "traceEvents": trace,
        "displayTimeUnit": "ms",
        "otherData": {"schema": SCHEMA_VERSION},
    }


#: pid of the request-span process (keeps replica lifecycle rows clean)
_SPAN_PID = 1


def _span_slices(spans: List[Mapping[str, Any]]) -> List[Dict[str, Any]]:
    """Request-span records -> nested per-replica Perfetto slices."""
    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": _SPAN_PID, "tid": 0, "name": "process_name",
         "args": {"name": "requests (sampled)"}},
    ]
    named: set = set()
    for rec in spans:
        segs = list(rec.get("segments") or [])
        if not segs:
            continue
        # the request rides the track of the first replica that served
        # it (migration hops stay visible as `transfer` child slices)
        rep = next(
            (int(s["replica"]) for s in segs if "replica" in s), -1
        )
        tid = rep + 1          # -1 (never dispatched) -> tid 0
        if tid not in named:
            named.add(tid)
            out.append({
                "ph": "M", "pid": _SPAN_PID, "tid": tid,
                "name": "thread_name",
                "args": {"name": (f"replica #{rep}" if rep >= 0
                                  else "undispatched")},
            })
        t0 = float(rec["arrival_s"])
        t1 = max(float(s["t1_s"]) for s in segs)
        args = {
            k: rec[k]
            for k in ("outcome", "attempts", "rtt_s", "e2e_s",
                      "first_token_s")
            if k in rec
        }
        out.append({
            "ph": "X", "pid": _SPAN_PID, "tid": tid,
            "ts": _us(t0), "dur": _us(max(t1 - t0, 0.0)),
            "name": f"req #{rec['ordinal']}", "args": args,
        })
        for s in segs:
            sargs = {
                k: v for k, v in s.items()
                if k not in ("name", "t0_s", "t1_s")
            }
            out.append({
                "ph": "X", "pid": _SPAN_PID, "tid": tid,
                "ts": _us(float(s["t0_s"])),
                "dur": _us(max(float(s["t1_s"]) - float(s["t0_s"]),
                               0.0)),
                "name": s["name"], "args": sargs,
            })
    return out


def write_chrome_trace(
    events: Iterable[Recordish],
    path: str,
    spans: Optional[Iterable[Mapping[str, Any]]] = None,
    token_windows: Optional[Iterable[Mapping[str, Any]]] = None,
) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(
            chrome_trace(events, spans=spans,
                         token_windows=token_windows),
            f, sort_keys=True, separators=(",", ":"),
        )
    return path


# ----------------------------------------------------------------------
# summaries

def summarize(events: Iterable[Recordish]) -> Dict[str, Any]:
    """Aggregate a record stream into a one-screen run summary."""
    records = _as_records(events)
    counts: Dict[str, int] = {}
    decisions: Dict[str, int] = {}
    lifecycle: Dict[str, int] = {}
    zones: Dict[str, int] = {}
    horizon = 0.0
    last_window: Optional[Dict[str, Any]] = None
    for r in records:
        kind = str(r.get("event"))
        counts[kind] = counts.get(kind, 0) + 1
        horizon = max(horizon, float(r.get("t", 0.0)))
        if kind == "decision":
            a = str(r.get("action"))
            decisions[a] = decisions.get(a, 0) + 1
            if r.get("zone") and a.startswith("launch"):
                z = str(r["zone"])
                zones[z] = zones.get(z, 0) + 1
        elif kind == "lifecycle":
            p = str(r.get("phase"))
            lifecycle[p] = lifecycle.get(p, 0) + 1
        elif kind == "window":
            last_window = r
    out: Dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "n_events": len(records),
        "horizon_s": horizon,
        "event_counts": {k: counts[k] for k in sorted(counts)},
        "decisions": {k: decisions[k] for k in sorted(decisions)},
        "lifecycle": {k: lifecycle[k] for k in sorted(lifecycle)},
        "launches_by_zone": {k: zones[k] for k in sorted(zones)},
    }
    if last_window is not None:
        out["final_window"] = {
            k: v for k, v in last_window.items()
            if k not in ("schema", "event")
        }
    return out


def diff_summaries(
    a: Iterable[Recordish], b: Iterable[Recordish]
) -> Dict[str, Any]:
    """Field-wise deltas between two run summaries (b − a)."""
    sa, sb = summarize(a), summarize(b)

    def delta(key: str) -> Dict[str, Any]:
        da, db = sa.get(key, {}), sb.get(key, {})
        keys = sorted(set(da) | set(db))
        return {
            k: {"a": da.get(k, 0), "b": db.get(k, 0),
                "delta": db.get(k, 0) - da.get(k, 0)}
            for k in keys
            if da.get(k, 0) != db.get(k, 0)
        }

    return {
        "schema": SCHEMA_VERSION,
        "n_events": {"a": sa["n_events"], "b": sb["n_events"],
                     "delta": sb["n_events"] - sa["n_events"]},
        "event_counts": delta("event_counts"),
        "decisions": delta("decisions"),
        "lifecycle": delta("lifecycle"),
        "launches_by_zone": delta("launches_by_zone"),
        "identical": sa == sb,
    }

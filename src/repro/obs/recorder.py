"""The per-run observability recorder.

One :class:`ObsRecorder` is shared by every emitter of a run — the
cluster simulator, the serving engine's tick, the migration runtime and
(via :func:`repro.obs.registry.use_registry`) the latency-model factory.
The ``detail`` knob gates cost:

* ``off`` — nothing is recorded; emitters short-circuit on
  :attr:`enabled` before even constructing event objects.
* ``decisions`` (default) — control-plane events (policy decisions with
  reasons, replica lifecycle, warnings, launch failures, migration
  plans) and registry metrics.
* ``full`` — additionally, windowed data-plane samples
  (:class:`~repro.obs.events.WindowSampleEvent` every ``window_s``) and
  artifact export by the :class:`~repro.service.Service` facade.

Recording is pure observation: no RNG draws, no engine state mutation —
golden metrics are byte-identical at every detail level
(tests/test_obs.py pins this).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.obs.events import Event
from repro.obs.registry import MetricsRegistry
from repro.obs.slo import SLOBurnConfig, burn_summary

__all__ = ["DETAIL_LEVELS", "ObsRecorder"]

DETAIL_LEVELS = ("off", "decisions", "full")


class ObsRecorder:
    """Event sink + metrics registry for one run."""

    def __init__(
        self,
        detail: str = "decisions",
        window_s: float = 60.0,
        trace_sample: float = 0.01,
        slo_burn: Optional[SLOBurnConfig] = None,
    ) -> None:
        if detail not in DETAIL_LEVELS:
            raise ValueError(
                f"observability detail must be one of {DETAIL_LEVELS}, "
                f"got {detail!r}"
            )
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if not 0.0 <= trace_sample <= 1.0:
            raise ValueError(
                f"trace_sample must be in [0, 1], got {trace_sample}"
            )
        self.detail = detail
        self.window_s = float(window_s)
        self.trace_sample = float(trace_sample)
        self.slo_burn = slo_burn if slo_burn is not None else SLOBurnConfig()
        self.events: List[Event] = []
        self.registry = MetricsRegistry()
        self.spans = None    # SpanCollector, attached by span_collector()
        self._ordinals: Dict[int, int] = {}

    def replica_ordinal(self, instance_id: int) -> int:
        """Run-local dense id for an instance.

        ``Instance.id`` comes from a process-global counter, so two runs
        in one process would never produce identical event logs if raw
        ids leaked into events.  Emitters translate through this map;
        first-use order is deterministic (provision order), so equal
        runs yield byte-identical streams.
        """
        ordinal = self._ordinals.get(instance_id)
        if ordinal is None:
            ordinal = self._ordinals[instance_id] = len(self._ordinals)
        return ordinal

    # ------------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.detail != "off"

    @property
    def wants_windows(self) -> bool:
        return self.detail == "full"

    def emit(self, event: Event) -> None:
        if self.detail != "off":
            self.events.append(event)

    def emit_window(self, event: Event) -> None:
        if self.detail == "full":
            self.events.append(event)

    def span_collector(self, requests: Sequence):
        """Attach (or return) the run's request-span collector.

        ``None`` when recording is off, sampling is disabled or the
        tape is empty — engines bind the result once and skip all span
        taps when it is ``None``.
        """
        if not self.enabled or self.trace_sample <= 0.0 or not requests:
            return None
        if self.spans is None:
            from repro.obs.spans import SpanCollector

            self.spans = SpanCollector(self.trace_sample, requests)
        return self.spans

    # ------------------------------------------------------------------
    def fresh(self) -> "ObsRecorder":
        """An empty recorder with the same configuration (the JAX
        engine's oracle fallback re-runs a cell from scratch and must
        not double-record phase-A events)."""
        return ObsRecorder(
            detail=self.detail,
            window_s=self.window_s,
            trace_sample=self.trace_sample,
            slo_burn=self.slo_burn,
        )

    def records(self) -> List[Dict[str, Any]]:
        return [e.to_record() for e in self.events]

    def event_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for e in self.events:
            counts[e.KIND] = counts.get(e.KIND, 0) + 1
        return {k: counts[k] for k in sorted(counts)}

    def window_records(self) -> List[Dict[str, Any]]:
        return [e.to_record() for e in self.events if e.KIND == "window"]

    def span_records(self) -> List[Dict[str, Any]]:
        return self.spans.records() if self.spans is not None else []

    def slo_burn_summary(self) -> Optional[Dict[str, Any]]:
        """Per-run burn summary (``None`` below detail ``full``)."""
        return burn_summary(
            e.to_record() for e in self.events if e.KIND == "slo_burn"
        )

"""CLI: summarize / diff / attribute / convert observability logs.

::

    python -m repro.obs summarize artifacts/obs/run.jsonl
    python -m repro.obs diff a.jsonl b.jsonl
    python -m repro.obs attribute run.jsonl --top 5
    python -m repro.obs trace run.jsonl -o run.trace.json
    python -m repro.obs request run.spans.jsonl 0
    python -m repro.obs slo run.jsonl

``summarize``/``diff``/``attribute`` print human-readable text by
default and structured JSON with ``--json``; ``trace`` writes a
Perfetto-loadable Chrome-trace file.  ``request`` renders one sampled
request's span as a waterfall (pass the ``.spans.jsonl`` artifact);
``slo`` renders the run's SLO burn-rate windows as a table.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.obs.attribution import attribution_report
from repro.obs.export import (
    diff_summaries,
    read_jsonl,
    summarize,
    write_chrome_trace,
)


def _print_kv(d: Dict[str, Any], indent: str = "  ") -> None:
    for k, v in d.items():
        if isinstance(v, dict):
            print(f"{indent}{k}:")
            _print_kv(v, indent + "  ")
        else:
            print(f"{indent}{k}: {v}")


def _cmd_summarize(args: argparse.Namespace) -> int:
    s = summarize(read_jsonl(args.log))
    if args.json:
        print(json.dumps(s, indent=1, sort_keys=True))
    else:
        print(f"run summary: {args.log}")
        _print_kv(s)
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    d = diff_summaries(read_jsonl(args.a), read_jsonl(args.b))
    if args.json:
        print(json.dumps(d, indent=1, sort_keys=True))
    else:
        print(f"diff (b − a): a={args.a} b={args.b}")
        _print_kv(d)
    return 0 if d["identical"] else 1


def _cmd_attribute(args: argparse.Namespace) -> int:
    rep = attribution_report(
        read_jsonl(args.log),
        top=args.top,
        spans=read_jsonl(args.spans) if args.spans else None,
    )
    if args.json:
        print(json.dumps(rep, indent=1, sort_keys=True))
        return 0
    print(f"decision attribution: {args.log}")
    print(f"  total cost: ${rep['total_cost_usd']:.4f} over "
          f"{rep['n_replicas']} replicas / {rep['n_decisions']} decisions "
          f"({rep['horizon_s']:.0f}s horizon)")
    print("  cost by action:")
    for action, agg in rep["cost_by_action"].items():
        print(f"    {action:<18} ${agg['cost_usd']:>10.4f}  "
              f"({agg['n_replicas']} replicas)")
    print(f"  top {len(rep['top_decisions'])} decisions by cost:")
    for d in rep["top_decisions"]:
        reason = ""
        if d["reason"]:
            reason = "  " + ",".join(
                f"{k}={v}" for k, v in sorted(d["reason"].items())
            )
        print(f"    t={d['t']:>9.1f}s {d['action']:<16} "
              f"zone={d['zone']} inst={d['instance_id']} "
              f"${d['cost_usd']:.4f} "
              f"({d['replica_lifetime_s']:.0f}s){reason}")
    fr = rep["failed_requests"]
    if fr["by_cause"] is not None:
        print(f"  failed requests ({fr['total']}):")
        for cause, n in sorted(fr["by_cause"].items()):
            print(f"    {cause:<16} {n}")
    elif fr["note"]:
        print(f"  failed requests: {fr['note']}")
    rs = rep.get("request_spans")
    if rs:
        print(f"  sampled request spans ({rs['n_spans']}): "
              f"{rs['n_retried']} retried, {rs['n_migrated']} migrated")
        for name, sec in rs["seconds_by_segment"].items():
            print(f"    {name:<10} {sec:>12.3f}s")
    return 0


def _cmd_request(args: argparse.Namespace) -> int:
    rec = next(
        (r for r in read_jsonl(args.log)
         if r.get("event") == "span"
         and int(r.get("ordinal", -1)) == args.ordinal),
        None,
    )
    if rec is None:
        print(f"no span record for ordinal {args.ordinal} "
              f"in {args.log} (is trace_sample high enough?)")
        return 1
    if args.json:
        print(json.dumps(rec, indent=1, sort_keys=True))
        return 0
    segs = rec.get("segments") or []
    t0 = float(rec["arrival_s"])
    t1 = max((float(s["t1_s"]) for s in segs), default=t0)
    span = max(t1 - t0, 1e-9)
    width = 40
    print(f"request #{rec['ordinal']}: outcome={rec['outcome']} "
          f"attempts={rec['attempts']} arrival={t0:.3f}s"
          + (f" e2e={rec['e2e_s']:.3f}s" if "e2e_s" in rec else ""))
    for s in segs:
        a, b = float(s["t0_s"]), float(s["t1_s"])
        lo = int((a - t0) / span * width)
        hi = max(int((b - t0) / span * width), lo + 1)
        bar = " " * lo + "#" * (hi - lo)
        extra = ",".join(
            f"{k}={v}" for k, v in sorted(s.items())
            if k not in ("name", "t0_s", "t1_s")
        )
        print(f"  {s['name']:<9} |{bar:<{width}}| "
              f"{a:11.3f}s -> {b:11.3f}s ({b - a:8.3f}s)"
              + (f"  {extra}" if extra else ""))
    return 0


def _cmd_slo(args: argparse.Namespace) -> int:
    from repro.obs.slo import burn_summary, burn_table

    records = read_jsonl(args.log)
    if args.json:
        print(json.dumps(
            {"summary": burn_summary(records)}, indent=1, sort_keys=True
        ))
        return 0
    print(burn_table(records))
    s = burn_summary(records)
    if s is not None:
        print(f"alerting {s['alert_windows']}/{s['windows']} windows "
              f"({s['alert_minutes']:.1f} min)")
    return 0


def _cmd_trace(args: argparse.Namespace) -> int:
    out = args.out or (args.log.rsplit(".", 1)[0] + ".trace.json")
    path = write_chrome_trace(read_jsonl(args.log), out)
    print(f"wrote {path} (load it at https://ui.perfetto.dev)")
    return 0


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="inspect repro.obs event logs",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="summarize one event log")
    p.add_argument("log")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("diff", help="diff two event logs (b − a)")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser(
        "attribute", help="decision-attribution report for one log"
    )
    p.add_argument("log")
    p.add_argument("--top", type=int, default=10)
    p.add_argument("--spans", default=None,
                   help="span log (.spans.jsonl) to extend the ledger")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_attribute)

    p = sub.add_parser(
        "trace", help="convert a log to a Chrome/Perfetto trace"
    )
    p.add_argument("log")
    p.add_argument("-o", "--out", default=None)
    p.set_defaults(fn=_cmd_trace)

    p = sub.add_parser(
        "request",
        help="waterfall of one sampled request (span log + ordinal)",
    )
    p.add_argument("log")
    p.add_argument("ordinal", type=int)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_request)

    p = sub.add_parser(
        "slo", help="SLO burn-rate windows of one event log"
    )
    p.add_argument("log")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_slo)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

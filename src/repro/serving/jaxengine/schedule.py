"""Phase A: replay the control plane, record the replica schedule.

The serving tick loop has a one-way dependency structure: the control
plane (policy, cluster FSM, autoscaler) never observes the data plane —
the autoscaler sees only *arrival* batches, which are a pure function of
the request tape and the sub-step grid.  So the control plane can run
once in ordinary Python with the real :class:`ClusterSimulator` (exact
costs, preemptions, launch failures, rng draws by construction) while
recording everything the data plane needs as dense arrays:

* the sub-step grid itself (the engines' own float accumulation,
  precomputed so grid points match the NumPy oracle bit-for-bit);
* per control window, the roster of ready replica slots;
* per slot, its RTT row (client-region code → seconds);
* kill events as ``(event order, slot, window)`` — a preemption at tick
  ``k`` lands *before* the tick hook (window ``k``), a policy
  termination lands *after* it (window ``k + 1``), and the recorder
  tracks that boundary so phase B re-pends work at the oracle's instant.

Phase B (:mod:`.kernel`) then replays only the serving data plane as one
``lax.scan`` over these arrays.  A :class:`CellSchedule` is a plain
numpy/dataclass payload — picklable, so phase A can fan out across
worker processes while phase B batches every cell in one program.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.cluster.simulator import SimResult

__all__ = ["CellSchedule", "SubStepGrid", "build_grid", "ScheduleRecorder"]


@dataclasses.dataclass(frozen=True)
class SubStepGrid:
    """The exact sub-step grid of a (duration, dt, sub_step) family."""

    ts: np.ndarray         # [G] float64 grid points
    win_of: np.ndarray     # [G] int64: control window of each point
    win_first: np.ndarray  # [W] int64: first grid index of each window
    ticks: int             # W
    dt: float
    sub_step_s: float

    @property
    def n_points(self) -> int:
        return int(self.ts.shape[0])

    @property
    def signature(self) -> Tuple[float, float, int]:
        """Two grids with equal signatures hold identical floats."""
        return (self.dt, self.sub_step_s, self.ticks)


def build_grid(duration_s: float, dt: float, sub_step_s: float) -> SubStepGrid:
    """Replicate the engines' per-window float accumulation exactly.

    Both the legacy simulator and the vectorized engine walk
    ``t = now; while t < now + dt: ...; t += sub_step_s`` inside each
    control tick, so the grid must be built with the *same* accumulation
    (not ``arange``) for timeout instants to match bit-for-bit.
    """
    ticks = int(float(duration_s) / dt)
    ts: List[float] = []
    win_of: List[int] = []
    win_first = np.empty(ticks, dtype=np.int64)
    for k in range(ticks):
        now = k * dt
        win_first[k] = len(ts)
        t = now
        end = now + dt
        while t < end:
            ts.append(t)
            win_of.append(k)
            t += sub_step_s
    return SubStepGrid(
        ts=np.asarray(ts, dtype=np.float64),
        win_of=np.asarray(win_of, dtype=np.int64),
        win_first=win_first,
        ticks=ticks,
        dt=float(dt),
        sub_step_s=float(sub_step_s),
    )


@dataclasses.dataclass
class CellSchedule:
    """One cell's complete phase-B input: tape + control-plane replay.

    Self-contained and picklable: the data plane needs nothing else, and
    the final :class:`~repro.serving.sim.ServingResult` is assembled
    from this plus the kernel outputs (see ``engine.assemble_result``).
    """

    # identity / labels
    policy_name: str
    trace_name: str
    workload_name: str
    # request tape
    arr: np.ndarray              # [n] float64 arrivals, sorted
    svc: np.ndarray              # [n] float64 roofline service times
    rcode: np.ndarray            # [n] client-region codes
    n_regions: int
    # serving knobs
    timeout_s: float
    concurrency: int
    lb_kind: str                 # "rr" | "ll"
    # control-plane replay
    grid: SubStepGrid
    ready_mask: np.ndarray       # [W, R] bool: slot ready in window
    rtt: np.ndarray              # [R, NREG] float64
    kill_slot: np.ndarray        # [E] int64, chronological
    kill_g: np.ndarray           # [E] int64 grid index; G ⇒ post-horizon
    post_slots: np.ndarray       # slots of post-horizon kill events
    base: SimResult              # control-plane result (costs, churn, ...)
    n_slots: int
    trace_on: bool = False       # carry span timelines through the kernel

    @property
    def n(self) -> int:
        return int(self.arr.shape[0])

    @property
    def n_events(self) -> int:
        return int(self.kill_slot.shape[0])


class ScheduleRecorder:
    """Recording state driven by ``JaxServingEngine``'s tick/kill hooks."""

    def __init__(self, grid: SubStepGrid, arr: np.ndarray) -> None:
        self.grid = grid
        # arrival observations per window: the oracle appends one
        # ``(t, n_new)`` per sub-step that consumed new arrivals
        ends = np.searchsorted(arr, grid.ts, side="right")
        counts = np.diff(ends, prepend=0)
        self._obs_by_win: List[List[Tuple[float, int]]] = [
            [] for _ in range(grid.ticks)
        ]
        for j in np.flatnonzero(counts):
            self._obs_by_win[int(grid.win_of[j])].append(
                (float(grid.ts[j]), int(counts[j]))
            )
        self.ready_rows: List[List[int]] = []
        self.kills: List[Tuple[int, int]] = []   # (window, slot), in order
        self.win = 0          # next window index
        self.kill_win = 0     # window a kill occurring *now* belongs to

    def obs_for(self, k: int) -> Sequence[Tuple[float, int]]:
        return self._obs_by_win[k]

    def record_tick(self, ready_slots: Sequence[int]) -> int:
        """Called from the tick hook *after* sync; returns this window."""
        k = self.win
        self.win = k + 1
        self.ready_rows.append(list(ready_slots))
        # anything dying between this hook and the next (policy
        # terminations of this tick, preemptions of the next) is
        # processed by the data plane at the start of window k+1
        self.kill_win = k + 1
        return k

    def record_kill(self, slot: int) -> None:
        self.kills.append((self.kill_win, slot))

    def control_arrays(
        self, n_slots: int, rtt_rows: Sequence[Sequence[float]],
        n_regions: int,
    ):
        """Densify the recording into phase-B arrays."""
        g = self.grid
        ready = np.zeros((max(g.ticks, 1), max(n_slots, 1)), dtype=bool)
        for k, row in enumerate(self.ready_rows):
            for s in row:
                ready[k, s] = True
        rtt = np.zeros((max(n_slots, 1), max(n_regions, 1)))
        for s, row in enumerate(rtt_rows):
            rtt[s, : len(row)] = row
        kill_slot = np.asarray([s for _, s in self.kills], dtype=np.int64)
        kill_g = np.asarray(
            [
                int(g.win_first[w]) if w < g.ticks else g.n_points
                for w, _ in self.kills
            ],
            dtype=np.int64,
        )
        post = np.asarray(
            [s for w, s in self.kills if w >= g.ticks], dtype=np.int64
        )
        return ready, rtt, kill_slot, kill_g, post

"""``JaxServingEngine``: jit/vmap scenario engine facade.

Drop-in subclass of :class:`VectorizedServingEngine` selectable via
``sim.engine: "jax"``.  A single ``run()`` replays the control plane in
Python (phase A, exact by construction — it *is* the real cluster
simulator) and compiles the serving data plane as one ``lax.scan``
(phase B).  The real win is :func:`run_cells` /
:func:`run_schedules`: every cell of a (policies × traces × seeds)
matrix that shares a static shape signature runs as one ``vmap``-ed XLA
program, so matrix throughput scales with the batch instead of the
Python interpreter.

Scope and guarantees:

* request-model cells are decision-for-decision equivalent to the NumPy
  oracle (``tests/test_jax_engine.py`` locks this down to 1e-6 and
  mostly to the bit);
* ``replica_model: "token"`` cells delegate to the oracle's data plane
  unchanged — continuous batching carries per-sequence KV state whose
  shapes are data-dependent, so it stays on the NumPy path (documented
  limitation; the jax path still accepts such specs);
* a cell whose per-replica queue would exceed ``queue_capacity`` is
  transparently re-run on the oracle (the kernel flags overflow instead
  of dropping work), so capacity tuning can never change results.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.obs.registry import use_registry
from repro.serving.engine import VectorizedServingEngine, _Rep
from repro.serving.jaxengine.schedule import (
    CellSchedule,
    ScheduleRecorder,
    build_grid,
)
from repro.serving.sim import ServingResult

__all__ = [
    "JaxServingEngine",
    "run_cells",
    "run_schedules",
    "assemble_result",
]

#: per-replica queue pool size (static shape); overflow → oracle rerun
DEFAULT_QUEUE_CAPACITY = 256


class JaxServingEngine(VectorizedServingEngine):
    """Two-phase JAX engine behind the ``VectorizedServingEngine`` API."""

    queue_capacity = DEFAULT_QUEUE_CAPACITY

    def __init__(self, trace, policy, requests, cfg, **kw) -> None:
        # pristine control-plane state for the overflow fallback (phase A
        # consumes the policy/autoscaler/balancer rng and counters)
        self._pristine = {
            "trace": trace,
            "policy": copy.deepcopy(policy),
            "requests": requests,
            "cfg": cfg,
            "kw": {
                k: (copy.deepcopy(v) if k in ("autoscaler", "lb") else v)
                for k, v in kw.items()
            },
        }
        super().__init__(trace, policy, requests, cfg, **kw)
        self._rec: Optional[ScheduleRecorder] = None
        self.schedule: Optional[CellSchedule] = None

    # -- phase-A hooks ------------------------------------------------
    def _tick(self, now, cluster) -> None:
        rec = self._rec
        if rec is None:
            super()._tick(now, cluster)
            return
        self._sync(now)
        k = rec.record_tick(self._ready_slots)
        obs = rec.obs_for(k)
        if obs:
            self._observe_batch(list(obs))

    def _kill(self, rep: _Rep, now=None) -> None:
        rec = self._rec
        if rec is None or rep.batch is not None:
            super()._kill(rep, now)
            return
        if rep.dead:
            return
        rep.dead = True
        self._live_dirty = True
        rec.record_kill(rep.slot)

    # -- phase A ------------------------------------------------------
    def record_schedule(
        self, duration_s: Optional[float] = None
    ) -> CellSchedule:
        """Run the control plane once; return the phase-B payload.

        Consumes this engine (the cluster has run); callable once.
        """
        if self._token_cfg is not None:
            raise RuntimeError(
                "token-model cells run on the NumPy data plane; "
                "call run() directly"
            )
        dt = self.cluster.config.control_interval_s
        dur = float(duration_s or self.cluster.trace.duration_s)
        grid = build_grid(dur, dt, self.sub_step_s)
        self._rec = ScheduleRecorder(grid, self._arr)
        # phase A is the real control plane: the cluster's obs taps emit
        # the same decision/lifecycle events as the other engines (no
        # window samples — this tick override never runs the sampler)
        with use_registry(self.obs.registry):
            base = self.cluster.run(duration_s)
        ready, rtt, kill_slot, kill_g, post = self._rec.control_arrays(
            len(self._reps),
            [r.rtt for r in self._reps],
            len(self._client_regions),
        )
        self._rec = None
        sched = CellSchedule(
            policy_name=self.cluster.policy.name,
            trace_name=self.cluster.trace.name,
            workload_name=self.workload_name,
            arr=self._arr,
            svc=self._svc,
            rcode=np.asarray(self._rcode, dtype=np.int64),
            n_regions=max(len(self._client_regions), 1),
            timeout_s=self.timeout_s,
            concurrency=self.concurrency,
            lb_kind=self._lb_kind,
            grid=grid,
            ready_mask=ready,
            rtt=rtt,
            kill_slot=kill_slot,
            kill_g=kill_g,
            post_slots=post,
            base=base,
            n_slots=len(self._reps),
            trace_on=self._spans is not None,
        )
        self.schedule = sched
        return sched

    def _fallback_run(
        self, duration_s: Optional[float]
    ) -> ServingResult:
        """Oracle rerun from pristine control-plane state (overflow)."""
        p = self._pristine
        kw = {
            k: (copy.deepcopy(v) if k in ("autoscaler", "lb") else v)
            for k, v in p["kw"].items()
        }
        # fresh recorder: the rerun replays the whole control plane, and
        # sharing this engine's recorder would double-record phase A
        kw["obs"] = self.obs.fresh()
        eng = VectorizedServingEngine(
            p["trace"],
            copy.deepcopy(p["policy"]),
            p["requests"],
            p["cfg"],
            **kw,
        )
        return eng.run(duration_s)

    # -- public API ---------------------------------------------------
    def run(self, duration_s: Optional[float] = None) -> ServingResult:
        if self._token_cfg is not None:
            # token cells: continuous batching stays on the NumPy path
            return super().run(duration_s)
        return run_cells([self], [duration_s])[0]


def assemble_result(sched: CellSchedule, out: dict) -> ServingResult:
    """Build a :class:`ServingResult` from one lane's kernel outputs."""
    n = sched.n
    status = np.asarray(out["status"][:n])
    e2e = np.asarray(out["e2e"][:n])
    n_req = int(out["a_ptr"])
    comp = status == 1
    n_completed = int(comp.sum())
    # drain: arrived but unresolved (pending / in-flight / queued,
    # including work on post-horizon-killed slots) fails, like the oracle
    n_failed = int((status == 2).sum()) + int(
        (status[:n_req] == 0).sum()
    )
    n_retried = int(out["n_retried"])
    for s in sched.post_slots:
        # kills after the last tick hook: the oracle re-pends this work
        # before the drain; the scan never processes the event, so its
        # final per-slot occupancy is exactly what the oracle re-pended
        n_retried += int(out["run_n"][s]) + int(out["q_cnt"][s])
    base = sched.base
    return ServingResult(
        policy=sched.policy_name,
        trace=sched.trace_name,
        workload=sched.workload_name,
        n_requests=n_req,
        n_completed=n_completed,
        n_failed=n_failed,
        latencies_s=e2e[comp],
        total_cost=base.total_cost,
        spot_cost=base.spot_cost,
        od_cost=base.od_cost,
        cost_vs_ondemand=base.cost_vs_ondemand,
        availability=base.availability,
        n_preemptions=base.n_preemptions,
        n_launch_failures=base.n_launch_failures,
        token=None,
        n_retried_requests=n_retried,
        lost_kv_tokens=0,
    )


def _empty_result(sched: CellSchedule) -> ServingResult:
    """Degenerate horizon (no control ticks) or empty tape: nothing to
    scan — every metric is determined host-side."""
    n_req = (
        int(np.searchsorted(sched.arr, sched.grid.ts[-1], side="right"))
        if sched.grid.n_points and sched.n
        else 0
    )
    base = sched.base
    return ServingResult(
        policy=sched.policy_name,
        trace=sched.trace_name,
        workload=sched.workload_name,
        n_requests=n_req,
        n_completed=0,
        n_failed=n_req,
        latencies_s=np.empty(0),
        total_cost=base.total_cost,
        spot_cost=base.spot_cost,
        od_cost=base.od_cost,
        cost_vs_ondemand=base.cost_vs_ondemand,
        availability=base.availability,
        n_preemptions=base.n_preemptions,
        n_launch_failures=base.n_launch_failures,
        token=None,
        n_retried_requests=0,
        lost_kv_tokens=0,
    )


def run_schedules(
    scheds: Sequence[CellSchedule],
    *,
    queue_capacity: int = DEFAULT_QUEUE_CAPACITY,
    outputs: Optional[List[Optional[dict]]] = None,
) -> List[Optional[ServingResult]]:
    """Phase B over many cells: group by static shape signature, pad
    each group to a common shape, and run one vmapped program per group.

    Returns results aligned with ``scheds``; ``None`` marks a lane whose
    queue pool overflowed (caller must rerun that cell on the oracle).
    Pass a list as ``outputs`` to also receive each lane's raw kernel
    outputs (aligned with ``scheds``; ``None`` for overflow/empty lanes)
    — the span-reconstruction path in :func:`run_cells` consumes these.
    """
    from repro.serving.jaxengine import kernel as K

    results: List[Optional[ServingResult]] = [None] * len(scheds)
    if outputs is not None:
        del outputs[:]
        outputs.extend([None] * len(scheds))
    groups: dict = {}
    for idx, sc in enumerate(scheds):
        if sc.grid.n_points == 0 or sc.n == 0 or sc.n_slots == 0:
            # no grid → nothing ever runs; no replicas → nothing ever
            # dispatches and the drain fails every arrival (oracle-equal:
            # with zero ready slots dispatch is skipped and pending only
            # drains at the horizon)
            results[idx] = _empty_result(sc)
            continue
        key = (
            sc.grid.signature,
            sc.concurrency,
            sc.lb_kind,
            sc.timeout_s > 0,
            sc.trace_on,
        )
        groups.setdefault(key, []).append(idx)

    for (gsig, C, lb_kind, expire_on, trace_on), idxs in groups.items():
        cells = [scheds[i] for i in idxs]
        g = cells[0].grid
        N = max(c.n for c in cells)
        R = max(c.n_slots for c in cells)
        E = max(c.n_events for c in cells)
        NREG = max(c.n_regions for c in cells)
        L = len(cells)
        lanes = {
            "arr": np.full((L, N), np.inf),
            "svc": np.ones((L, N)),
            "rcode": np.zeros((L, N), dtype=np.int64),
            "rtt": np.zeros((L, R, NREG)),
            "ready": np.zeros((L, g.ticks, R), dtype=bool),
            "kill_slot": np.zeros((L, max(E, 1)), dtype=np.int64),
            "kill_g": np.full(
                (L, max(E, 1)), g.n_points, dtype=np.int64
            ),
            "timeout": np.zeros(L),
        }
        amax, atyp = 1, 1
        for li, c in enumerate(cells):
            lanes["arr"][li, : c.n] = c.arr
            lanes["svc"][li, : c.n] = c.svc
            lanes["rcode"][li, : c.n] = c.rcode
            lanes["rtt"][li, : c.n_slots, : c.n_regions] = c.rtt
            lanes["ready"][li, :, : c.n_slots] = c.ready_mask
            lanes["kill_slot"][li, : c.n_events] = c.kill_slot
            lanes["kill_g"][li, : c.n_events] = c.kill_g
            lanes["timeout"][li] = c.timeout_s
            # exact per-sub-step arrival bound: sizes the kernel's masked
            # dispatch/start scans (backlog spikes spill to the remainder
            # loop, so this is a performance knob, not a correctness one)
            counts = np.diff(
                np.searchsorted(c.arr, g.ts, side="right"), prepend=0
            )
            if counts.size:
                amax = max(amax, int(counts.max()))
                atyp = max(atyp, int(np.percentile(counts, 99)))
        key = K.KernelKey(
            G=g.n_points,
            W=g.ticks,
            N=N,
            R=R,
            Q=queue_capacity,
            C=C,
            NREG=NREG,
            E=E,
            AMAX=amax,
            ATYP=atyp,
            lb_rr=(lb_kind == "rr"),
            expire_on=expire_on,
            trace_on=trace_on,
        )
        out = K.run_group(
            key,
            lanes,
            g.ts,
            np.arange(g.n_points, dtype=np.int64),
            g.win_of,
        )
        for li, i in enumerate(idxs):
            if bool(out["overflow"][li]):
                continue     # caller falls back to the oracle
            lane_out = {k2: v[li] for k2, v in out.items()}
            results[i] = assemble_result(cells[li], lane_out)
            if outputs is not None:
                outputs[i] = lane_out
    return results


def _reconstruct_spans(
    eng: JaxServingEngine, sched: CellSchedule, out: dict
) -> None:
    """Rebuild sampled request spans from the kernel's span timelines.

    The kernel resolves one (dispatch, start, finish, slot) quadruple per
    completion-scattered request — a killed-and-retried request records
    its final, completing attempt (``attempts`` stays 1; no preempt
    cuts), and drain-failed or queue-expired requests get no jax spans.
    For never-preempted requests the replayed taps are bit-identical to
    the oracle's (x64 kernel, same grid), so the span parity test can
    compare records byte-for-byte after filtering.
    """
    spans = eng._spans
    if spans is None or "disp_t" not in out:
        return
    n = sched.n
    status = np.asarray(out["status"][:n])
    e2e = np.asarray(out["e2e"][:n])
    disp = np.asarray(out["disp_t"][:n])
    start = np.asarray(out["start_t"][:n])
    rep_slot = np.asarray(out["rep"][:n])
    fin = np.asarray(out["fin_t"][:n])
    rtt, rcode, arr = sched.rtt, sched.rcode, sched.arr
    ords = [r.ord for r in eng._reps]
    want = spans.want_l
    for o in range(n):
        if not want[o] or status[o] == 0:
            continue
        slot = int(rep_slot[o])
        spans.dispatch(
            o, float(disp[o]), ords[slot],
            float(rtt[slot, rcode[o]]), float(arr[o]),
        )
        spans.start(o, float(start[o]))
        spans.finish(
            o, float(fin[o]),
            "ok" if status[o] == 1 else "timeout", float(e2e[o]),
        )


def run_cells(
    engines: Sequence[JaxServingEngine],
    durations: Optional[Sequence[Optional[float]]] = None,
) -> List[ServingResult]:
    """Run a batch of cells end to end: serial phase A per cell, one
    vmapped phase B per shape group, oracle fallback for token cells and
    queue-overflow lanes.  Results align with ``engines``."""
    if durations is None:
        durations = [None] * len(engines)
    results: List[Optional[ServingResult]] = [None] * len(engines)
    jax_idx: List[int] = []
    scheds: List[CellSchedule] = []
    for i, (eng, dur) in enumerate(zip(engines, durations)):
        if eng._token_cfg is not None:
            results[i] = VectorizedServingEngine.run(eng, dur)
        else:
            scheds.append(eng.record_schedule(dur))
            jax_idx.append(i)
    if scheds:
        cap = max(
            getattr(e, "queue_capacity", DEFAULT_QUEUE_CAPACITY)
            for e in engines
        )
        outs: List[Optional[dict]] = []
        group = run_schedules(scheds, queue_capacity=cap, outputs=outs)
        for k, (i, res) in enumerate(zip(jax_idx, group)):
            if res is None:     # queue pool overflow → oracle rerun
                # the rerun's own recorder rides on its result
                res = engines[i]._fallback_run(durations[i])
            else:
                obs = engines[i].obs
                if outs[k] is not None:
                    _reconstruct_spans(engines[i], scheds[k], outs[k])
                res = dataclasses.replace(
                    res,
                    metrics=obs.registry.snapshot() or None,
                    obs=obs if obs.enabled else None,
                )
            results[i] = res
    return results

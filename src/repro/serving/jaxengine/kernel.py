"""Phase B: the JAX-compiled serving data plane.

One :func:`lax.scan` replays the request-model sub-step loop over a
precomputed control-plane schedule (``schedule.CellSchedule``): per-slot
ready windows, RTT rows and kill events are *data*, so the scan carries
only fixed-shape serving state and the whole (policies × traces × seeds)
matrix runs as a single ``vmap``-ed XLA program.

Exactness contract (differential-tested against the NumPy
``VectorizedServingEngine`` oracle):

* the sub-step grid is precomputed in Python with the engines' own float
  accumulation, so timeout instants and arrival batches match bit-for-bit;
* every predicate (bare pending expiry, RTT-inclusive queue expiry,
  completion deadline, immediate-start condition, LL/RR routing ties) is
  the oracle's predicate — several oracle *guards* (pmin/qmin bounds, the
  ``_active`` skip, touched/due step sets) are pure-performance pruning
  whose removal is outcome-equivalent, which is what makes a fixed-shape
  scan possible;
* pending expiry is lazy: an expired pending request is dropped at the
  next dispatch's per-request check (same predicate, later ``t`` — still
  expired) or by the end-of-run drain, so the no-ready expiry sweep needs
  no per-step O(P) work;
* a dropped request keeps ``status == 0`` and is counted failed at the
  drain — loops never touch the O(N) metric arrays, which is what keeps
  their carries small (see below).

State layout per lane (R slots, C concurrency, Q queue capacity, N tape):

* pending — ring buffer of request indices (capacity N: a request lives
  in at most one place; row N is a scatter dump for masked writes);
* running — ``run_fin/run_idx [R, C]`` compacted in start order with
  ``+inf`` padding, ``run_n [R]``;
* queues — slot-local pools ``q_idx/q_age/q_seq/q_valid [R, Q]`` with a
  monotone sequence number for FIFO order and a carried per-slot min
  effective age (``arrival - rtt``) so the expiry guard is O(R) per step;
* metrics — ``status [N+1]`` (0 unresolved / 1 completed / 2 failed, the
  last row is a scatter dump) and ``e2e [N+1]``, written only by the
  vectorized completion stage.

Performance shape: under ``vmap``, every ``lax.while_loop`` iteration
select-copies its whole carry per lane, so data-proportional work must
not run through a while loop.  Arrivals are a masked vectorized scatter
(the per-step count is bounded by the host-computed ``AMAX``), dispatch
and queue-drain starts are fixed-length masked ``lax.scan``s of AMAX
iterations (scan bodies are batched without carry selects) with a
while-loop *remainder* that only spins on rare backlog spikes (outage
recovery, kill re-pends), and queue expiry clears a whole hit slot per
iteration.  Kills stay a plain while loop — they are control-plane-rare.

A lane whose queue pool would overflow sets a flag; the facade reruns
that cell on the NumPy oracle, so capacity is a performance knob, never a
correctness one.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

# float64 parity with the NumPy engines is scoped to run_group's
# enable_x64() context — the Pallas model kernels elsewhere in this
# repo assume the default-f32 world, so the flag must never be flipped
# process-globally here

_BIG_I = np.iinfo(np.int64).max

#: masked pops per inner-scan iteration (dispatch / starts): amortizes
#: the per-iteration fixed cost without changing pop order
_UNROLL = 4


@dataclasses.dataclass(frozen=True)
class KernelKey:
    """Static shape/flag signature — one compiled program per key."""

    G: int          # grid points
    W: int          # control windows
    N: int          # padded tape length
    R: int          # padded replica slots
    Q: int          # queue pool capacity per slot
    C: int          # concurrency (unrolled)
    NREG: int       # padded client-region count
    E: int          # padded kill events
    AMAX: int       # max arrivals in any sub-step (exact, host-computed)
    ATYP: int       # p99 arrivals per sub-step: sizes the masked scans
    lb_rr: bool     # round-robin (else least-loaded)
    expire_on: bool  # timeout_s > 0: run the queue-expiry sweep
    trace_on: bool = False  # carry span timelines (dispatch/start/finish)


_KERNELS: Dict[KernelKey, object] = {}

#: small-state keys: everything the per-step loops may carry.  The O(N)
#: metric arrays (status/e2e) are deliberately NOT here — a while-loop
#: carry under vmap is select-copied per iteration per lane.
_SMALL = (
    "pend", "p_head", "p_cnt", "a_ptr",
    "run_fin", "run_idx", "run_n",
    "q_idx", "q_age", "q_seq", "q_valid", "q_cnt", "qmin",
    "seq_ctr", "rr_cur", "kill_ptr", "n_retried", "overflow",
)


def _build_kernel(key: KernelKey):
    G, N, R, Q, C = key.G, key.N, key.R, key.Q, key.C
    lb_rr, expire_on, E = key.lb_rr, key.expire_on, key.E
    trace_on = key.trace_on
    # span timelines ride the running/queue pools (same shapes, same
    # scatter indices), so tracing adds writes but no new loop structure
    small = _SMALL + (("run_disp", "run_start", "q_disp")
                      if trace_on else ())
    AMAX = max(key.AMAX, 1)
    # scans cover the typical step; the chunked remainder loops absorb
    # the Poisson tail (≤1 % of steps), so executed pop-bodies per step
    # track the p99 rather than the worst case
    NCHUNK = max(1, -(-min(max(key.ATYP, 1), AMAX) // _UNROLL))

    def _pend_push(s, i):
        s = dict(s)
        pos = (s["p_head"] + s["p_cnt"]) % N
        s["pend"] = s["pend"].at[pos].set(i)
        s["p_cnt"] = s["p_cnt"] + 1
        return s

    def _q_pop(s, slot, j):
        """Remove pool cell ``j`` from ``slot``; refresh the cached min."""
        s = dict(s)
        s["q_valid"] = s["q_valid"].at[slot, j].set(False)
        s["q_cnt"] = s["q_cnt"].at[slot].add(-1)
        ages = jnp.where(s["q_valid"][slot], s["q_age"][slot], jnp.inf)
        s["qmin"] = s["qmin"].at[slot].set(ages.min())
        return s

    def lane(arr, svc, rcode, rtt, ready_mask, kill_slot, kill_g,
             timeout, ts, gs, wins):
        # arr/svc [N] (+inf / 1.0 padded), rcode [N], rtt [R, NREG],
        # ready_mask [W, R] bool, kill_slot [E], kill_g [E] (grid index,
        # G ⇒ post-horizon), timeout scalar; ts/gs/wins [G] shared.
        st0 = {
            "pend": jnp.zeros(N + 1, dtype=jnp.int64),
            "p_head": jnp.zeros((), dtype=jnp.int64),
            "p_cnt": jnp.zeros((), dtype=jnp.int64),
            "a_ptr": jnp.zeros((), dtype=jnp.int64),
            "run_fin": jnp.full((R, C), jnp.inf),
            "run_idx": jnp.zeros((R, C), dtype=jnp.int64),
            "run_n": jnp.zeros(R, dtype=jnp.int64),
            "q_idx": jnp.zeros((R, Q), dtype=jnp.int64),
            "q_age": jnp.zeros((R, Q)),
            "q_seq": jnp.zeros((R, Q), dtype=jnp.int64),
            "q_valid": jnp.zeros((R, Q), dtype=bool),
            "q_cnt": jnp.zeros(R, dtype=jnp.int64),
            "qmin": jnp.full(R, jnp.inf),
            "seq_ctr": jnp.zeros((), dtype=jnp.int64),
            "rr_cur": jnp.zeros((), dtype=jnp.int64),
            "kill_ptr": jnp.zeros((), dtype=jnp.int64),
            "n_retried": jnp.zeros((), dtype=jnp.int64),
            "overflow": jnp.zeros((), dtype=bool),
            "status": jnp.zeros(N + 1, dtype=jnp.int8),
            "e2e": jnp.zeros(N + 1),
        }
        if trace_on:
            st0.update({
                # pool-shaped timelines carried by the loops ...
                "run_disp": jnp.zeros((R, C)),
                "run_start": jnp.zeros((R, C)),
                "q_disp": jnp.zeros((R, Q)),
                # ... and O(N) per-request outputs written only by the
                # completion stage (like status/e2e, never loop-carried)
                "disp_t": jnp.full(N + 1, -jnp.inf),
                "start_t": jnp.full(N + 1, -jnp.inf),
                "rep": jnp.full(N + 1, -1, dtype=jnp.int64),
                "fin_t": jnp.full(N + 1, -jnp.inf),
            })

        def step(st, xs):
            t, g, win = xs
            s = {k: st[k] for k in small}

            # -- 1) kill events due before this sub-step ----------------
            if E > 0:
                def kill_cond(s):
                    kp = jnp.minimum(s["kill_ptr"], E - 1)
                    return (s["kill_ptr"] < E) & (kill_g[kp] <= g)

                def kill_body(s):
                    kp = s["kill_ptr"]
                    slot = kill_slot[kp]
                    s = dict(s)
                    s["n_retried"] = (
                        s["n_retried"] + s["run_n"][slot] + s["q_cnt"][slot]
                    )
                    # in-flight work re-pends first, in start order
                    for c in range(C):
                        take = c < s["run_n"][slot]
                        pos = (s["p_head"] + s["p_cnt"]) % N
                        s["pend"] = s["pend"].at[pos].set(
                            jnp.where(take, s["run_idx"][slot, c],
                                      s["pend"][pos])
                        )
                        s["p_cnt"] = s["p_cnt"] + take
                    # then the queue, FIFO

                    def qm_cond(s2):
                        return s2["q_cnt"][slot] > 0

                    def qm_body(s2):
                        seqs = jnp.where(
                            s2["q_valid"][slot], s2["q_seq"][slot], _BIG_I
                        )
                        j = jnp.argmin(seqs)
                        s2 = _pend_push(s2, s2["q_idx"][slot, j])
                        return _q_pop(s2, slot, j)

                    s = lax.while_loop(qm_cond, qm_body, s)
                    s = dict(s)
                    s["run_fin"] = s["run_fin"].at[slot].set(jnp.inf)
                    s["run_n"] = s["run_n"].at[slot].set(0)
                    s["kill_ptr"] = kp + 1
                    return s

                s = lax.while_loop(kill_cond, kill_body, s)

            # -- 2) arrivals (vectorized: ≤ AMAX per sub-step by
            #       construction; the flag is insurance, not a path) -----
            new_ptr = jnp.searchsorted(arr, t, side="right").astype(
                jnp.int64
            )
            cnt = new_ptr - s["a_ptr"]
            ks = jnp.arange(AMAX, dtype=jnp.int64)
            src = s["a_ptr"] + ks
            valid = src < new_ptr
            pos = jnp.where(valid, (s["p_head"] + s["p_cnt"] + ks) % N, N)
            s["pend"] = s["pend"].at[pos].set(src)
            s["p_cnt"] = s["p_cnt"] + cnt
            s["a_ptr"] = new_ptr
            s["overflow"] = s["overflow"] | (cnt > AMAX)

            # -- 3) due + dispatch --------------------------------------
            ready = ready_mask[win]
            nready = ready.sum()
            due = (s["run_fin"] <= t).any(axis=1)   # pads/empties are +inf

            def disp_body(s, act):
                s = dict(s)
                i = s["pend"][s["p_head"]]
                s["p_head"] = (s["p_head"] + jnp.where(act, 1, 0)) % N
                s["p_cnt"] = s["p_cnt"] - jnp.where(act, 1, 0)
                expired = t - arr[i] > timeout
                loads = s["run_n"] + s["q_cnt"]
                rc = rcode[i]
                if lb_rr:
                    # nready==0 only reaches here masked (act False)
                    j = s["rr_cur"] % jnp.maximum(nready, 1)
                    slot = jnp.argmax(jnp.cumsum(ready) == j + 1)
                    s["rr_cur"] = s["rr_cur"] + jnp.where(
                        act & (~expired), 1, 0
                    )
                else:
                    # least-loaded: lexicographic argmin over (load, rtt);
                    # ready order == slot order == id order, so the
                    # first-index tie-break IS the oracle's id tie-break
                    col = rtt[:, rc]
                    lmask = jnp.where(ready, loads, _BIG_I)
                    c1 = ready & (loads == lmask.min())
                    colm = jnp.where(c1, col, jnp.inf)
                    c2 = c1 & (col == colm.min())
                    slot = jnp.argmax(c2)
                rn = s["run_n"][slot]
                imm = (s["q_cnt"][slot] == 0) & (rn < C) & (~due[slot])
                do_start = act & (~expired) & imm
                do_queue = act & (~expired) & (~imm)
                # immediate start (queue-then-start within this sub-step)
                rn_c = jnp.minimum(rn, C - 1)
                fin = t + svc[i] * (1.0 + 0.15 * rn)
                s["run_fin"] = s["run_fin"].at[slot, rn_c].set(
                    jnp.where(do_start, fin, s["run_fin"][slot, rn_c])
                )
                s["run_idx"] = s["run_idx"].at[slot, rn_c].set(
                    jnp.where(do_start, i, s["run_idx"][slot, rn_c])
                )
                s["run_n"] = s["run_n"].at[slot].add(do_start)
                if trace_on:
                    s["run_disp"] = s["run_disp"].at[slot, rn_c].set(
                        jnp.where(do_start, t, s["run_disp"][slot, rn_c])
                    )
                    s["run_start"] = s["run_start"].at[slot, rn_c].set(
                        jnp.where(do_start, t, s["run_start"][slot, rn_c])
                    )
                # queue append with effective age (arrival − rtt): the
                # shared `t - age > timeout` sweep is then RTT-inclusive
                age = arr[i] - rtt[slot, rc]
                free = jnp.argmin(s["q_valid"][slot])      # first False
                s["overflow"] = s["overflow"] | (
                    do_queue & s["q_valid"][slot].all()
                )
                s["q_idx"] = s["q_idx"].at[slot, free].set(
                    jnp.where(do_queue, i, s["q_idx"][slot, free])
                )
                s["q_age"] = s["q_age"].at[slot, free].set(
                    jnp.where(do_queue, age, s["q_age"][slot, free])
                )
                if trace_on:
                    s["q_disp"] = s["q_disp"].at[slot, free].set(
                        jnp.where(do_queue, t, s["q_disp"][slot, free])
                    )
                s["q_seq"] = s["q_seq"].at[slot, free].set(
                    jnp.where(do_queue, s["seq_ctr"],
                              s["q_seq"][slot, free])
                )
                s["q_valid"] = s["q_valid"].at[slot, free].set(
                    s["q_valid"][slot, free] | do_queue
                )
                s["q_cnt"] = s["q_cnt"].at[slot].add(do_queue)
                s["qmin"] = s["qmin"].at[slot].set(
                    jnp.where(
                        do_queue,
                        jnp.minimum(s["qmin"][slot], age),
                        s["qmin"][slot],
                    )
                )
                s["seq_ctr"] = s["seq_ctr"] + do_queue
                # a lazily-expired pending entry is simply dropped here:
                # status stays 0 and the drain counts it failed
                return s

            def disp_cond(s):
                return (s["p_cnt"] > 0) & (nready > 0)

            def disp_chunk(s, _):
                # K masked pops per iteration: the per-iteration fixed
                # cost (op dispatch dominates on CPU) amortizes over K
                for _k in range(_UNROLL):
                    s = disp_body(s, disp_cond(s))
                return s, None

            s, _ = lax.scan(disp_chunk, s, None, length=NCHUNK)
            # tail remainder (Poisson spikes, outage recovery, kill
            # re-pends) — chunked so carry copies stay few
            s = lax.while_loop(
                disp_cond, lambda s: disp_chunk(s, None)[0], s
            )

            # -- 4) completions (every entry with finish <= t) ----------
            fin = s["run_fin"]
            done = fin <= t
            idxs = s["run_idx"]
            e2e_v = (fin - arr[idxs]) + rtt[
                jnp.arange(R)[:, None], rcode[idxs]
            ]
            scat = jnp.where(done, idxs, N).ravel()
            verdict = jnp.where(e2e_v > timeout, 2, 1).astype(jnp.int8)
            status = st["status"].at[scat].set(verdict.ravel())
            e2e = st["e2e"].at[scat].set(e2e_v.ravel())
            if trace_on:
                # resolve the span timeline at the same scatter (a killed
                # request overwrites on its retry, so these record the
                # final — completing — attempt)
                slot_ids = jnp.broadcast_to(
                    jnp.arange(R, dtype=jnp.int64)[:, None], (R, C)
                )
                trace_out = {
                    "disp_t": st["disp_t"].at[scat].set(
                        s["run_disp"].ravel()
                    ),
                    "start_t": st["start_t"].at[scat].set(
                        s["run_start"].ravel()
                    ),
                    "rep": st["rep"].at[scat].set(slot_ids.ravel()),
                    "fin_t": st["fin_t"].at[scat].set(fin.ravel()),
                }
            order = jnp.argsort(done.astype(jnp.int8), axis=1,
                                stable=True)         # keep start order
            s["run_fin"] = jnp.take_along_axis(
                jnp.where(done, jnp.inf, fin), order, axis=1
            )
            s["run_idx"] = jnp.take_along_axis(idxs, order, axis=1)
            s["run_n"] = s["run_n"] - done.sum(axis=1)
            if trace_on:
                # compact the timelines in lockstep with run_fin/run_idx
                s["run_disp"] = jnp.take_along_axis(
                    s["run_disp"], order, axis=1
                )
                s["run_start"] = jnp.take_along_axis(
                    s["run_start"], order, axis=1
                )

            # -- 5) queue expiry (RTT-inclusive; O(R) guard per step,
            #       one whole slot cleared per iteration) ---------------
            if expire_on:
                q_age_c = s["q_age"]     # append-only within this stage

                def exp_cond(e):
                    hit = (e["q_cnt"] > 0) & (t - e["qmin"] > timeout)
                    return hit.any()

                def exp_body(e):
                    hit = (e["q_cnt"] > 0) & (t - e["qmin"] > timeout)
                    slot = jnp.argmax(hit)
                    vrow = e["q_valid"][slot]
                    drop = vrow & (t - q_age_c[slot] > timeout)
                    nv = vrow & ~drop
                    ages = jnp.where(nv, q_age_c[slot], jnp.inf)
                    e = dict(e)
                    e["q_valid"] = e["q_valid"].at[slot].set(nv)
                    e["q_cnt"] = e["q_cnt"].at[slot].set(nv.sum())
                    e["qmin"] = e["qmin"].at[slot].set(ages.min())
                    return e

                sub = {k: s[k] for k in ("q_valid", "q_cnt", "qmin")}
                s.update(lax.while_loop(exp_cond, exp_body, sub))

            # -- 6) starts (drain queues into freed capacity) -----------
            def start_body(s, act):
                can = ready & (s["run_n"] < C) & (s["q_cnt"] > 0)
                act = act & can.any()
                slot = jnp.argmax(can)
                seqs = jnp.where(
                    s["q_valid"][slot], s["q_seq"][slot], _BIG_I
                )
                j = jnp.argmin(seqs)
                i = s["q_idx"][slot, j]
                rn = s["run_n"][slot]
                rn_c = jnp.minimum(rn, C - 1)
                fin_t = t + svc[i] * (1.0 + 0.15 * rn)
                s = dict(s)
                s["run_fin"] = s["run_fin"].at[slot, rn_c].set(
                    jnp.where(act, fin_t, s["run_fin"][slot, rn_c])
                )
                s["run_idx"] = s["run_idx"].at[slot, rn_c].set(
                    jnp.where(act, i, s["run_idx"][slot, rn_c])
                )
                s["run_n"] = s["run_n"].at[slot].add(act)
                if trace_on:
                    s["run_disp"] = s["run_disp"].at[slot, rn_c].set(
                        jnp.where(act, s["q_disp"][slot, j],
                                  s["run_disp"][slot, rn_c])
                    )
                    s["run_start"] = s["run_start"].at[slot, rn_c].set(
                        jnp.where(act, t, s["run_start"][slot, rn_c])
                    )
                s["q_valid"] = s["q_valid"].at[slot, j].set(
                    s["q_valid"][slot, j] & (~act)
                )
                s["q_cnt"] = s["q_cnt"].at[slot].add(
                    jnp.where(act, -1, 0)
                )
                ages = jnp.where(s["q_valid"][slot], s["q_age"][slot],
                                 jnp.inf)
                s["qmin"] = s["qmin"].at[slot].set(
                    jnp.where(act, ages.min(), s["qmin"][slot])
                )
                return s

            def start_cond(s):
                can = ready & (s["run_n"] < C) & (s["q_cnt"] > 0)
                return can.any()

            def start_chunk(s, _):
                for _k in range(_UNROLL):
                    s = start_body(s, jnp.bool_(True))
                return s, None

            s, _ = lax.scan(start_chunk, s, None, length=NCHUNK)
            s = lax.while_loop(
                start_cond, lambda s: start_chunk(s, None)[0], s
            )

            st = dict(st)
            st.update(s)
            st["status"] = status
            st["e2e"] = e2e
            if trace_on:
                st.update(trace_out)
            return st, None

        st, _ = lax.scan(step, st0, (ts, gs, wins))
        out = {
            "status": st["status"][:N],
            "e2e": st["e2e"][:N],
            "a_ptr": st["a_ptr"],
            "run_n": st["run_n"],
            "q_cnt": st["q_cnt"],
            "n_retried": st["n_retried"],
            "overflow": st["overflow"],
        }
        if trace_on:
            out.update({
                "disp_t": st["disp_t"][:N],
                "start_t": st["start_t"][:N],
                "rep": st["rep"][:N],
                "fin_t": st["fin_t"][:N],
            })
        return out

    return jax.jit(
        jax.vmap(
            lane,
            in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None, None, None),
        )
    )


def get_kernel(key: KernelKey):
    """Compile-once cache: cells sharing a static signature share one
    XLA program (the vmap batch width is a traced dimension per call)."""
    k = _KERNELS.get(key)
    if k is None:
        k = _KERNELS[key] = _build_kernel(key)
    return k


def run_group(
    key: KernelKey,
    lanes: Dict[str, np.ndarray],
    ts: np.ndarray,
    gs: np.ndarray,
    wins: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Run one shape group: ``lanes`` holds the stacked per-cell tensors
    (leading axis = cell), grid arrays are shared across the batch.
    Returns host-side numpy outputs keyed like the lane dict above."""
    kern = get_kernel(key)
    # trace, compile and execute under x64 (the jit cache keys on the
    # flag, so every call sees one consistent dtype world)
    with enable_x64():
        out = kern(
            jnp.asarray(lanes["arr"]),
            jnp.asarray(lanes["svc"]),
            jnp.asarray(lanes["rcode"]),
            jnp.asarray(lanes["rtt"]),
            jnp.asarray(lanes["ready"]),
            jnp.asarray(lanes["kill_slot"]),
            jnp.asarray(lanes["kill_g"]),
            jnp.asarray(lanes["timeout"]),
            jnp.asarray(ts),
            jnp.asarray(gs),
            jnp.asarray(wins),
        )
        return {k2: np.asarray(v) for k2, v in out.items()}

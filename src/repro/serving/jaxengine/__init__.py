"""JAX-native scenario engine: jit/vmap the serving sweep itself.

Two-phase design — phase A replays the control plane in Python with the
real cluster simulator and records a dense replica schedule; phase B
compiles the request-model serving data plane as one ``lax.scan`` and
``vmap``s it across every cell of a scenario matrix that shares a shape
signature.  See :mod:`repro.serving.jaxengine.schedule` (phase A),
:mod:`repro.serving.jaxengine.kernel` (phase B) and
:mod:`repro.serving.jaxengine.engine` (the facade / batch API).

Importing this package pulls in :mod:`jax`; the service builder imports
it lazily so ``sim.engine: "vector"`` runs never pay that cost.
"""

from repro.serving.jaxengine.engine import (
    JaxServingEngine,
    assemble_result,
    run_cells,
    run_schedules,
)
from repro.serving.jaxengine.schedule import (
    CellSchedule,
    SubStepGrid,
    build_grid,
)

__all__ = [
    "JaxServingEngine",
    "CellSchedule",
    "SubStepGrid",
    "assemble_result",
    "build_grid",
    "run_cells",
    "run_schedules",
]

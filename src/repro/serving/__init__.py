"""Serving data plane: latency model, replicas, LB, serving engines.

Two equivalent simulation engines share the control plane (policy /
autoscaler / controller / LB) and the roofline latency model:

* ``engine.py`` — :class:`VectorizedServingEngine`, the default hot path:
  NumPy array state, event-skipping sub-ticks, several times faster;
* ``sim.py`` — :class:`ServingSimulator`, the legacy per-request object
  simulator; kept as the readable reference implementation and the
  differential-test oracle (``tests/test_differential.py``).

Live token-serving (real JAX prefill/decode) lives in
``examples/serve_llm.py`` / ``benchmarks/engine_bench.py``.
"""

from repro.serving.engine import VectorizedServingEngine
from repro.serving.latency import (
    LatencyModel,
    ProfiledLatencyModel,
    make_latency_model,
)
from repro.serving.load_balancer import LeastLoadedBalancer, RoundRobinBalancer
from repro.serving.replica import Replica, ReplicaState
from repro.serving.sim import ServingSimulator, ServingResult
from repro.serving.token import (
    ContinuousBatch,
    TokenEngineConfig,
    TokenReplica,
    TokenSchedulerConfig,
    TokenStats,
)

__all__ = [
    "LatencyModel",
    "ProfiledLatencyModel",
    "make_latency_model",
    "LeastLoadedBalancer",
    "RoundRobinBalancer",
    "Replica",
    "ReplicaState",
    "ServingSimulator",
    "ServingResult",
    "ContinuousBatch",
    "TokenEngineConfig",
    "TokenReplica",
    "TokenSchedulerConfig",
    "TokenStats",
    "VectorizedServingEngine",
]

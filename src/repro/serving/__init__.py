"""Serving data plane: latency model, replicas, LB, controller, engine.

Two execution modes share the same control plane (policy / autoscaler /
controller / LB):

* **simulated replicas** (``sim.py``): request service times come from the
  roofline-derived latency model — this is how the paper's §5 experiments
  replay 22-hour workloads in seconds;
* **live replicas** (``engine.py``): a real JAX inference engine (prefill +
  continuous-batching decode) serves actual tokens; preemptions are
  injected into the running fleet (the §5.1 analogue on this container).
"""

from repro.serving.latency import LatencyModel
from repro.serving.load_balancer import LeastLoadedBalancer, RoundRobinBalancer
from repro.serving.replica import Replica, ReplicaState
from repro.serving.sim import ServingSimulator, ServingResult

__all__ = [
    "LatencyModel",
    "LeastLoadedBalancer",
    "RoundRobinBalancer",
    "Replica",
    "ReplicaState",
    "ServingSimulator",
    "ServingResult",
]

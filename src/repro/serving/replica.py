"""Replica: an inference endpoint bound to a cloud instance.

Lifecycle mirrors the controller's view (§4): the instance provisions
(cold start d covers VM boot + image + model load), then the readiness
probe flips the replica READY and the LB may route to it.  A preemption
kills the replica; its in-flight requests fail and are retried client-side
(the failure time counts into end-to-end latency — §5.1 methodology).

In simulation the replica is an M/G/c-style server: ``concurrency`` slots,
FIFO queue, service times from the latency model.  (The vectorized engine
in ``repro.serving.engine`` replicates this exact behavior with array
state instead of one object per replica.)
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Callable, Dict, List, Optional, Tuple

from repro.cluster.catalog import region_rtt_ms
from repro.cluster.instance import Instance
from repro.serving.latency import LatencyModel
from repro.workloads.arrivals import Request


class ReplicaState(enum.Enum):
    PROVISIONING = "provisioning"
    READY = "ready"
    DEAD = "dead"


@dataclasses.dataclass
class InFlight:
    request: Request
    started_s: float
    finish_s: float


class Replica:
    """One model replica on one instance."""

    def __init__(
        self,
        instance: Instance,
        latency: LatencyModel,
        *,
        concurrency: Optional[int] = None,
        concurrency_cap: int = 16,   # cap on the model-derived default
        timeout_s: float = 0.0,      # 0: requests never expire in queue
        span_tap=None,               # repro.obs.spans.SpanCollector
        span_ord: int = -1,          # this replica's dense run ordinal
    ) -> None:
        self.instance = instance
        self.latency = latency
        self.concurrency = concurrency or min(
            latency.max_concurrency(), concurrency_cap
        )
        self.timeout_s = timeout_s
        self.span_tap = span_tap
        self.span_ord = span_ord
        self.state = ReplicaState.PROVISIONING
        self.queue: List[Request] = []
        self.running: List[InFlight] = []
        self.completed = 0

    # -- lifecycle ------------------------------------------------------
    @property
    def id(self) -> int:
        return self.instance.id

    @property
    def zone(self) -> str:
        return self.instance.zone

    @property
    def region(self) -> str:
        return self.instance.region

    def readiness_probe(self, now: float) -> bool:
        """§4: periodic health probe; flips PROVISIONING -> READY."""
        if self.state is ReplicaState.PROVISIONING and \
                self.instance.is_ready():
            self.state = ReplicaState.READY
        return self.state is ReplicaState.READY

    def kill(self) -> List[Request]:
        """Preemption/termination: fail queue + in-flight; return them for
        client-side retry."""
        self.state = ReplicaState.DEAD
        failed = [f.request for f in self.running] + self.queue
        self.running, self.queue = [], []
        return failed

    # -- request path --------------------------------------------------
    @property
    def load(self) -> int:
        return len(self.running) + len(self.queue)

    def submit(self, req: Request, now: float) -> None:
        self.queue.append(req)

    def step(self, now: float) -> Tuple[
        List[Tuple[Request, float]], List[Request]
    ]:
        """Advance to ``now``: complete finished work, expire abandoned
        queue entries (client hung up past its timeout), start queued work.
        Returns (completions [(request, completion_time)], expired)."""
        done: List[Tuple[Request, float]] = []
        still: List[InFlight] = []
        for f in self.running:
            if f.finish_s <= now:
                done.append((f.request, f.finish_s))
                self.completed += 1
            else:
                still.append(f)
        self.running = still
        expired: List[Request] = []
        if self.timeout_s > 0:
            fresh = []
            for q in self.queue:
                # RTT-inclusive deadline: the response cannot reach the
                # client before arrival + timeout once
                # now - arrival + rtt > timeout — the same check applied
                # to completed responses in the engines
                rtt = region_rtt_ms(q.client_region, self.region) / 1e3
                if now - q.arrival_s + rtt > self.timeout_s:
                    expired.append(q)
                else:
                    fresh.append(q)
            self.queue = fresh
        tap = self.span_tap
        while self.queue and len(self.running) < self.concurrency:
            req = self.queue.pop(0)
            svc = self.latency.service_s(req.prompt_tokens,
                                         req.output_tokens)
            # mild interference: concurrent decode shares HBM bandwidth
            factor = 1.0 + 0.15 * len(self.running)
            self.running.append(
                InFlight(req, now, now + svc * factor)
            )
            if tap is not None:
                o = tap.want_ids.get(req.id)
                if o is not None:
                    tap.start(o, now)
        return done, expired

    def eta_if_submitted(self, req: Request, now: float) -> float:
        """Rough completion estimate used by latency-aware LBs.

        The backlog ahead of the new request is the queued work *plus*
        the residual time of work already running — ignoring the latter
        made estimates systematically optimistic on busy replicas (a
        replica with full slots but an empty queue looked instantly
        available)."""
        svc = self.latency.service_s(req.prompt_tokens, req.output_tokens)
        residual = sum(
            max(0.0, f.finish_s - now) for f in self.running
        )
        backlog = (residual + sum(
            self.latency.service_s(q.prompt_tokens, q.output_tokens)
            for q in self.queue
        )) / max(self.concurrency, 1)
        return now + backlog + svc

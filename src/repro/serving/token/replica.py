"""TokenReplica: the continuous-batching engine behind the Replica API.

A drop-in for :class:`repro.serving.replica.Replica` inside the legacy
:class:`~repro.serving.sim.ServingSimulator`: same lifecycle (readiness
probe, kill-on-preemption), same ``submit``/``step`` contract, but the
request path runs through a :class:`~repro.serving.token.batch.
ContinuousBatch` instead of M/G/c slots — requests join and leave the
batch at iteration boundaries, queue when the KV cache is full, and lose
all KV state on preemption.

``step`` still returns ``(completions, expired)`` so the simulator's
request accounting is untouched; the token-level timelines ride along in
``take_completions()`` (parallel to the completions of the *same* step),
from which the simulator builds :class:`TokenRecord`s with the RTT term.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.cluster.catalog import region_rtt_ms
from repro.cluster.instance import Instance
from repro.serving.latency import LatencyModel
from repro.serving.replica import Replica, ReplicaState
from repro.serving.token.batch import (
    ContinuousBatch,
    KillReport,
    TokenCompletion,
)
from repro.serving.token.config import TokenEngineConfig
from repro.workloads.arrivals import Request

__all__ = ["TokenReplica"]


class TokenReplica(Replica):
    """One continuous-batching model replica on one instance."""

    def __init__(
        self,
        instance: Instance,
        latency: LatencyModel,
        engine_cfg: TokenEngineConfig,
        *,
        timeout_s: float = 0.0,
        span_tap=None,
        span_ord: int = -1,
    ) -> None:
        # concurrency slots are meaningless here (the batch admits by KV
        # budget and max_batch); pass 1 to skip the M/G/c derivation
        super().__init__(
            instance, latency, concurrency=1, timeout_s=timeout_s,
            span_tap=span_tap, span_ord=span_ord,
        )
        self.batch = ContinuousBatch(engine_cfg, tap=span_tap)
        self.kill_report: Optional[KillReport] = None
        self._by_key: Dict[int, Request] = {}
        self._rejected: List[Request] = []
        self._completions: List[TokenCompletion] = []

    # -- request path ---------------------------------------------------
    @property
    def load(self) -> int:
        return self.batch.load

    def submit(self, req: Request, now: float) -> None:
        rtt = region_rtt_ms(req.client_region, self.region) / 1e3
        ok = self.batch.enqueue(
            req.id, req.prompt_tokens, req.output_tokens,
            req.arrival_s, now, rtt_s=rtt,
        )
        if ok:
            self._by_key[req.id] = req
        else:
            # prompt+output exceed the whole KV budget: unservable here
            self._rejected.append(req)
        tap = self.span_tap
        if tap is not None:
            o = tap.want_ids.get(req.id)
            if o is not None:
                tap.dispatch(
                    o, now, self.span_ord, rtt, req.arrival_s, token=True
                )
                if ok:
                    self.batch.track(req.id, o)
                else:
                    tap.reject(o, now)

    def step(self, now: float) -> Tuple[
        List[Tuple[Request, float]], List[Request]
    ]:
        done: List[Tuple[Request, float]] = []
        for c in self.batch.advance(now):
            req = self._by_key.pop(c.key)
            done.append((req, c.finish_s))
            self._completions.append(c)
            self.completed += 1
        expired: List[Request] = []
        if self.timeout_s > 0:
            for key in self.batch.expire_queue(now, self.timeout_s):
                expired.append(self._by_key.pop(key))
        if self._rejected:
            expired.extend(self._rejected)
            self._rejected = []
        return done, expired

    def take_completions(self) -> List[TokenCompletion]:
        """Token timelines parallel to the last ``step``'s completions."""
        out = self._completions
        self._completions = []
        return out

    def kill(self) -> List[Request]:
        self.state = ReplicaState.DEAD
        report = self.batch.kill()
        self.kill_report = report
        failed = [self._by_key.pop(k) for k in report.keys]
        failed.extend(self._rejected)
        self._rejected = []
        return failed

    def kill_migrating(
        self,
        runtime,                    # repro.migration.MigrationRuntime
        targets: List["TokenReplica"],
        now: float,
        grace_s: float,
    ) -> Tuple[object, List[Tuple[Request, object]], List[Request]]:
        """Warned preemption: drain/migrate/kill via the migration
        runtime instead of dropping everything.

        Returns ``(outcome, drained, failed)``: the
        :class:`~repro.migration.runtime.PreemptionOutcome`, the drained
        ``(request, SeqState)`` pairs (they complete at the kill
        instant; the caller emits their records), and the requests that
        must retry client-side.  Migrated requests move to the target
        replica's key map and complete there."""
        self.state = ReplicaState.DEAD
        by_rid = {tr.instance.id: tr for tr in targets}
        outcome = runtime.execute_preemption(
            self.batch,
            self.instance,
            [(tr.instance.id, tr.batch, tr.instance) for tr in targets],
            now,
            grace_s,
        )
        drained = [
            (self._by_key.pop(s.key), s) for s in outcome.drained
        ]
        for m in outcome.migrated:
            tgt = by_rid[m.target_rid]
            tgt._by_key[m.state.key] = self._by_key.pop(m.state.key)
        self.kill_report = outcome.kill_report
        failed = [self._by_key.pop(k) for k in outcome.kill_report.keys]
        failed.extend(self._rejected)
        self._rejected = []
        return outcome, drained, failed

    def eta_if_submitted(self, req: Request, now: float) -> float:
        svc = (
            self.batch.cfg.overhead_s
            + req.prompt_tokens * self.batch.cfg.prefill_s_per_token
            + req.output_tokens * self.batch.cfg.weight_read_s
        )
        return now + self.batch.backlog_hint_s() + svc

"""ContinuousBatch: iteration-level (Orca-style) replica scheduling.

One :class:`ContinuousBatch` models the inference engine on one replica:

* **join/leave at iteration boundaries** — requests wait in a FIFO
  admission queue until the KV cache has room for their full footprint
  (``prompt + output`` tokens, reserved up front so a sequence never has
  to be evicted mid-flight) and the batch is under ``max_batch``;
* **chunked prefill** — at most ``prefill_chunk_tokens`` prompt tokens
  are processed per iteration (shared FIFO across prefilling sequences),
  so a long prompt cannot stall decode for seconds;
* **batch-dependent decode step** — an iteration costs
  ``iter_overhead + weight_read_s + kv_read_s_per_token · K`` where
  ``K`` is the batch's resident KV tokens: weights are read once and
  amortized across the batch, KV is read per-sequence.  This is the HBM
  roofline doing the work the request-level model's ``1 + 0.15·running``
  constant hand-waved;
* **preemption loses all KV state** — ``kill()`` drops every in-flight
  sequence and returns an accounting of the tokens that must be
  re-prefetched/re-decoded elsewhere (the SpotServe cost).

The hot path is exact but not naive: pure-decode stretches advance in
closed form (the iteration time is affine in the iteration index, so the
time of ``n`` iterations is a quadratic — solved, not summed), and the
per-sequence state lives in parallel NumPy arrays so both serving engines
share one vectorized implementation.

Clock discipline: ``advance(t)`` runs whole iterations whose *end* is
``<= t`` — the internal clock never passes ``t``, and a request enqueued
at time ``e`` never occupies an iteration that starts before ``e``.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.token.config import TokenEngineConfig

__all__ = ["ContinuousBatch", "TokenCompletion", "KillReport"]


@dataclasses.dataclass(frozen=True)
class TokenCompletion:
    """One finished request, with its token-level timeline.

    ``finish_s`` / ``first_token_s`` include the per-request
    ``overhead_s`` constant (tokenize/detokenize/HTTP), so an engine's
    end-to-end time is ``finish_s - arrival_s + rtt`` — the same shape
    as the request-level model's accounting.
    """

    key: int
    arrival_s: float
    enqueued_s: float
    first_token_s: float
    finish_s: float
    prompt_tokens: int
    output_tokens: int


@dataclasses.dataclass(frozen=True)
class KillReport:
    """What a preemption destroyed: sequences, queue entries, KV work."""

    keys: Tuple[int, ...]           # every request to retry client-side
    n_batch: int                    # sequences that lost KV state
    n_queued: int                   # admission-queue entries (no KV yet)
    lost_prefill_tokens: int        # prompt tokens that must re-prefill
    lost_decode_tokens: int         # output tokens that must re-decode


_EMPTY_I = np.empty(0, dtype=np.int64)
_EMPTY_F = np.empty(0, dtype=np.float64)


class ContinuousBatch:
    """Iteration-level scheduler state for one replica."""

    __slots__ = (
        "cfg", "now", "queue", "reserved_tokens", "completed",
        "_keys", "_prompt", "_out", "_pref", "_dec",
        "_arrival", "_enq", "_first", "_mig", "tap", "_tord",
    )

    def __init__(self, cfg: TokenEngineConfig, tap=None) -> None:
        self.cfg = cfg
        # span tap (repro.obs.spans.SpanCollector) + key -> ordinal map
        # for the sampled requests resident here.  None when tracing is
        # off: every hot-path guard is one falsy check.
        self.tap = tap
        self._tord: Optional[Dict[int, int]] = (
            {} if tap is not None else None
        )
        self.now = 0.0
        # admission queue:
        # (key, prompt, out, arrival_s, enqueued_s, rtt_s) — rtt_s is the
        # client's network round-trip to THIS replica, folded into the
        # queue-expiry deadline so queued and completed requests face the
        # same RTT-inclusive timeout
        self.queue: Deque[Tuple[int, int, int, float, float, float]] = \
            deque()
        self.reserved_tokens = 0        # sum(prompt+out) over active seqs
        self.completed = 0
        self._keys = _EMPTY_I
        self._prompt = _EMPTY_I
        self._out = _EMPTY_I
        self._pref = _EMPTY_I           # prompt tokens prefilled so far
        self._dec = _EMPTY_I            # output tokens produced so far
        self._arrival = _EMPTY_F
        self._enq = _EMPTY_F
        self._first = _EMPTY_F          # first-token time (engine clock)
        # migrated-in progress awaiting admission: key -> (pref, dec,
        # first).  None (not {}) when migration is off: zero overhead.
        self._mig: Optional[Dict[int, Tuple[int, int, float]]] = None

    # -- introspection --------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._keys)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    @property
    def load(self) -> int:
        return len(self._keys) + len(self.queue)

    @property
    def kv_tokens(self) -> int:
        """Resident KV tokens right now (prefilled + decoded)."""
        return int(self._pref.sum() + self._dec.sum())

    @property
    def committed_tokens(self) -> int:
        """KV tokens spoken for: active reservations plus what the
        admission queue will claim — a migration target's used budget."""
        return self.reserved_tokens + sum(
            p + o for _, p, o, _, _, _ in self.queue
        )

    def iter_states(self) -> List[
        Tuple[int, int, int, int, int, float, float, float]
    ]:
        """Snapshot of in-batch sequences for the migration planner:
        ``(key, prompt, out, prefilled, decoded, arrival_s, enqueued_s,
        first_s)`` per sequence (``first_s`` is nan before any token)."""
        return [
            (int(self._keys[j]), int(self._prompt[j]), int(self._out[j]),
             int(self._pref[j]), int(self._dec[j]),
             float(self._arrival[j]), float(self._enq[j]),
             float(self._first[j]))
            for j in range(len(self._keys))
        ]

    def backlog_hint_s(self) -> float:
        """Rough seconds of work ahead of a new arrival (LB estimates)."""
        cfg = self.cfg
        rem_dec = int((self._out - self._dec).sum())
        rem_pref = int((self._prompt - self._pref).sum())
        q_pref = sum(p for _, p, _, _, _, _ in self.queue)
        q_dec = sum(o for _, _, o, _, _, _ in self.queue)
        b = max(self.n_active, 1)
        # decode tokens of concurrent sequences overlap (one iteration
        # serves the whole batch); queued work runs after them
        return (
            rem_dec * cfg.weight_read_s / b
            + (rem_pref + q_pref) * cfg.prefill_s_per_token
            + q_dec * cfg.weight_read_s
        )

    def track(self, key: int, ordinal: int) -> None:
        """Register a span-sampled request: batch events for ``key`` tap
        the span at ``ordinal`` until the key retires or is evicted."""
        if self._tord is not None:
            self._tord[int(key)] = int(ordinal)

    # -- request path ---------------------------------------------------
    def enqueue(self, key: int, prompt_tokens: int, output_tokens: int,
                arrival_s: float, enqueued_s: float,
                rtt_s: float = 0.0) -> bool:
        """Queue a request for admission.  Returns False when the request
        can *never* fit the KV budget (caller should fail it).
        ``rtt_s`` is the client↔replica round-trip, counted against the
        queue-expiry deadline (see :meth:`expire_queue`)."""
        p = max(1, int(prompt_tokens))
        o = max(1, int(output_tokens))
        if p + o > self.cfg.kv_budget_tokens:
            return False
        self.queue.append(
            (key, p, o, float(arrival_s), float(enqueued_s), float(rtt_s))
        )
        return True

    def enqueue_migrated(
        self, key: int, prompt_tokens: int, output_tokens: int,
        arrival_s: float, enqueued_s: float,
        prefilled: int, decoded: int, first_s: float,
        rtt_s: float = 0.0,
    ) -> bool:
        """Queue a migrated-in sequence.  Its KV cache (``prefilled +
        decoded`` tokens) survived the move, so admission seeds progress
        instead of starting from zero; ``enqueued_s`` is the
        transfer-complete time (the sequence joins at a boundary after
        it), and ``first_s`` preserves an already-emitted first token."""
        p = max(1, int(prompt_tokens))
        o = max(1, int(output_tokens))
        if p + o > self.cfg.kv_budget_tokens:
            return False
        if self._mig is None:
            self._mig = {}
        self._mig[int(key)] = (
            int(prefilled), int(decoded), float(first_s)
        )
        self.queue.append(
            (int(key), p, o, float(arrival_s), float(enqueued_s),
             float(rtt_s))
        )
        return True

    def expire_queue(self, t: float, timeout_s: float) -> List[int]:
        """Drop admission-queue entries whose client gave up: the
        response cannot reach the client before ``arrival + timeout``
        once ``t - arrival + rtt > timeout`` — the same RTT-inclusive
        deadline applied to completed responses.  Returns their keys."""
        if not self.queue:
            return []
        expired: List[int] = []
        kept: Deque[Tuple[int, int, int, float, float, float]] = deque()
        for entry in self.queue:
            if t - entry[3] + entry[5] > timeout_s:
                expired.append(entry[0])
            else:
                kept.append(entry)
        if expired:
            self.queue = kept
            if self._mig:
                for k in expired:
                    self._mig.pop(k, None)
            if self._tord:
                for k in expired:
                    self._tord.pop(k, None)
        return expired

    def remove(self, keys: Sequence[int]) -> None:
        """Drop sequences from the batch without completing or counting
        them (they drained or migrated; the migration runtime owns their
        accounting).  Frees their KV reservation."""
        if len(self._keys) == 0 or not keys:
            return
        kset = {int(k) for k in keys}
        if self._tord:
            for k in kset:
                self._tord.pop(k, None)
        mask = np.fromiter(
            (int(k) in kset for k in self._keys), dtype=bool,
            count=len(self._keys),
        )
        if not mask.any():
            return
        idx = np.nonzero(mask)[0]
        self.reserved_tokens -= int(
            (self._prompt[idx] + self._out[idx]).sum()
        )
        keep = ~mask
        self._keys = self._keys[keep]
        self._prompt = self._prompt[keep]
        self._out = self._out[keep]
        self._pref = self._pref[keep]
        self._dec = self._dec[keep]
        self._arrival = self._arrival[keep]
        self._enq = self._enq[keep]
        self._first = self._first[keep]

    def kill(self) -> KillReport:
        """Preemption: all KV state is lost; every request must retry."""
        keys = tuple(int(k) for k in self._keys) + tuple(
            e[0] for e in self.queue
        )
        lost_p = int(self._pref.sum())
        lost_d = int(self._dec.sum())
        if self._mig:
            # migrated-in sequences still awaiting admission carried KV
            # over the wire; killing the target loses that state too
            for mp, md, _ in self._mig.values():
                lost_p += mp
                lost_d += md
        report = KillReport(
            keys=keys,
            n_batch=len(self._keys),
            n_queued=len(self.queue),
            lost_prefill_tokens=lost_p,
            lost_decode_tokens=lost_d,
        )
        self.queue.clear()
        self._mig = None
        if self._tord:
            self._tord.clear()
        self.reserved_tokens = 0
        self._keys = _EMPTY_I
        self._prompt = _EMPTY_I
        self._out = _EMPTY_I
        self._pref = _EMPTY_I
        self._dec = _EMPTY_I
        self._arrival = _EMPTY_F
        self._enq = _EMPTY_F
        self._first = _EMPTY_F
        return report

    # -- scheduling core ------------------------------------------------
    def _admit(self) -> None:
        """Join waiting requests at the current iteration boundary."""
        cfg = self.cfg
        q = self.queue
        while q:
            key, p, o, arr, enq, _ = q[0]
            if len(self._keys) >= cfg.max_batch:
                break
            if self.reserved_tokens + p + o > cfg.kv_budget_tokens:
                break                   # FIFO: no overtaking smaller reqs
            if len(self._keys) == 0:
                # idle engine: the clock jumps to the work's enqueue time
                if enq > self.now:
                    self.now = enq
            elif enq > self.now:
                break                   # joins at a boundary >= enqueue
            q.popleft()
            self.reserved_tokens += p + o
            mig = self._mig.pop(key, None) if self._mig else None
            self._keys = np.append(self._keys, key)
            self._prompt = np.append(self._prompt, p)
            self._out = np.append(self._out, o)
            if mig is None:
                self._pref = np.append(self._pref, 0)
                self._dec = np.append(self._dec, 0)
                self._first = np.append(self._first, np.nan)
            else:
                # migrated-in: KV survived the move — resume progress
                self._pref = np.append(self._pref, mig[0])
                self._dec = np.append(self._dec, mig[1])
                self._first = np.append(self._first, mig[2])
            self._arrival = np.append(self._arrival, arr)
            self._enq = np.append(self._enq, enq)
            if self._tord:
                o = self._tord.get(key)
                if o is not None:
                    pref0 = p if mig is None else p - mig[0]
                    self.tap.token_join(
                        o, self.now, prefilling=pref0 > 0
                    )

    def _retire(self, mask: np.ndarray, end: float,
                done: List[TokenCompletion]) -> None:
        cfg = self.cfg
        idx = np.nonzero(mask)[0]
        if self._tord:
            for j in idx:
                self._tord.pop(int(self._keys[j]), None)
        for j in idx:
            done.append(TokenCompletion(
                key=int(self._keys[j]),
                arrival_s=float(self._arrival[j]),
                enqueued_s=float(self._enq[j]),
                first_token_s=float(self._first[j]) + cfg.overhead_s,
                finish_s=end + cfg.overhead_s,
                prompt_tokens=int(self._prompt[j]),
                output_tokens=int(self._out[j]),
            ))
        self.completed += len(idx)
        self.reserved_tokens -= int(
            (self._prompt[idx] + self._out[idx]).sum()
        )
        keep = ~mask
        self._keys = self._keys[keep]
        self._prompt = self._prompt[keep]
        self._out = self._out[keep]
        self._pref = self._pref[keep]
        self._dec = self._dec[keep]
        self._arrival = self._arrival[keep]
        self._enq = self._enq[keep]
        self._first = self._first[keep]

    @staticmethod
    def _max_iters(avail: float, lin: float, quad: float) -> int:
        """Largest n >= 0 with ``lin·n + quad·n·(n-1) <= avail``."""
        if avail <= 0 or lin <= 0:
            return 0
        if quad <= 0:
            return int(avail // lin)
        # quad*n^2 + (lin-quad)*n <= avail
        b = lin - quad
        n = int((-b + math.sqrt(b * b + 4.0 * quad * avail)) / (2.0 * quad))
        while n > 0 and lin * n + quad * n * (n - 1) > avail:
            n -= 1
        while lin * (n + 1) + quad * (n + 1) * n <= avail:
            n += 1
        return n

    def advance(self, t: float) -> List[TokenCompletion]:
        """Run every iteration that ends at or before ``t``."""
        cfg = self.cfg
        w = cfg.weight_read_s
        oh = cfg.iter_overhead_s
        r = cfg.kv_read_s_per_token
        pf = cfg.prefill_s_per_token
        done: List[TokenCompletion] = []
        while True:
            self._admit()
            b = len(self._keys)
            if b == 0:
                break
            need = self._prompt - self._pref
            if need.any():
                # ---- mixed iteration: chunked prefill (+ decode step) --
                budget = cfg.prefill_chunk_tokens
                take = np.zeros(b, dtype=np.int64)
                for j in np.nonzero(need)[0]:
                    c = min(int(need[j]), budget)
                    take[j] = c
                    budget -= c
                    if budget <= 0:
                        break
                decoding = need == 0
                n_dec = int(decoding.sum())
                dt = oh + int(take.sum()) * pf
                if n_dec:
                    k_dec = int(
                        (self._pref[decoding] + self._dec[decoding]).sum()
                    )
                    dt += w + r * k_dec
                end = self.now + dt
                if end > t:
                    break
                self.now = end
                self._pref += take
                if self._tord:
                    tap = self.tap
                    for j in np.nonzero(take)[0]:
                        o = self._tord.get(int(self._keys[j]))
                        if o is None:
                            continue
                        tap.token_chunk(o, int(take[j]))
                        if self._pref[j] == self._prompt[j]:
                            tap.token_prefill_done(o, end)
                if n_dec:
                    self._dec[decoding] += 1
                    newly = decoding & (self._dec == 1)
                    self._first[newly] = end
                    finished = decoding & (self._dec == self._out)
                    if finished.any():
                        self._retire(finished, end, done)
                continue
            # ---- pure decode: closed-form block advance ----------------
            rem = self._out - self._dec
            n_leave = int(rem.min())
            k0 = int((self._pref + self._dec).sum())
            lin = oh + w + r * k0           # first iteration's cost
            quad = r * b / 2.0              # KV growth per iteration pair
            # a waiting (admissible) request joins at the first boundary
            # past its enqueue time — cap the block there
            t_eff = t
            join_wait = False
            if self.queue and b < cfg.max_batch:
                key, p, o, arr, enq, _ = self.queue[0]
                if (self.reserved_tokens + p + o <= cfg.kv_budget_tokens
                        and enq < t):
                    cap = max(self.now, min(t, enq))
                    if cap < t_eff:
                        t_eff = cap
                        join_wait = True
            n = self._max_iters(t_eff - self.now, lin, quad)
            if n > n_leave:
                n = n_leave
            if n <= 0:
                if join_wait and self.now + lin <= t:
                    n = 1               # one iteration crosses the join
                else:
                    break
            first_end = self.now + lin
            end = self.now + lin * n + quad * n * (n - 1)
            newly = self._dec == 0
            self._dec += n
            if newly.any():
                self._first[newly] = first_end
            self.now = end
            if n == n_leave:
                self._retire(self._dec == self._out, end, done)
                continue
            if join_wait:
                continue                # clock may now admit the waiter
            break                       # time-capped at t
        return done

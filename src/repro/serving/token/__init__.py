"""Token-level continuous-batching replica model (``repro.serving.token``).

The request-level simulators price a request with one frozen
``service_s`` number and an ad-hoc interference factor.  This package
models what an LLM replica actually does: iteration-level (Orca-style)
batching where requests join/leave per decode step, a KV-cache token
budget derived from the same HBM arithmetic as
``LatencyModel.max_concurrency``, a batch-size-dependent decode step from
the HBM roofline (weight reads amortized across the batch, KV reads per
sequence), chunked prefill, and preemptions that destroy in-flight KV
state so retried requests re-prefill elsewhere.

Select it per run with ``sim.replica_model: token`` in a ``ServiceSpec``;
tune it with the ``serving:`` section.  Both serving engines consume the
same :class:`ContinuousBatch` core: the legacy ``ServingSimulator``
through :class:`TokenReplica`, the ``VectorizedServingEngine`` through a
per-slot batched step loop.  Runs in token mode attach a
:class:`TokenStats` (TTFT/TPOT percentiles, windowed goodput-vs-SLO,
preemption KV-loss accounting) to their ``ServingResult``.
"""

from repro.serving.token.batch import (
    ContinuousBatch,
    KillReport,
    TokenCompletion,
)
from repro.serving.token.config import (
    TokenEngineConfig,
    TokenSchedulerConfig,
    UNBOUNDED_KV_TOKENS,
)
from repro.serving.token.metrics import TokenRecord, TokenStats
from repro.serving.token.replica import TokenReplica

__all__ = [
    "ContinuousBatch",
    "KillReport",
    "TokenCompletion",
    "TokenEngineConfig",
    "TokenSchedulerConfig",
    "TokenRecord",
    "TokenReplica",
    "TokenStats",
    "UNBOUNDED_KV_TOKENS",
]

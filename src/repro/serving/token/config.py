"""Token-engine configuration: scheduler knobs + derived physics.

Two layers, deliberately separate:

* :class:`TokenSchedulerConfig` — the *spec-visible knobs* (SLO targets,
  prefill chunk size, batch/KV caps, per-iteration overhead).  The
  service layer builds one from a ``ServiceSpec``'s ``serving:`` section;
  defaults reproduce an idealized engine (no scheduler overhead).

* :class:`TokenEngineConfig` — the *resolved physics* for one
  (model × instance) pair, derived from a :class:`~repro.serving.latency.
  LatencyModel` by :meth:`TokenEngineConfig.from_latency`:

  - ``weight_read_s`` — one decode iteration's weight traffic over the
    effective HBM bandwidth.  This is exactly
    ``LatencyModel.decode_s_per_token()``: the weights are streamed once
    per iteration and *amortized across the whole batch*, which is the
    physical fact the request-level model's ad-hoc ``1 + 0.15·running``
    interference factor was approximating.
  - ``kv_read_s_per_token`` — per cached token, per iteration: each
    decoding sequence re-reads its own KV cache, so KV traffic scales
    with the batch's resident tokens while weight traffic does not.
  - ``prefill_s_per_token`` — compute-bound prefill from the FLOPs
    roofline (``2·N_active`` FLOPs per token over effective FLOP/s).
  - ``kv_budget_tokens`` — the HBM left after weights, in tokens.  Same
    arithmetic as ``LatencyModel.max_concurrency`` (90% usable HBM minus
    bf16 weights, floored at 5%), just left in tokens instead of being
    divided into fixed ``max_ctx`` request slots.  Attention-free
    architectures (no KV cache) get an unbounded budget and zero KV
    read cost.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.latency import LatencyModel

__all__ = [
    "TokenSchedulerConfig",
    "TokenEngineConfig",
    "UNBOUNDED_KV_TOKENS",
]

# attention-free archs have no KV cache: effectively unlimited token slots
UNBOUNDED_KV_TOKENS = 1 << 40


@dataclasses.dataclass(frozen=True)
class TokenSchedulerConfig:
    """Spec-visible knobs of the continuous-batching scheduler."""

    slo_ttft_s: float = 10.0        # time-to-first-token SLO target
    slo_tpot_s: float = 0.2         # time-per-output-token SLO target
    prefill_chunk_tokens: int = 512  # prefill budget per iteration
    max_batch: Optional[int] = None  # max sequences in flight (None: KV-bound)
    kv_budget_tokens: Optional[int] = None   # override the derived budget
    iter_overhead_s: float = 0.0    # scheduler overhead per iteration
    goodput_window_s: float = 60.0  # goodput aggregation window

    def __post_init__(self) -> None:
        if self.slo_ttft_s <= 0 or self.slo_tpot_s <= 0:
            raise ValueError(
                f"SLO targets must be positive, got ttft={self.slo_ttft_s} "
                f"tpot={self.slo_tpot_s}"
            )
        if self.prefill_chunk_tokens < 1:
            raise ValueError(
                f"prefill_chunk_tokens must be >= 1, "
                f"got {self.prefill_chunk_tokens}"
            )
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.kv_budget_tokens is not None and self.kv_budget_tokens < 1:
            raise ValueError(
                f"kv_budget_tokens must be >= 1, got {self.kv_budget_tokens}"
            )
        if self.iter_overhead_s < 0:
            raise ValueError(
                f"iter_overhead_s must be >= 0, got {self.iter_overhead_s}"
            )
        if self.goodput_window_s <= 0:
            raise ValueError(
                f"goodput_window_s must be positive, "
                f"got {self.goodput_window_s}"
            )


@dataclasses.dataclass(frozen=True)
class TokenEngineConfig:
    """Resolved per-(model × instance) physics of the token engine."""

    weight_read_s: float            # decode iteration floor (weights / HBM)
    kv_read_s_per_token: float      # extra per resident KV token, per iter
    prefill_s_per_token: float      # compute-bound prefill slope
    overhead_s: float               # per-request tokenize/HTTP constant
    iter_overhead_s: float
    kv_budget_tokens: int
    prefill_chunk_tokens: int
    max_batch: int
    # per cached token, bytes resident in HBM — what a KV migration has
    # to move over the wire (0.0 for attention-free architectures)
    kv_bytes_per_token: float = 0.0

    @classmethod
    def from_latency(
        cls,
        lm: LatencyModel,
        knobs: Optional[TokenSchedulerConfig] = None,
    ) -> "TokenEngineConfig":
        knobs = knobs or TokenSchedulerConfig()
        kv_bytes = lm.kv_bytes_per_token()
        if kv_bytes > 0:
            # the same free-HBM arithmetic as LatencyModel.max_concurrency
            # (shared helpers), kept in tokens instead of fixed
            # max_ctx-sized request slots
            budget = max(1, int(lm.free_kv_hbm_bytes() / kv_bytes))
            kv_read = kv_bytes / lm.hbm_bytes_per_s
        else:
            budget = UNBOUNDED_KV_TOKENS
            kv_read = 0.0
        if knobs.kv_budget_tokens is not None:
            budget = knobs.kv_budget_tokens
        return cls(
            weight_read_s=lm.decode_s_per_token(),
            kv_read_s_per_token=kv_read,
            prefill_s_per_token=2.0 * lm._active_params / lm.flops_per_s,
            overhead_s=lm.overhead_s,
            iter_overhead_s=knobs.iter_overhead_s,
            kv_budget_tokens=budget,
            prefill_chunk_tokens=knobs.prefill_chunk_tokens,
            max_batch=knobs.max_batch if knobs.max_batch is not None
            else 1 << 30,
            kv_bytes_per_token=kv_bytes,
        )

"""Token-level serving metrics: TTFT, TPOT, and goodput-vs-SLO.

The paper's headline latency percentiles treat a request as one number;
LLM serving SLOs do not.  The token engine therefore emits, per request:

* **TTFT** — time to first token: arrival -> first decode iteration end,
  including queueing, chunked prefill, the per-request overhead constant
  and the client<->replica RTT (first byte crosses the network);
* **TPOT** — time per output token: the mean inter-token gap over the
  decode phase, ``(finish - first_token) / (output_tokens - 1)`` — pure
  decode pace, independent of queueing and prefill.

A request *attains the SLO* when both TTFT and TPOT are within their
targets.  **Goodput** is the throughput of SLO-attaining requests
(req/s) — the metric DistServe/AlpaServe-style systems optimize —
reported both for the whole run and per wall-clock window so a
preemption's goodput crater is visible in the series.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["TokenRecord", "TokenStats"]


@dataclasses.dataclass(frozen=True)
class TokenRecord:
    """Token-level timeline of one *completed* request."""

    req_id: int
    arrival_s: float
    first_token_s: float            # engine clock, incl. overhead_s
    finish_s: float                 # engine clock, incl. overhead_s
    output_tokens: int
    rtt_s: float

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s + self.rtt_s

    @property
    def tpot_s(self) -> float:
        return (self.finish_s - self.first_token_s) / max(
            self.output_tokens - 1, 1
        )

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s + self.rtt_s


@dataclasses.dataclass
class TokenStats:
    """Aggregated token-level metrics of one serving run."""

    slo_ttft_s: float
    slo_tpot_s: float
    n_requests: int                 # every request that arrived
    n_recorded: int                 # completions with token records
    ttft_s: np.ndarray
    tpot_s: np.ndarray
    n_slo_ok: int
    slo_attainment: float           # n_slo_ok / n_requests
    goodput_rps: float              # n_slo_ok / horizon
    window_s: float
    windows: List[Dict[str, float]]
    # preemption cost accounting (KV state is not recoverable)
    n_kv_preempted_seqs: int = 0
    n_killed_queued: int = 0
    lost_prefill_tokens: int = 0
    lost_decode_tokens: int = 0
    # grace-period migration accounting (repro.migration; all zero when
    # migration is disabled)
    n_drained_seqs: int = 0         # finished in place in the window
    n_migrated_seqs: int = 0        # KV shipped to a surviving replica
    migrated_kv_tokens: int = 0     # resident tokens that moved
    saved_prefill_tokens: int = 0   # prefill work not re-done elsewhere
    saved_decode_tokens: int = 0
    migration_transfer_s: float = 0.0   # cumulative wire time
    recompute_saved_s: float = 0.0  # engine-seconds of recompute avoided

    # ------------------------------------------------------------------
    @classmethod
    def from_records(
        cls,
        records: List[TokenRecord],
        *,
        slo_ttft_s: float,
        slo_tpot_s: float,
        horizon_s: float,
        window_s: float,
        n_requests: int,
        n_kv_preempted_seqs: int = 0,
        n_killed_queued: int = 0,
        lost_prefill_tokens: int = 0,
        lost_decode_tokens: int = 0,
        n_drained_seqs: int = 0,
        n_migrated_seqs: int = 0,
        migrated_kv_tokens: int = 0,
        saved_prefill_tokens: int = 0,
        saved_decode_tokens: int = 0,
        migration_transfer_s: float = 0.0,
        recompute_saved_s: float = 0.0,
    ) -> "TokenStats":
        n = len(records)
        ttft = np.fromiter((r.ttft_s for r in records), np.float64, count=n)
        tpot = np.fromiter((r.tpot_s for r in records), np.float64, count=n)
        ok = (ttft <= slo_ttft_s) & (tpot <= slo_tpot_s)
        n_ok = int(ok.sum())
        horizon = max(float(horizon_s), 1e-9)
        finish = np.fromiter(
            (r.finish_s for r in records), np.float64, count=n
        )
        windows: List[Dict[str, float]] = []
        n_windows = int(np.ceil(horizon / window_s)) if n else 0
        if n_windows:
            # post-horizon finishes (the end-of-run drain) land in their
            # own flagged bucket — clipping them into the last real
            # window would inflate its goodput with work the horizon
            # never saw
            bins = np.minimum(
                np.maximum((finish // window_s).astype(np.int64), 0),
                n_windows,
            )
            total = np.bincount(bins, minlength=n_windows + 1)
            good = np.bincount(
                bins, weights=ok.astype(np.float64),
                minlength=n_windows + 1,
            )
            for k in range(n_windows):
                windows.append({
                    "t0_s": round(k * window_s, 6),
                    "n_completed": int(total[k]),
                    "n_slo_ok": int(good[k]),
                    "goodput_rps": round(float(good[k]) / window_s, 6),
                })
            if total[n_windows]:
                # drain bucket: no defined duration, so no rate
                windows.append({
                    "t0_s": round(n_windows * window_s, 6),
                    "n_completed": int(total[n_windows]),
                    "n_slo_ok": int(good[n_windows]),
                    "goodput_rps": 0.0,
                    "post_horizon": True,
                })
        return cls(
            slo_ttft_s=slo_ttft_s,
            slo_tpot_s=slo_tpot_s,
            n_requests=n_requests,
            n_recorded=n,
            ttft_s=ttft,
            tpot_s=tpot,
            n_slo_ok=n_ok,
            slo_attainment=n_ok / max(n_requests, 1),
            goodput_rps=n_ok / horizon,
            window_s=window_s,
            windows=windows,
            n_kv_preempted_seqs=n_kv_preempted_seqs,
            n_killed_queued=n_killed_queued,
            lost_prefill_tokens=lost_prefill_tokens,
            lost_decode_tokens=lost_decode_tokens,
            n_drained_seqs=n_drained_seqs,
            n_migrated_seqs=n_migrated_seqs,
            migrated_kv_tokens=migrated_kv_tokens,
            saved_prefill_tokens=saved_prefill_tokens,
            saved_decode_tokens=saved_decode_tokens,
            migration_transfer_s=migration_transfer_s,
            recompute_saved_s=recompute_saved_s,
        )

    # ------------------------------------------------------------------
    def ttft_pct(self, q: float) -> float:
        if len(self.ttft_s) == 0:
            return float("nan")
        return float(np.percentile(self.ttft_s, q))

    def tpot_pct(self, q: float) -> float:
        if len(self.tpot_s) == 0:
            return float("nan")
        return float(np.percentile(self.tpot_s, q))

    def to_dict(self, include_windows: bool = True) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "slo_ttft_s": self.slo_ttft_s,
            "slo_tpot_s": self.slo_tpot_s,
            "n_requests": self.n_requests,
            "n_recorded": self.n_recorded,
            "n_slo_ok": self.n_slo_ok,
            "slo_attainment": round(self.slo_attainment, 6),
            "goodput_rps": round(self.goodput_rps, 6),
            "ttft_p50_s": _r(self.ttft_pct(50)),
            "ttft_p90_s": _r(self.ttft_pct(90)),
            "ttft_p99_s": _r(self.ttft_pct(99)),
            "tpot_p50_s": _r(self.tpot_pct(50)),
            "tpot_p99_s": _r(self.tpot_pct(99)),
            "n_kv_preempted_seqs": self.n_kv_preempted_seqs,
            "n_killed_queued": self.n_killed_queued,
            "lost_prefill_tokens": self.lost_prefill_tokens,
            "lost_decode_tokens": self.lost_decode_tokens,
            "n_drained_seqs": self.n_drained_seqs,
            "n_migrated_seqs": self.n_migrated_seqs,
            "migrated_kv_tokens": self.migrated_kv_tokens,
            "saved_prefill_tokens": self.saved_prefill_tokens,
            "saved_decode_tokens": self.saved_decode_tokens,
            "migration_transfer_s": round(self.migration_transfer_s, 6),
            "recompute_saved_s": round(self.recompute_saved_s, 6),
            "window_s": self.window_s,
        }
        if include_windows:
            out["windows"] = self.windows
        return out


def _r(v: float, nd: int = 6) -> Optional[float]:
    return round(v, nd) if np.isfinite(v) else None
